"""The paper's own evaluation models (Table I) — used by the benchmark
harness for §Paper-validation. These are *cost/config* definitions; routing
traces come from trace-scale variants (same L/E/k, tiny d_model) run through
the live engine, exactly mirroring the paper's offline preprocess.

Quantization per paper §VI-A: 4-bit AWQ for both Mixtrals, FP8 for
Qwen3-30B-A3B, full weights for DeepSeekMoE-16B.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL_8X7B

MIXTRAL_8X22B = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128,
    n_experts=8, n_shared_experts=0, top_k=2, d_expert=16384,
    rope_theta=1_000_000.0, source="arXiv:2401.04088 (8x22B card)",
)

QWEN3_30B_A3B = ArchConfig(
    name="qwen3-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128, qk_norm=True,
    n_experts=128, n_shared_experts=0, top_k=8, d_expert=768,
    rope_theta=1_000_000.0, source="hf:Qwen/Qwen3-30B-A3B",
)

DEEPSEEKMOE_16B = ArchConfig(
    name="deepseekmoe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, d_expert=1408,
    rope_theta=10_000.0, source="arXiv:2401.06066",
)

PAPER_MODELS = {
    "mixtral-8x7b": MIXTRAL_8X7B,
    "mixtral-8x22b": MIXTRAL_8X22B,
    "qwen3-30b-a3b": QWEN3_30B_A3B,
    "deepseekmoe-16b": DEEPSEEKMOE_16B,
}

# bytes per weight under the paper's deployment quantization
QUANT_BYTES = {
    "mixtral-8x7b": 0.5,      # AWQ 4-bit
    "mixtral-8x22b": 0.5,     # AWQ 4-bit
    "qwen3-30b-a3b": 1.0,     # FP8
    "deepseekmoe-16b": 2.0,   # full bf16
}


def trace_scale(cfg: ArchConfig) -> ArchConfig:
    """Trace-collection variant: SAME n_layers / n_experts / top_k (routing
    structure is what matters), tiny width so the live engine runs on CPU."""
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-trace",
        d_model=128, head_dim=32, n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads % 2 == 0 else 1,
        d_ff=min(cfg.d_ff, 128) if cfg.d_ff else 0,
        d_expert=64,
        vocab=2048,
        n_shared_experts=min(cfg.n_shared_experts, 1),
    )
