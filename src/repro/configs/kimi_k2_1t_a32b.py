"""Kimi-K2-1T-A32B [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff(expert)=2048
vocab=163840, 384 routed experts top-8 + 1 shared, layer 0 dense (d_ff 18432).

Trillion-param MoE, paper-table scale; extreme sparsity regime for DuoServe.
Assigned GQA kv=8 used as given (real K2 uses MLA; noted in DESIGN.md).
[arXiv:2501.kimi2]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=128,
    n_experts=384, n_shared_experts=1, top_k=8, d_expert=2048,
    n_dense_layers=1, dense_d_ff=18432,
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2",
)
