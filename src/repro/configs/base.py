"""Config system: architecture configs + input shapes.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exporting ``CONFIG: ArchConfig`` with the exact assigned dimensions (source
cited in the module docstring). ``get_config(name)`` resolves by id;
``reduced(cfg)`` produces the CPU-smoke-test variant of the same family
(<=2 layers, d_model<=512, <=4 experts) per the assignment rules.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None       # window for local layers
    local_global_pattern: Optional[int] = None  # e.g. 5 -> 5 local : 1 global
    rms_eps: float = 1e-6

    # MoE options
    n_experts: int = 0           # routed experts (0 => dense FFN)
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0            # per-expert FFN hidden dim
    n_dense_layers: int = 0      # leading dense layers (kimi first_k_dense)
    dense_d_ff: int = 0          # d_ff for those leading dense layers
    router_aux_loss: float = 0.01
    capacity_factor: float = 1.25

    # SSM options (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    hybrid_attn_every: int = 0   # zamba2: shared attn block every N layers

    # enc-dec options
    enc_layers: int = 0          # if >0: encoder-decoder; n_layers = decoder
    cross_attn_every: int = 0    # vlm: cross-attn layer every N layers

    # modality frontend stubs
    frontend: Optional[str] = None   # 'audio' | 'vision'
    n_frontend_tokens: int = 0       # frames / image patches fed to the stub
    frontend_dim: int = 0            # embedding dim delivered by the stub

    # technique applicability (DuoServe expert scheduling)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def duoserve_applicable(self) -> bool:
        """The paper's expert scheduling needs routed experts."""
        return self.is_moe

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode memory: SSM / hybrid / sliding-window archs."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def window_for_layer(self, layer: int) -> int:
        """-1 means full attention; otherwise the sliding window size."""
        if self.sliding_window is None:
            return -1
        if self.local_global_pattern is None:
            return self.sliding_window
        # pattern N: layers 0..N-1 local, layer N global, repeating
        return -1 if (layer % (self.local_global_pattern + 1)
                      == self.local_global_pattern) else self.sliding_window


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "qwen3_1_7b",
    "granite_34b",
    "llama_3_2_vision_90b",
    "seamless_m4t_medium",
    "mamba2_2_7b",
    "qwen1_5_110b",
    "qwen2_moe_a2_7b",
    "zamba2_7b",
    "gemma3_1b",
    "kimi_k2_1t_a32b",
    # paper's own headline model (replica) for §Paper-validation
    "mixtral_8x7b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "qwen3-1.7b": "qwen3_1_7b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
})


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Tuple[ArchConfig, ...]:
    return tuple(get_config(a) for a in ARCH_IDS)


def pairs():
    """All (arch, shape) dry-run pairs, honouring decode-shape applicability."""
    out = []
    for a in ARCH_IDS:
        if a == "mixtral_8x7b":
            continue  # replica is extra, not part of the assigned 10x4 matrix
        cfg = get_config(a)
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            shape = INPUT_SHAPES[s]
            if shape.name == "long_500k" and not cfg.supports_long_decode:
                continue
            out.append((cfg, shape))
    return out


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant: same family/features, tiny dims (CPU-runnable)."""
    hd = 32
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = 1 if cfg.n_kv_heads == 1 else min(cfg.n_kv_heads, n_heads)
    d_model = min(256, cfg.d_model)
    # keep d_model divisible by heads*hd relationships simple
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=min(512, cfg.d_ff) if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(4, cfg.n_experts) if cfg.n_experts else 0,
        n_shared_experts=min(1, cfg.n_shared_experts),
        top_k=min(2, cfg.top_k) if cfg.top_k else 0,
        d_expert=min(128, cfg.d_expert) if cfg.d_expert else 0,
        n_dense_layers=min(1, cfg.n_dense_layers),
        dense_d_ff=min(256, cfg.dense_d_ff) if cfg.dense_d_ff else 0,
        ssm_state=min(16, cfg.ssm_state) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else cfg.ssm_head_dim,
        hybrid_attn_every=2 if cfg.hybrid_attn_every else 0,
        enc_layers=min(2, cfg.enc_layers),
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        sliding_window=min(64, cfg.sliding_window) if cfg.sliding_window else None,
        local_global_pattern=cfg.local_global_pattern,
        n_frontend_tokens=min(16, cfg.n_frontend_tokens),
        frontend_dim=min(64, cfg.frontend_dim) if cfg.frontend_dim else 0,
    )
