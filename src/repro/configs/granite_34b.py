"""Granite-34B-Code [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Llama-style arch, code model, multi-query attention. [arXiv:2405.04324]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    rope_theta=10_000.0,
    source="arXiv:2405.04324",
)
