"""Mamba2-2.7B [ssm] — 64L d_model=2560, attn-free, vocab=50280, ssm_state=128.

SSD (state-space duality), expand=2 -> d_inner=5120, headdim=64 -> 80 heads,
conv4, ngroups=1. [arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_groups=1,
    source="arXiv:2405.21060",
)
