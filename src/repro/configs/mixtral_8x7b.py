"""Mixtral-8x7B replica [moe] — the paper's own headline model, for
§Paper-validation. 32L d_model=4096 32H (GQA kv=8) 8 experts top-2,
expert d_ff=14336, vocab=32000. [arXiv:2401.04088]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    n_experts=8, n_shared_experts=0, top_k=2, d_expert=14336,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)
