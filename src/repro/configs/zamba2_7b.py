"""Zamba2-7B [hybrid] — 81L d_model=3584 32H (MHA kv=32) d_ff=14336 vocab=32000,
ssm_state=64.

Mamba2 backbone + one weight-SHARED attention+MLP block applied every 6th
layer (simplified from Zamba2's dual shared blocks + concat residual; dims
preserved). [arXiv:2411.15242]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    hybrid_attn_every=6, sliding_window=4096,
    source="arXiv:2411.15242",
)
