"""SeamlessM4T-medium [audio] — 12L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206.

Encoder-decoder: 12 encoder + 12 decoder layers (text-to-unit stack of the
medium card). The speech frontend (mel-spectrogram + conv feature extractor)
is a STUB: input_specs() supplies (B, frames, 1024) frame embeddings.
[arXiv:2308.11596]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    enc_layers=12,
    frontend="audio", n_frontend_tokens=0, frontend_dim=1024,
    source="arXiv:2308.11596",
)
