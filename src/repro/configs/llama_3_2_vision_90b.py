"""Llama-3.2-Vision-90B [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

100 layers = 80 self-attn + 20 gated cross-attn (every 5th attends to image
patch embeddings). Vision tower (ViT) is a STUB per the assignment carve-out:
input_specs() supplies projected patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    frontend="vision", n_frontend_tokens=1601, frontend_dim=1280,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
