"""Gemma3-1B [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5 local : 1 global attention pattern, sliding window 512, 128k-class context.
Runs long_500k via the sliding-window local layers. [hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab=262144, head_dim=256,
    qk_norm=True, rope_theta=1_000_000.0,
    sliding_window=512, local_global_pattern=5,
    source="hf:google/gemma-3-1b-pt",
)
