"""Qwen2-MoE-A2.7B [moe] — 24L d_model=2048 16H (MHA kv=16) d_ff(expert)=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts.

Primary DuoServe-MoE target arch (large pool, top-4). [hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, head_dim=128,
    n_experts=60, n_shared_experts=4, top_k=4, d_expert=1408,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
