"""State Constructor (paper §IV-B / §V-C): builds the predictor input s_l.

Paper Eq. 5: s_l = [h_l, p_l, a_{l-1,l}] — cumulative activation history,
layer-l popularity, and the affinity rows of the experts selected at l-1.
Following the paper's simplification ("we abstracted the combination of
multiple experts per layer into a single expert's influence"), the k selected
rows of A_{l-1,l} are aggregated (mean) into one E-vector instead of flattening
the full ExE matrix — this keeps the input size O(E) for 384-expert pools.

Feature layout (dim = (hist_window + 3) * E + 8):
  [ multi-hot of last `hist_window` layers' selections  (hist_window * E)
  | cumulative multi-hot over all previous layers        (E)
  | popularity p_l                                       (E)
  | aggregated affinity rows a_{l-1 -> l}                (E)
  | sinusoidal embedding of the target layer index       (8) ]
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.tracer import TraceStats

LAYER_EMB = 8


def _layer_embedding(l: int, n_layers: int) -> np.ndarray:
    t = l / max(n_layers - 1, 1)
    freqs = 2.0 ** np.arange(LAYER_EMB // 2)
    return np.concatenate([np.sin(np.pi * t * freqs),
                           np.cos(np.pi * t * freqs)]).astype(np.float32)


class StateConstructor:
    def __init__(self, stats: TraceStats, hist_window: int = 4):
        self.stats = stats
        self.hist = hist_window
        self.E = stats.n_experts
        self.L = stats.n_layers

    @property
    def feature_dim(self) -> int:
        return (self.hist + 3) * self.E + LAYER_EMB

    def features(self, prefix: Sequence[np.ndarray], layer: int) -> np.ndarray:
        """prefix: expert-id arrays for layers [0 .. layer-1]; predicts `layer`."""
        E = self.E
        hot = np.zeros((self.hist, E), np.float32)
        for i, sel in enumerate(prefix[-self.hist:][::-1]):
            hot[i, np.asarray(sel, np.int32)] = 1.0
        cum = np.zeros(E, np.float32)
        for sel in prefix:
            cum[np.asarray(sel, np.int32)] = 1.0
        pop = self.stats.popularity[layer]
        if layer >= 1 and len(prefix) >= 1 and self.stats.affinity.shape[0]:
            rows = self.stats.affinity[layer - 1][np.asarray(prefix[-1], np.int32)]
            aff = rows.mean(axis=0)
        else:
            aff = np.zeros(E, np.float32)
        return np.concatenate([hot.ravel(), cum, pop, aff,
                               _layer_embedding(layer, self.L)]).astype(np.float32)

    def build_dataset(self, paths: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """paths: [N, L, k] -> (X [M, D], Y [M, E]) for layers 1..L-1."""
        xs, ys = [], []
        for path in np.asarray(paths):
            prefix: List[np.ndarray] = []
            for l in range(path.shape[0]):
                if l >= 1:
                    xs.append(self.features(prefix, l))
                    y = np.zeros(self.E, np.float32)
                    y[path[l]] = 1.0
                    ys.append(y)
                prefix.append(path[l])
        return np.stack(xs), np.stack(ys)
