"""Expert weight stores and device-side expert caches.

HostExpertStore — the "CPU expert cache" of the paper: all routed-expert
weights live in host RAM (numpy). DeviceExpertCache — the "GPU expert cache":
a small set of device-resident slots per layer (DuoServe sizes it to top-k),
filled by `prefetch` (jax.device_put → host->HBM DMA; asynchronously
dispatched, so issuing a prefetch then dispatching compute overlaps them the
way the paper's two CUDA streams do).

Both the serving engine and the discrete-event simulator share the same
residency/eviction logic via CacheState, so simulated peak memory and hit
rates reflect exactly what the engine would do.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Set, Tuple

import jax
import numpy as np

ExpertKey = Tuple[int, int]  # (layer, expert)


class HostExpertStore:
    """Host-RAM store of per-expert FFN weights (w1, w3, w2)."""

    def __init__(self, weights: Dict[ExpertKey, Tuple[np.ndarray, ...]]):
        self.weights = weights
        any_w = next(iter(weights.values()))
        self.bytes_per_expert = sum(a.nbytes for a in any_w)

    @staticmethod
    def from_params(layer_moe_params, n_layers: int, n_experts: int
                    ) -> "HostExpertStore":
        """layer_moe_params: stacked MoE params {'w1': [L,E,d,de], ...}."""
        w = {}
        for l in range(n_layers):
            for e in range(n_experts):
                w[(l, e)] = (np.asarray(layer_moe_params["w1"][l, e]),
                             np.asarray(layer_moe_params["w3"][l, e]),
                             np.asarray(layer_moe_params["w2"][l, e]))
        return HostExpertStore(w)

    def get(self, key: ExpertKey):
        return self.weights[key]


@dataclasses.dataclass
class CacheEvent:
    kind: str            # 'fetch' | 'hit' | 'evict'
    key: ExpertKey
    t_issue: float       # host wall-clock when issued (engine) / sim time
    bytes: int = 0


class CacheState:
    """Residency bookkeeping shared by engine + simulator.

    capacity: max resident experts (global across layers). Eviction is LRU
    among non-pinned entries; `pin`/`unpin` protect experts between prefetch
    and use (the paper's sync-point semantics).

    Invariant (tests/test_property.py): residency exceeds capacity ONLY
    while every resident entry is pinned — pinned must-have admissions may
    grow an all-pinned cache, speculative (unpinned) ones are declined, and
    unpinning shrinks an over-grown cache back to capacity.
    """

    def __init__(self, capacity: int, bytes_per_expert: int):
        self.capacity = capacity
        self.bytes_per_expert = bytes_per_expert
        self.resident: "collections.OrderedDict[ExpertKey, bool]" = \
            collections.OrderedDict()  # key -> pinned
        self.events: List[CacheEvent] = []
        self.peak_resident = 0
        self.hits = 0
        self.misses = 0

    def contains(self, key: ExpertKey) -> bool:
        return key in self.resident

    def touch(self, key: ExpertKey) -> None:
        self.resident.move_to_end(key)

    def lookup(self, key: ExpertKey, t: float = 0.0) -> bool:
        if key in self.resident:
            self.hits += 1
            self.touch(key)
            self.events.append(CacheEvent("hit", key, t))
            return True
        self.misses += 1
        return False

    def admit(self, key: ExpertKey, t: float = 0.0, pinned: bool = True
              ) -> List[ExpertKey]:
        """Admit key, evicting LRU unpinned entries if needed.

        Invariant: residency exceeds capacity ONLY while every resident
        entry is pinned. A pinned (must-have) admission into an all-pinned
        full cache grows it — correctness requires the weights resident
        (the engine should never reach this). An unpinned (speculative)
        admission in the same situation is DECLINED instead: growing past
        capacity for a prefetch that itself would be the next victim is
        never worth it. Declined keys stay non-resident and record no fetch
        event; callers check `contains` after admit. Returns evicted keys.
        """
        evicted = []
        if key in self.resident:
            self.resident[key] = pinned or self.resident[key]
            self.touch(key)
            return evicted
        while len(self.resident) >= self.capacity:
            victim = None
            for k, pin in self.resident.items():
                if not pin:
                    victim = k
                    break
            if victim is None:  # everything pinned
                if not pinned:
                    return evicted  # decline the speculative admission
                break               # grow (engine never should)
            del self.resident[victim]
            self.events.append(CacheEvent("evict", victim, t))
            evicted.append(victim)
        self.resident[key] = pinned
        self.events.append(
            CacheEvent("fetch", key, t, self.bytes_per_expert))
        self.peak_resident = max(self.peak_resident, len(self.resident))
        return evicted

    def unpin(self, key: ExpertKey, t: float = 0.0) -> List[ExpertKey]:
        """Unpin `key`; if the cache had grown past capacity while all
        entries were pinned, shrink back now that a victim exists.
        Returns keys evicted by the shrink."""
        if key in self.resident:
            self.resident[key] = False
            return self._shrink(t)
        return []

    def unpin_all(self, t: float = 0.0) -> List[ExpertKey]:
        for k in self.resident:
            self.resident[k] = False
        return self._shrink(t)

    def _shrink(self, t: float = 0.0) -> List[ExpertKey]:
        evicted = []
        while len(self.resident) > self.capacity:
            victim = None
            for k, pin in self.resident.items():
                if not pin:
                    victim = k
                    break
            if victim is None:
                break
            del self.resident[victim]
            self.events.append(CacheEvent("evict", victim, t))
            evicted.append(victim)
        return evicted

    @property
    def peak_bytes(self) -> int:
        return self.peak_resident * self.bytes_per_expert

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


class DeviceExpertCache:
    """Real device-side cache backed by CacheState bookkeeping.

    prefetch() issues jax.device_put (async dispatch — returns immediately;
    the transfer overlaps subsequently dispatched compute, the TPU analogue
    of the paper's communication stream).
    """

    def __init__(self, store: HostExpertStore, capacity: int):
        self.store = store
        self.state = CacheState(capacity, store.bytes_per_expert)
        self._dev: Dict[ExpertKey, Tuple[jax.Array, ...]] = {}
        self.transfer_log: List[Tuple[ExpertKey, float]] = []

    def prefetch(self, key: ExpertKey, pinned: bool = True) -> bool:
        """Returns True on hit (already resident)."""
        t = time.perf_counter()
        if self.state.lookup(key, t):
            return True
        for victim in self.state.admit(key, t, pinned):
            self._dev.pop(victim, None)
        if not self.state.contains(key):
            return False  # speculative admit declined: nothing transferred
        host = self.store.get(key)
        self._dev[key] = tuple(jax.device_put(a) for a in host)
        self.transfer_log.append((key, t))
        return False

    def get(self, key: ExpertKey) -> Tuple[jax.Array, ...]:
        if key not in self._dev:  # miss on use = correction fetch (sync point)
            self.prefetch(key)
        self.state.touch(key)
        return self._dev[key]

    def wait(self, key: ExpertKey) -> None:
        """Sync point: block until the expert's weights are on device."""
        for a in self._dev[key]:
            a.block_until_ready()
