"""Unified expert-residency subsystem: one ledger, fixed slot-pool buffers.

HostExpertStore — the "CPU expert cache" of the paper: all routed-expert
weights live in host RAM (numpy). ExpertResidency — the "GPU expert cache":
ONE CacheState ledger (shared by reference with the scheduling policy, see
core/scheduler.py `make_scheduler(state=...)`) fused with preallocated
slot-pool device buffers — stacked ``[pool_capacity, d, de]`` arrays for
w1/w3/w2 allocated once at engine construction. Every ledger decision is
applied symmetrically to device memory through the `_on_admit`/`_on_evict`
hooks: admission allocates a pool slot, eviction (LRU, shrink-on-unpin, or
ODF's free-after-forward `drop`) frees it. Expert HBM is therefore provably
``pool_capacity * bytes_per_expert`` — a fixed bound, not a high-water mark
of an ever-growing dict.

Transfers keep the paper's two-stream overlap: the ledger admits at *plan*
time (scheduler), but the host->device copy is issued at *dispatch* time by
the engine (`prefetch`): ``jax.device_put`` per slab feeding a
donated-buffer ``.at[slot].set`` so the write is in place (no allocator
churn) and, under JAX async dispatch, overlaps subsequently dispatched
compute the way the paper's communication stream does. Compute reads
weights by slot index straight out of the pools (see EngineCore._jit_fns).

Both the serving engine and the discrete-event simulator drive the same
CacheState logic (the simulator with a plain ledger-only CacheState), so
simulated peak memory and hit rates reflect exactly what the engine does.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Dict, Iterable, List, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ExpertKey = Tuple[int, int]  # (layer, expert)


class HostExpertStore:
    """Host-RAM store of per-expert FFN weights (w1, w3, w2)."""

    def __init__(self, weights: Dict[ExpertKey, Tuple[np.ndarray, ...]]):
        self.weights = weights
        any_w = next(iter(weights.values()))
        self.bytes_per_expert = sum(a.nbytes for a in any_w)

    @staticmethod
    def from_params(layer_moe_params, n_layers: int, n_experts: int
                    ) -> "HostExpertStore":
        """layer_moe_params: stacked MoE params {'w1': [L,E,d,de], ...}."""
        w = {}
        for l in range(n_layers):
            for e in range(n_experts):
                w[(l, e)] = (np.asarray(layer_moe_params["w1"][l, e]),
                             np.asarray(layer_moe_params["w3"][l, e]),
                             np.asarray(layer_moe_params["w2"][l, e]))
        return HostExpertStore(w)

    def get(self, key: ExpertKey):
        return self.weights[key]


@dataclasses.dataclass
class CacheEvent:
    kind: str            # 'fetch' | 'hit' | 'evict'
    key: ExpertKey
    t_issue: float       # host wall-clock when issued (engine) / sim time
    bytes: int = 0


class CacheState:
    """Residency bookkeeping shared by engine + simulator.

    capacity: max resident experts (global across layers). Eviction is LRU
    among non-pinned entries; `pin`/`unpin` protect experts between prefetch
    and use (the paper's sync-point semantics).

    Every residency mutation funnels through `_on_admit`/`_on_evict` hooks
    (no-ops here) so a subclass can mirror the ledger into device memory —
    ExpertResidency maps admissions to pool-slot allocations and evictions
    to slot frees, keeping ledger and device buffers one mechanism.

    Invariant (tests/test_property.py): residency exceeds capacity ONLY
    while every resident entry is pinned — pinned must-have admissions may
    grow an all-pinned cache, speculative (unpinned) ones are declined, and
    unpinning shrinks an over-grown cache back to capacity.
    """

    def __init__(self, capacity: int, bytes_per_expert: int):
        self.capacity = capacity
        self.bytes_per_expert = bytes_per_expert
        self.resident: "collections.OrderedDict[ExpertKey, bool]" = \
            collections.OrderedDict()  # key -> pinned
        self.events: List[CacheEvent] = []
        self.peak_resident = 0
        self.hits = 0
        self.misses = 0

    # -- device-mirror hooks (overridden by ExpertResidency) -----------------
    def _on_admit(self, key: ExpertKey) -> None:
        """Called exactly once when `key` newly becomes resident."""

    def _on_evict(self, key: ExpertKey) -> None:
        """Called exactly once when `key` leaves residency (any path)."""

    def contains(self, key: ExpertKey) -> bool:
        return key in self.resident

    def touch(self, key: ExpertKey) -> None:
        self.resident.move_to_end(key)

    def residency_overlap(self, keys: Iterable[ExpertKey]) -> int:
        """How many of `keys` are resident right now. A read-only scoring
        probe — no LRU touch, no hit/miss accounting, no events — so the
        cluster router's expert-affinity policy can rank replicas by live
        cache overlap without perturbing the replayable event stream."""
        resident = self.resident
        return sum(1 for k in keys if k in resident)

    def lookup(self, key: ExpertKey, t: float = 0.0) -> bool:
        if key in self.resident:
            self.hits += 1
            self.touch(key)
            self.events.append(CacheEvent("hit", key, t))
            return True
        self.misses += 1
        return False

    def admit(self, key: ExpertKey, t: float = 0.0, pinned: bool = True
              ) -> List[ExpertKey]:
        """Admit key, evicting LRU unpinned entries if needed.

        Invariant: residency exceeds capacity ONLY while every resident
        entry is pinned. A pinned (must-have) admission into an all-pinned
        full cache grows it — correctness requires the weights resident
        (engines size their residency so this never fires; ExpertResidency
        regrows its pool if it does). An unpinned (speculative) admission in
        the same situation is DECLINED instead: growing past capacity for a
        prefetch that itself would be the next victim is never worth it.
        Declined keys stay non-resident and record no fetch event; callers
        check `contains` after admit. Returns evicted keys.
        """
        evicted = []
        if key in self.resident:
            self.resident[key] = pinned or self.resident[key]
            self.touch(key)
            return evicted
        while len(self.resident) >= self.capacity:
            victim = None
            for k, pin in self.resident.items():
                if not pin:
                    victim = k
                    break
            if victim is None:  # everything pinned
                if not pinned:
                    return evicted  # decline the speculative admission
                break               # grow (sized engines never reach this)
            del self.resident[victim]
            self._on_evict(victim)
            self.events.append(CacheEvent("evict", victim, t))
            evicted.append(victim)
        self.resident[key] = pinned
        self._on_admit(key)
        self.events.append(
            CacheEvent("fetch", key, t, self.bytes_per_expert))
        self.peak_resident = max(self.peak_resident, len(self.resident))
        return evicted

    def drop(self, key: ExpertKey, t: float = 0.0) -> bool:
        """Remove `key` from residency without an evict event: the ODF
        free-after-forward semantics (HF-Accelerate releases offloaded
        module weights right after the module runs — not a capacity
        eviction). The device mirror still frees the slot."""
        if key in self.resident:
            del self.resident[key]
            self._on_evict(key)
            return True
        return False

    def unpin(self, key: ExpertKey, t: float = 0.0) -> List[ExpertKey]:
        """Unpin `key`; if the cache had grown past capacity while all
        entries were pinned, shrink back now that a victim exists.
        Returns keys evicted by the shrink."""
        if key in self.resident:
            self.resident[key] = False
            return self._shrink(t)
        return []

    def unpin_all(self, t: float = 0.0) -> List[ExpertKey]:
        for k in self.resident:
            self.resident[k] = False
        return self._shrink(t)

    def _shrink(self, t: float = 0.0) -> List[ExpertKey]:
        evicted = []
        while len(self.resident) > self.capacity:
            victim = None
            for k, pin in self.resident.items():
                if not pin:
                    victim = k
                    break
            if victim is None:
                break
            del self.resident[victim]
            self._on_evict(victim)
            self.events.append(CacheEvent("evict", victim, t))
            evicted.append(victim)
        return evicted

    def rescale(self, new_capacity: int) -> None:
        """Raise the residency bound (batch-size change, policy swap).
        Grow-only: shrinking would need an eviction sweep no caller wants
        implicitly; ExpertResidency also regrows its device pools here."""
        assert new_capacity >= self.capacity, \
            f"rescale is grow-only ({self.capacity} -> {new_capacity})"
        self.capacity = new_capacity

    @property
    def peak_bytes(self) -> int:
        return self.peak_resident * self.bytes_per_expert

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


@functools.partial(jax.jit, donate_argnums=(0,))
def _pool_write(pool: jax.Array, slot: jax.Array, slab: jax.Array
                ) -> jax.Array:
    """In-place (donated) host->device write of one expert slab into its
    pool slot. Donation makes the at-set reuse the pool's buffer — no
    allocator churn per fetch — while async dispatch lets the copy overlap
    compute dispatched afterwards."""
    return pool.at[slot].set(slab)


class ExpertResidency(CacheState):
    """THE ledger fused with the device expert buffers (one mechanism).

    The scheduler shares this object by reference (`sched.cache is
    engine.cache`) and performs all plan-time ledger ops on it; the
    `_on_admit`/`_on_evict` overrides mirror every decision into a fixed
    slot pool:

      * pools: stacked ``w1/w3: [pool_capacity, d, de]``,
        ``w2: [pool_capacity, de, d]`` device arrays allocated ONCE.
      * slot_of: ExpertKey -> pool slot for every resident expert
        (invariant: ``set(slot_of) == set(resident)`` at all times).
      * admission pops a free slot; eviction pushes it back — O(1), no
        device allocation on the steady-state path.

    Transfer issuance is decoupled from admission so the engine keeps the
    paper's overlap structure: `prefetch(key)` performs the actual
    host->device copy for an already-admitted key at the point the engine
    dispatches it (between compute dispatches); `slot(key)` is the
    use-time sync point — it issues any still-pending copy and returns the
    slot index for the jitted slot-indexed expert kernels.

    If a must-have admission grows an all-pinned ledger past the pool (the
    engines size `capacity` so this never happens — asserted in
    tests/test_residency.py), the pool regrows rather than corrupting a
    live slot; `regrow_events` counts those.
    """

    def __init__(self, store: HostExpertStore, capacity: int):
        super().__init__(capacity, store.bytes_per_expert)
        self.store = store
        w1, w3, w2 = next(iter(store.weights.values()))
        self.pool_capacity = capacity
        self._pools: Dict[str, jax.Array] = {
            "w1": jnp.zeros((capacity,) + w1.shape, w1.dtype),
            "w3": jnp.zeros((capacity,) + w3.shape, w3.dtype),
            "w2": jnp.zeros((capacity,) + w2.shape, w2.dtype),
        }
        self.slot_of: Dict[ExpertKey, int] = {}
        self._free: List[int] = list(range(capacity))[::-1]
        self._loaded: Set[ExpertKey] = set()
        self.transfer_log: List[Tuple[ExpertKey, float]] = []
        self.regrow_events = 0

    # -- ledger -> device mirroring -----------------------------------------
    def _on_admit(self, key: ExpertKey) -> None:
        if not self._free:
            # all-pinned ledger growth: never corrupt a live slot
            self._regrow(self.pool_capacity + max(1, self.pool_capacity // 2))
        self.slot_of[key] = self._free.pop()

    def _on_evict(self, key: ExpertKey) -> None:
        slot = self.slot_of.pop(key, None)
        if slot is not None:
            self._free.append(slot)
            self._loaded.discard(key)

    def _regrow(self, new_pool_capacity: int) -> None:
        grown = new_pool_capacity - self.pool_capacity
        for name, pool in self._pools.items():
            pad = jnp.zeros((grown,) + pool.shape[1:], pool.dtype)
            self._pools[name] = jnp.concatenate([pool, pad], axis=0)
        self._free.extend(range(self.pool_capacity, new_pool_capacity))
        self.pool_capacity = new_pool_capacity
        self.regrow_events += 1

    def rescale(self, new_capacity: int) -> None:
        super().rescale(new_capacity)
        if new_capacity > self.pool_capacity:
            self._regrow(new_capacity)
            self.regrow_events -= 1  # provisioning, not an overflow event

    # -- device transfers ----------------------------------------------------
    def prefetch(self, key: ExpertKey) -> bool:
        """Issue the host->device copy for an already-admitted key (async
        dispatch: returns immediately, the DMA overlaps compute dispatched
        after it — the TPU analogue of the paper's communication stream).
        Returns True if the key was already loaded; no-op (False) for keys
        the ledger declined (speculative admit into an all-pinned cache)."""
        slot = self.slot_of.get(key)
        if slot is None:
            return False
        if key in self._loaded:
            return True
        w1, w3, w2 = self.store.get(key)
        s = jnp.int32(slot)
        self._pools["w1"] = _pool_write(self._pools["w1"], s,
                                        jax.device_put(w1))
        self._pools["w3"] = _pool_write(self._pools["w3"], s,
                                        jax.device_put(w3))
        self._pools["w2"] = _pool_write(self._pools["w2"], s,
                                        jax.device_put(w2))
        self._loaded.add(key)
        self.transfer_log.append((key, time.perf_counter()))
        return False

    def slot(self, key: ExpertKey) -> int:
        """Use-time access: slot index of a resident key, issuing its copy
        if still pending. A non-resident key is a scheduler/engine bug; the
        correction admit below records honest ledger events, so the
        engine-vs-simulator parity tests surface it loudly instead of a
        silent re-fetch masking it."""
        if key not in self.slot_of:
            self.admit(key, time.perf_counter(), pinned=True)
        self.prefetch(key)
        return self.slot_of[key]

    @property
    def pools(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Current (w1, w3, w2) slot-pool arrays. Re-read after any
        prefetch/slot call: each write produces a fresh array object
        (donation reuses the buffer underneath)."""
        return self._pools["w1"], self._pools["w3"], self._pools["w2"]

    def get(self, key: ExpertKey) -> Tuple[jax.Array, ...]:
        """Slot-sliced (w1, w3, w2) views for one expert (compat/testing
        path; the engines pass pools + slot index into jitted kernels)."""
        s = self.slot(key)
        return tuple(self._pools[n][s] for n in ("w1", "w3", "w2"))

    def wait(self, key: ExpertKey) -> None:
        """Sync point: block until the expert's weights are on device."""
        self.slot(key)
        for p in self._pools.values():
            p.block_until_ready()

    @property
    def device_bytes(self) -> int:
        """Actual expert HBM footprint — the fixed pool allocation."""
        return sum(p.nbytes for p in self._pools.values())

    @property
    def hbm_bound_ok(self) -> bool:
        """THE expert-HBM bound predicate (one definition for tests,
        benches, and examples): device bytes equal the fixed
        ``capacity * bytes_per_expert`` allocation and the pool never
        regrew past the capacity it was sized with."""
        return (self.device_bytes
                == self.pool_capacity * self.bytes_per_expert
                and self.regrow_events == 0
                and self.pool_capacity == self.capacity)
