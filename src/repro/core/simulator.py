"""Discrete-event two/three-stream latency simulator (paper Figs. 4–7).

The container is CPU-only, so wall-clock overlap cannot be measured; instead
the engine's *real* routing traces and the predictor's *real* hit/miss
outcomes are replayed through an event simulator with explicit streams:

  compute stream  — attention/non-MoE + per-expert FFN ops
  comm stream     — host->device expert weight transfers (serialized, like a
                    single DMA/PCIe channel driven by one CUDA stream)
  pred stream     — the ExpertMLP inference (paper: ~0.6 ms, overlapped)

Stream semantics mirror CUDA streams: ops on one stream execute FIFO; an op
starts at max(stream-free time, all dependency completion times). Sync points
are modelled as dependencies. Op durations come from a roofline cost model
(max of compute-bound and memory-bound time) with the hardware constants in
``HW`` — defaults describe the paper's edge-server class device; the TPU-v5e
constants used for §Roofline are provided by ``HW.tpu_v5e()``.

Policies are the *same objects* the live engine uses (core/scheduler.py), so
simulated hit rates, fetch orders, and peak residency are exactly the
engine's. The engine drives the SAME single CacheState ledger that backs its
device slot pools (core/cache.ExpertResidency shared into the scheduler via
``make_scheduler(state=...)``); a replay here constructs a plain ledger-only
CacheState with the engine's capacity and reproduces the identical
hit/miss/evict event sequence (tests/test_cache_parity.py) — simulated peak
residency IS the engine's device footprint, not an estimate of it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.scheduler import BaseScheduler, DuoServeScheduler


# ---------------------------------------------------------------------------
# hardware + cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HW:
    """Hardware constants. Defaults: paper-class edge GPU (A5000-ish)."""
    name: str = "edge-gpu-24g"
    flops: float = 27.8e12          # bf16/fp16 dense TFLOP/s
    hbm_bw: float = 768e9           # device memory bandwidth B/s
    host_bw: float = 25.6e9         # host->device link (PCIe 4.0 x16)
    host_lat: float = 20e-6         # per-transfer fixed latency
    kernel_lat: float = 8e-6        # per-op launch overhead
    pred_lat: float = 0.6e-3        # ExpertMLP latency (paper §VI-D)
    mem_budget: float = 24e9

    @staticmethod
    def tpu_v5e() -> "HW":
        return HW(name="tpu-v5e", flops=197e12, hbm_bw=819e9, host_bw=32e9,
                  host_lat=15e-6, kernel_lat=5e-6, pred_lat=0.2e-3,
                  mem_budget=16e9)


@dataclasses.dataclass(frozen=True)
class ModelCosts:
    """Per-op FLOPs/bytes derived from an ArchConfig."""
    cfg: ArchConfig
    quant_bytes: float = 2.0  # bytes per weight (bf16 default; 0.5 = 4-bit)

    @property
    def d(self):
        return self.cfg.d_model

    @property
    def expert_bytes(self) -> float:
        return 3 * self.d * self.cfg.d_expert * self.quant_bytes

    @property
    def nonmoe_bytes_per_layer(self) -> float:
        cfg = self.cfg
        attn = (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) * cfg.hd * self.d
        shared = 3 * self.d * cfg.n_shared_experts * cfg.d_expert
        gate = self.d * cfg.n_experts
        return (attn + shared + gate) * self.quant_bytes

    def nonmoe_flops(self, tokens: int, kv_len: int) -> float:
        cfg = self.cfg
        proj = 2 * tokens * (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) \
            * cfg.hd * self.d
        attn = 4 * tokens * kv_len * cfg.n_heads * cfg.hd
        shared = 2 * tokens * 3 * self.d * cfg.n_shared_experts * cfg.d_expert
        gate = 2 * tokens * self.d * cfg.n_experts
        return proj + attn + shared + gate

    def expert_flops(self, tokens: int) -> float:
        return 2 * tokens * 3 * self.d * self.cfg.d_expert

    def kv_bytes(self, kv_len: int, batch: int = 1) -> float:
        return 2 * kv_len * batch * self.cfg.n_kv_heads * self.cfg.hd * 2

    def nonexpert_resident_bytes(self) -> float:
        cfg = self.cfg
        emb = cfg.vocab * self.d * self.quant_bytes
        return emb + cfg.n_layers * self.nonmoe_bytes_per_layer


# ---------------------------------------------------------------------------
# stream simulator
# ---------------------------------------------------------------------------


class StreamSim:
    def __init__(self, streams=("comp", "comm", "pred")):
        self.free = {s: 0.0 for s in streams}
        self.log: List[Tuple[str, str, float, float]] = []

    def issue(self, stream: str, dur: float, deps: Sequence[float] = (),
              label: str = "") -> float:
        start = max([self.free[stream], *deps]) if deps else self.free[stream]
        end = start + dur
        self.free[stream] = end
        self.log.append((stream, label, start, end))
        return end

    @property
    def now(self) -> float:
        return max(self.free.values())


def _op_time(flops: float, bytes_: float, hw: HW) -> float:
    return max(flops / hw.flops, bytes_ / hw.hbm_bw) + hw.kernel_lat


def _xfer_time(bytes_: float, hw: HW) -> float:
    return bytes_ / hw.host_bw + hw.host_lat


# ---------------------------------------------------------------------------
# request replay
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    ttft: float
    step_latencies: np.ndarray   # decode per-step
    e2e: float
    peak_bytes: float
    hit_rate: float
    policy: str


def simulate_prefill(sched: BaseScheduler, costs: ModelCosts, hw: HW,
                     prefill_active: Sequence[Sequence[int]],
                     seq_len: int, batch: int = 1,
                     sim: Optional[StreamSim] = None) -> float:
    """Replays prefill through the policy. prefill_active[l] = union of
    experts activated at layer l. Returns TTFT (time of first token)."""
    sim = sim or StreamSim()
    cfg = costs.cfg
    tokens = seq_len * batch
    done = 0.0  # completion of previous layer
    for l in range(cfg.n_layers):
        plan = sched.prefill_plan(l, prefill_active[l])
        t_attn = _op_time(costs.nonmoe_flops(tokens, seq_len),
                          costs.nonmoe_bytes_per_layer
                          + tokens * costs.d * 4, hw)
        attn_end = sim.issue("comp", t_attn, [done], f"L{l}.attn")
        gate_end = attn_end

        need = set(plan.fetches)
        t_fx = _xfer_time(costs.expert_bytes, hw)
        n_active = max(len(plan.order), 1)
        tok_per_e = max(tokens * cfg.top_k // n_active, 1)
        t_ex = _op_time(costs.expert_flops(tok_per_e),
                        costs.expert_bytes + tok_per_e * costs.d * 4, hw)

        fetch_end: Dict[int, float] = {}
        if plan.prefetch_all_first or plan.overlap_first:
            # transfers may start as soon as the previous layer's experts
            # freed their slots (issue at layer start, overlapping attn)
            issue_dep = [done]
        else:
            issue_dep = [gate_end]

        if plan.pipelined:
            # DuoServe two-stream pipeline: fetch_0 overlaps attn; fetch_{i+1}
            # waits for its slot (compute_{i-1} done) — cache holds 2.
            comp_end = {}
            for i, e in enumerate(plan.order):
                deps = list(issue_dep) if i == 0 else [fetch_end[plan.order[i - 1]]]
                if i >= 2:
                    deps.append(comp_end[plan.order[i - 2]])  # slot free
                if e in need:
                    fetch_end[e] = sim.issue("comm", t_fx, deps, f"L{l}.fx{e}")
                else:
                    fetch_end[e] = max([sim.free["comm"], *deps])
                cdeps = [fetch_end[e], gate_end]
                if i > 0:
                    cdeps.append(comp_end[plan.order[i - 1]])
                comp_end[e] = sim.issue("comp", t_ex, cdeps, f"L{l}.ex{e}")
            done = comp_end[plan.order[-1]] if plan.order else gate_end
        else:
            last_fx = issue_dep[0]
            for e in plan.order:
                if e in need:
                    dep = [last_fx] if plan.prefetch_all_first else \
                        [max(last_fx, gate_end)]
                    if not plan.prefetch_all_first and not plan.overlap_first:
                        # strict on-demand: fetch issued only when reached
                        dep = [max(last_fx, sim.free["comp"])]
                    fetch_end[e] = sim.issue("comm", t_fx, dep, f"L{l}.fx{e}")
                    last_fx = fetch_end[e]
                else:
                    fetch_end[e] = 0.0
            barrier = max([gate_end] + [fetch_end[e] for e in plan.order]) \
                if plan.prefetch_all_first else None
            cend = gate_end
            for e in plan.order:
                deps = [barrier] if barrier is not None else \
                    [max(fetch_end[e], cend)]
                cend = sim.issue("comp", t_ex, deps, f"L{l}.ex{e}")
            done = cend
        sched.end_layer(l)
    # final norm + logits
    t_head = _op_time(2 * tokens * costs.d * cfg.vocab,
                      cfg.vocab * costs.d * costs.quant_bytes, hw)
    return sim.issue("comp", t_head, [done], "head")


def simulate_decode(sched: BaseScheduler, costs: ModelCosts, hw: HW,
                    decode_trace: np.ndarray, kv_len: int, batch: int = 1,
                    sim: Optional[StreamSim] = None,
                    t0: float = 0.0) -> np.ndarray:
    """decode_trace: [T, L, k] selected experts per step/layer. Replays the
    policy; DuoServe's predictions come from the scheduler itself (it holds
    the trained predictor). Returns per-step completion latencies."""
    sim = sim or StreamSim()
    cfg = costs.cfg
    T = decode_trace.shape[0]
    lat = np.zeros(T)
    done = t0
    t_fx = _xfer_time(costs.expert_bytes, hw)
    for t in range(T):
        step_start = done
        if isinstance(sched, DuoServeScheduler):
            sched.begin_decode_step()
        for l in range(cfg.n_layers):
            t_attn = _op_time(costs.nonmoe_flops(batch, kv_len + t),
                              costs.nonmoe_bytes_per_layer
                              + costs.kv_bytes(kv_len + t, batch), hw)
            attn_end = sim.issue("comp", t_attn, [done], f"t{t}L{l}.attn")
            plan = sched.decode_plan(l, decode_trace[t, l])
            t_ex = _op_time(costs.expert_flops(batch),
                            costs.expert_bytes + batch * costs.d * 4, hw)
            # blocking correction fetches (misses) serialize before compute
            miss_end = attn_end
            for e in plan.misses:
                miss_end = sim.issue("comm", t_fx, [miss_end],
                                     f"t{t}L{l}.miss{e}")
            cend = max(attn_end, miss_end)
            for e in plan.hits + plan.misses:
                cend = sim.issue("comp", t_ex, [cend], f"t{t}L{l}.ex{e}")
            # async next-layer prefetch + predictor overlap expert compute
            if plan.prefetch_next:
                pdep = [attn_end]
                if isinstance(sched, DuoServeScheduler) and sched.uses_predictor:
                    pend = sim.issue("pred", hw.pred_lat, [attn_end],
                                     f"t{t}L{l}.pred")
                    pdep = [pend]
                for e in plan.prefetch_next:
                    sim.issue("comm", t_fx, pdep, f"t{t}L{l}.pf{e}")
            done = cend
        # mirror the engines: the last layer has no successor plan to
        # end_layer it, so unpin it at step end (ledger parity)
        sched.end_layer(cfg.n_layers - 1)
        t_head = _op_time(2 * batch * costs.d * cfg.vocab,
                          cfg.vocab * costs.d * costs.quant_bytes, hw)
        done = sim.issue("comp", t_head, [done], f"t{t}.head")
        lat[t] = done - step_start
    return lat


def simulate_request(sched: BaseScheduler, costs: ModelCosts, hw: HW,
                     prefill_active: Sequence[Sequence[int]],
                     decode_trace: np.ndarray, seq_len: int,
                     batch: int = 1) -> SimResult:
    sched.begin_request()
    sim = StreamSim()
    ttft = simulate_prefill(sched, costs, hw, prefill_active, seq_len, batch,
                            sim)
    lat = simulate_decode(sched, costs, hw, decode_trace, seq_len, batch, sim,
                          t0=ttft)
    peak = (sched.cache.peak_bytes + costs.nonexpert_resident_bytes()
            + costs.kv_bytes(seq_len + len(decode_trace), batch)
            * costs.cfg.n_layers)
    return SimResult(ttft=ttft, step_latencies=lat,
                     e2e=ttft + float(lat.sum()), peak_bytes=peak,
                     hit_rate=sched.decode_hit_rate, policy=sched.name)
