"""Cross-request prefix/KV reuse: a radix tree over slot-pool KV rows.

ROADMAP item 2: traffic from millions of users is dominated by shared
prefixes (system prompts, few-shot templates, multi-turn history), so the
single biggest remaining TTFT lever is to stop re-prefilling tokens whose
KV already sits in the slot pool. The ``PrefixTree`` maps token sequences
to slot-pool rows: because the KV ring never wraps (``need <= W`` is
asserted at submission, so slot row p == absolute position p), a cached
prefix of ``n`` tokens is a contiguous ``[n, hkv, hd]`` region per layer
that can be copied row-for-row into a newly acquired slot — and the copy
is bit-identical to what cold prefill would have written, because prefill
of the same token ids at the same positions through the same weights is
deterministic.

Structure (token-level radix tree):

  * Each edge (node) carries a compressed run of token ids and the
    slot-pool region backing it: ``(slot, start, start+len(tokens))`` with
    ``start`` the absolute position of the edge's first token. Different
    nodes on one root path may be backed by DIFFERENT slots (each request
    contributed the suffix it was first to prefill).
  * ``match(tokens)`` walks the longest cached prefix, splits the final
    edge at the match boundary (so a holder's span is always a whole-node
    path), increments a per-node refcount along the path, and returns the
    hit length plus the ``(slot, lo, hi)`` row blocks to copy.
    ``release(tokens, n_hit)`` walks the same span and drops the refs.
    ``peek`` is the read-only variant (admission charging, router
    scoring) — no refs, no splits, no LRU touch.
  * ``insert(tokens, slot)`` records that ``slot`` now holds rows for
    ``tokens`` at positions ``0..len-1``: only the un-cached suffix
    creates a node (one compressed edge), backed by the inserting
    request's slot.

Slot ownership: while the donor request is LIVE its rows are valid (the
ring never wraps, so decode appends never overwrite the prompt region)
and the tree simply points into its slot. When the donor releases the
slot (``retire``/``cancel``/``snapshot``), the engine asks
``slot_released(slot)``: if any node still references the slot the tree
RETAINS it (the slot becomes tree-owned cache instead of returning to the
free list); otherwise the engine frees it normally. Tree-owned slots are
reclaimed by ``evict_for(n)`` — LRU over whole reclaimable slots, evicting
refcount-0 subtrees leaf-up — when the engine needs a free slot; eviction
never frees a node on any live request's path (refs pin the path, and a
pinned descendant pins every ancestor because eviction is leaf-only).

Invariants (tests/test_prefix.py, property-based + deterministic mirror):
refcounts never negative; per-slot row ranges disjoint and within the
ring; total referenced rows bounded by the pool; longest-match agrees
with a brute-force reference.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

TokenSeq = Tuple[int, ...]
Block = Tuple[int, int, int]          # (slot, row_lo, row_hi)


def _common_len(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


@dataclasses.dataclass(eq=False)
class PrefixNode:
    """One radix edge: a compressed token run backed by slot-pool rows
    ``[start, start + len(tokens))`` of ``slot`` (row == absolute
    position, PR 6's no-wrap invariant)."""
    tokens: TokenSeq
    slot: int
    start: int                        # absolute position of tokens[0]
    parent: Optional["PrefixNode"] = None
    refs: int = 0
    last_use: int = 0
    children: Dict[int, "PrefixNode"] = dataclasses.field(
        default_factory=dict)

    @property
    def end(self) -> int:
        return self.start + len(self.tokens)

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        return (f"PrefixNode({list(self.tokens)!r}, slot={self.slot}, "
                f"rows=[{self.start},{self.end}), refs={self.refs})")


class PrefixTree:
    """Token-level radix tree over slot-pool KV rows (module docstring)."""

    def __init__(self):
        self.root = PrefixNode(tokens=(), slot=-1, start=0)
        self._clock = 0
        # every node backed by a given slot (edges + their split halves)
        self.nodes_by_slot: Dict[int, Set[PrefixNode]] = {}
        # slots whose donor request released them while nodes still
        # reference their rows — tree-owned cache, reclaimable by eviction
        self.owned: Set[int] = set()
        # stats ------------------------------------------------------------
        self.lookups = 0
        self.hits = 0                 # match() calls with n_hit > 0
        self.hit_tokens = 0           # total tokens served from cache
        self.inserted_rows = 0        # total rows ever cached by insert()
        self.evicted_nodes = 0
        self.reclaimed_slots = 0

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return sum(len(s) for s in self.nodes_by_slot.values())

    def nodes(self) -> List[PrefixNode]:
        out: List[PrefixNode] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n is not self.root:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def cached_rows(self) -> int:
        """Total slot-pool rows currently referenced by the tree."""
        return sum(len(n.tokens) for n in self.nodes())

    # -- walk ----------------------------------------------------------------
    def _walk(self, tokens: TokenSeq, limit: Optional[int] = None
              ) -> Tuple[List[Tuple[PrefixNode, int]], int]:
        """Longest-prefix walk: returns ``([(node, n_matched_in_node)...],
        total_matched)``. The last entry may be a partial edge match; every
        earlier entry matches its node fully."""
        n_max = len(tokens) if limit is None else min(limit, len(tokens))
        node, i = self.root, 0
        path: List[Tuple[PrefixNode, int]] = []
        while i < n_max:
            child = node.children.get(tokens[i])
            if child is None:
                break
            m = _common_len(child.tokens, tokens[i:n_max])
            path.append((child, m))
            i += m
            if m < len(child.tokens):
                break
            node = child
        return path, i

    def peek(self, tokens: Sequence[int], limit: Optional[int] = None
             ) -> int:
        """Read-only longest cached prefix length (router scoring,
        admission charging): no refs, no splits, no LRU touch."""
        _, n = self._walk(tuple(int(t) for t in tokens), limit)
        return n

    # -- match / release (the live-request contract) -------------------------
    def match(self, tokens: Sequence[int], limit: Optional[int] = None
              ) -> Tuple[int, List[Block]]:
        """Longest cached prefix of ``tokens`` (capped at ``limit``):
        splits the final edge at the match boundary, pins the path
        (refs += 1 on every node whose rows the caller will copy), bumps
        LRU recency, and returns ``(n_hit, blocks)`` where the blocks'
        ``(slot, lo, hi)`` row ranges tile positions ``[0, n_hit)`` in
        order. The caller MUST pair every match having n_hit > 0 with one
        ``release(tokens, n_hit)``."""
        toks = tuple(int(t) for t in tokens)
        self.lookups += 1
        path, n = self._walk(toks, limit)
        if path:
            last, m = path[-1]
            if m < len(last.tokens):
                # split so the held span ends exactly at a node boundary;
                # existing holders of `last` all cover it fully (match
                # always leaves whole-node spans), so the new tail
                # inherits the refcount and release walks stay balanced
                self._split(last, m)
        self._clock += 1
        blocks: List[Block] = []
        for node, _ in path:
            node.refs += 1
            node.last_use = self._clock
            blocks.append((node.slot, node.start, node.end))
        if n:
            self.hits += 1
            self.hit_tokens += n
        return n, blocks

    def release(self, tokens: Sequence[int], n_hit: int) -> None:
        """Drop the refs a ``match(tokens) -> n_hit`` acquired. Walks the
        same token span; later splits only subdivide it into smaller
        whole nodes, so the walk visits exactly the held path."""
        if n_hit <= 0:
            return
        toks = tuple(int(t) for t in tokens)
        path, n = self._walk(toks, n_hit)
        assert n == n_hit, \
            f"release of unheld span: matched {n} of {n_hit} tokens"
        for node, m in path:
            assert m == len(node.tokens), "held span not node-aligned"
            node.refs -= 1
            assert node.refs >= 0, "refcount underflow"

    def _split(self, node: PrefixNode, at: int) -> PrefixNode:
        """Split ``node``'s edge after ``at`` tokens. The original object
        keeps the head (so existing path references stay valid); the new
        tail child inherits children, refcount, and recency."""
        assert 0 < at < len(node.tokens)
        tail = PrefixNode(tokens=node.tokens[at:], slot=node.slot,
                          start=node.start + at, parent=node,
                          refs=node.refs, last_use=node.last_use,
                          children=node.children)
        for gc in tail.children.values():
            gc.parent = tail
        node.tokens = node.tokens[:at]
        node.children = {tail.tokens[0]: tail}
        self.nodes_by_slot.setdefault(node.slot, set()).add(tail)
        return tail

    # -- insert --------------------------------------------------------------
    def insert(self, tokens: Sequence[int], slot: int) -> int:
        """Record that ``slot`` holds KV rows for ``tokens`` at positions
        ``0..len-1``. Creates at most ONE new edge (the un-cached suffix,
        backed by ``slot``); returns the number of newly cached rows (0 if
        the whole sequence was already present)."""
        toks = tuple(int(t) for t in tokens)
        assert slot >= 0
        path, n = self._walk(toks)
        self._clock += 1
        for node, _ in path:
            node.last_use = self._clock
        if n >= len(toks):
            return 0
        if path:
            last, m = path[-1]
            if m < len(last.tokens):
                self._split(last, m)         # diverge mid-edge
                parent = last
            else:
                parent = last
        else:
            parent = self.root
        child = PrefixNode(tokens=toks[n:], slot=slot, start=n,
                           parent=parent, last_use=self._clock)
        parent.children[toks[n]] = child
        self.nodes_by_slot.setdefault(slot, set()).add(child)
        self.inserted_rows += len(toks) - n
        return len(toks) - n

    # -- slot lifecycle ------------------------------------------------------
    def slot_released(self, slot: int) -> bool:
        """The donor request released ``slot``. True -> the tree still
        references its rows and RETAINS the slot (now tree-owned cache —
        the engine must NOT free it); False -> no references, the engine
        frees it normally."""
        if self.nodes_by_slot.get(slot):
            self.owned.add(slot)
            return True
        self.nodes_by_slot.pop(slot, None)
        return False

    def forget_slot(self, slot: int) -> None:
        """Drop every node backed by ``slot`` without freeing anything
        (the donor's rows became invalid while it still owns the slot —
        not used by the engine today, but the safe escape hatch). Refuses
        if any node on the subtree is pinned."""
        for node in list(self.nodes_by_slot.get(slot, ())):
            self._remove_subtree(node)
        self.nodes_by_slot.pop(slot, None)
        self.owned.discard(slot)

    # -- eviction ------------------------------------------------------------
    def _subtree_unpinned(self, node: PrefixNode) -> bool:
        """True iff the whole subtree at ``node`` could be evicted: no
        refs anywhere, and every backing slot is tree-owned (a node backed
        by a LIVE request's slot frees no memory and marks state the
        donor will re-offer at release)."""
        if node.refs > 0 or node.slot not in self.owned:
            return False
        return all(self._subtree_unpinned(c)
                   for c in node.children.values())

    def _slot_reclaimable(self, slot: int) -> bool:
        nodes = self.nodes_by_slot.get(slot)
        if not nodes or slot not in self.owned:
            return False
        return all(self._subtree_unpinned(n) for n in nodes)

    def n_reclaimable(self) -> int:
        """Tree-owned slots an ``evict_for`` call could free RIGHT NOW —
        the admission limit's slack on top of the engine's free list."""
        return sum(1 for s in self.owned if self._slot_reclaimable(s))

    def _remove_subtree(self, node: PrefixNode) -> None:
        assert node.refs == 0, "evicting a pinned node"
        for child in list(node.children.values()):
            self._remove_subtree(child)
        parent = node.parent
        assert parent is not None
        del parent.children[node.tokens[0]]
        node.parent = None
        s = self.nodes_by_slot.get(node.slot)
        if s is not None:
            s.discard(node)
        self.evicted_nodes += 1

    def evict_for(self, want: int) -> List[int]:
        """Reclaim up to ``want`` tree-owned slots, least-recently-used
        first (slot recency = the newest touch among its nodes). Evicting
        one slot's subtrees can cascade-free other owned slots whose only
        nodes hung beneath them; every freed slot is returned. Never
        touches a pinned path or a live request's slot."""
        freed: List[int] = []
        while len(freed) < want:
            cands = [s for s in self.owned if self._slot_reclaimable(s)]
            if not cands:
                break
            victim = min(cands, key=lambda s: (
                max(n.last_use for n in self.nodes_by_slot[s]), s))
            # leaf-up removal of every subtree rooted at the victim's
            # nodes; skip nodes a sibling subtree already removed
            for node in sorted(self.nodes_by_slot[victim],
                               key=lambda n: -n.start):
                if node.parent is not None:
                    self._remove_subtree(node)
            for s in list(self.owned):
                if not self.nodes_by_slot.get(s):
                    self.nodes_by_slot.pop(s, None)
                    self.owned.discard(s)
                    self.reclaimed_slots += 1
                    freed.append(s)
        return freed

    # -- invariants (exercised by tests/test_prefix.py) ----------------------
    def check_invariants(self, n_rows: Optional[int] = None) -> None:
        """Structural health: child keys match edge heads, parent links
        are consistent, refs are non-negative, per-slot row ranges are
        disjoint and within the ring, and the by-slot index matches the
        tree exactly."""
        seen_by_slot: Dict[int, List[Tuple[int, int]]] = {}
        stack = [self.root]
        while stack:
            n = stack.pop()
            assert n.refs >= 0
            for head, c in n.children.items():
                assert c.tokens and c.tokens[0] == head
                assert c.parent is n
                assert c.start == n.end   # positions are absolute
                stack.append(c)
            if n is self.root:
                continue
            assert n in self.nodes_by_slot.get(n.slot, set())
            seen_by_slot.setdefault(n.slot, []).append((n.start, n.end))
        for slot, ranges in seen_by_slot.items():
            ranges.sort()
            for (a0, b0), (a1, b1) in zip(ranges, ranges[1:]):
                assert b0 <= a1, f"overlapping rows on slot {slot}"
            if n_rows is not None:
                assert ranges[-1][1] <= n_rows, "rows beyond the ring"
        tree_nodes = set(self.nodes())
        index_nodes = {n for s in self.nodes_by_slot.values() for n in s}
        assert tree_nodes == index_nodes, "by-slot index out of sync"
        for s in self.owned:
            assert self.nodes_by_slot.get(s), "owned slot without nodes"
