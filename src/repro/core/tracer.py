"""Experts Tracer (paper §IV-A): activation-path recording + popularity /
affinity statistics.

An *expert activation path* is the per-token sequence of selected expert sets
across layers during one inference episode (Eq. 1). From N recorded paths the
tracer builds:

  * popularity  P[l, i]    — Eq. 2: selection frequency per layer, normalized
                             to a probability distribution over experts;
  * affinity    A[l, i, j] — Eq. 3: P(expert j selected at layer l+1 | expert
                             i selected at layer l), rows normalized.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class TraceStats:
    popularity: np.ndarray    # [L, E]
    affinity: np.ndarray      # [L-1, E, E]
    n_paths: int
    n_layers: int
    n_experts: int
    top_k: int

    def save(self, path: str) -> None:
        np.savez(path, popularity=self.popularity, affinity=self.affinity,
                 meta=np.array([self.n_paths, self.n_layers, self.n_experts,
                                self.top_k]))

    @staticmethod
    def load(path: str) -> "TraceStats":
        z = np.load(path)
        n, l, e, k = (int(v) for v in z["meta"])
        return TraceStats(z["popularity"], z["affinity"], n, l, e, k)

    def tiled(self, n_layers: int) -> "TraceStats":
        """Project stats from a shallow trace model onto a deeper stack by
        repeating the layer pattern (demo/replay helper)."""
        reps = -(-n_layers // self.n_layers)
        pop = np.tile(self.popularity, (reps, 1))[:n_layers]
        if self.affinity.shape[0]:
            reps_a = -(-(n_layers - 1) // self.affinity.shape[0])
            aff = np.tile(self.affinity, (reps_a, 1, 1))[: n_layers - 1]
        else:
            aff = np.zeros((n_layers - 1, self.n_experts, self.n_experts),
                           np.float32)
        return TraceStats(pop, aff, self.n_paths, n_layers, self.n_experts,
                          self.top_k)


class ExpertsTracer:
    """Records [L, k] expert-id paths; computes popularity/affinity."""

    def __init__(self, n_layers: int, n_experts: int, top_k: int):
        self.n_layers = n_layers
        self.n_experts = n_experts
        self.top_k = top_k
        self.paths: List[np.ndarray] = []

    def add_path(self, path: np.ndarray) -> None:
        path = np.asarray(path, np.int32)
        assert path.shape == (self.n_layers, self.top_k), (
            f"path shape {path.shape} != {(self.n_layers, self.top_k)}")
        assert (path >= 0).all() and (path < self.n_experts).all()
        self.paths.append(path)

    def add_paths(self, paths: np.ndarray) -> None:
        """paths: [N, L, k]."""
        for p in np.asarray(paths):
            self.add_path(p)

    def stats(self) -> TraceStats:
        L, E = self.n_layers, self.n_experts
        counts = np.zeros((L, E))
        joint = np.zeros((max(L - 1, 0), E, E))
        for path in self.paths:
            for l in range(L):
                counts[l, path[l]] += 1
                if l + 1 < L:
                    for i in path[l]:
                        joint[l, i, path[l + 1]] += 1
        # Eq. 2: normalize per layer (selection probability distribution)
        pop = counts / np.maximum(counts.sum(axis=1, keepdims=True), 1)
        # Eq. 3: normalize rows of each layer-transition matrix
        aff = joint / np.maximum(joint.sum(axis=2, keepdims=True), 1)
        return TraceStats(pop.astype(np.float32), aff.astype(np.float32),
                          len(self.paths), L, E, self.top_k)

    def as_array(self) -> np.ndarray:
        return np.stack(self.paths) if self.paths else np.zeros(
            (0, self.n_layers, self.top_k), np.int32)
