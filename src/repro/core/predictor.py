"""ExpertMLP (paper §IV-B): deep MLP expert-activation predictor, pure JAX.

Architecture (faithful): seven fully-connected hidden layers with widths
progressively reduced from 2048 to 64, each followed by BatchNorm + ReLU +
Dropout(0.1), then a final linear output over the target layer's experts.
Trained with multi-label Binary Cross-Entropy (Eq. 6) via sigmoid outputs.

One predictor is shared across all layers of a model (the layer index is part
of the state vector — "layer-level prediction"). `width_scale` shrinks the
stack proportionally for reduced smoke models.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optimizer import AdamW

HIDDEN = (2048, 1536, 1024, 512, 256, 128, 64)
DROPOUT = 0.1


def hidden_dims(width_scale: float = 1.0) -> Tuple[int, ...]:
    return tuple(max(8, int(h * width_scale)) for h in HIDDEN)


def init_predictor(key, in_dim: int, n_experts: int,
                   width_scale: float = 1.0):
    dims = (in_dim,) + hidden_dims(width_scale) + (n_experts,)
    keys = jax.random.split(key, len(dims) - 1)
    params, bn = [], []
    for i, k in enumerate(keys):
        fan_in = dims[i]
        w = jax.random.normal(k, (dims[i], dims[i + 1])) * (2.0 / fan_in) ** 0.5
        params.append({"w": w.astype(jnp.float32),
                       "b": jnp.zeros(dims[i + 1], jnp.float32)})
        if i < len(keys) - 1:  # batchnorm on hidden layers only
            params[-1]["bn_scale"] = jnp.ones(dims[i + 1], jnp.float32)
            params[-1]["bn_bias"] = jnp.zeros(dims[i + 1], jnp.float32)
            bn.append({"mean": jnp.zeros(dims[i + 1], jnp.float32),
                       "var": jnp.ones(dims[i + 1], jnp.float32)})
    return params, bn


def forward(params: List[Dict], bn_state: List[Dict], x: jax.Array, *,
            train: bool, rng=None, momentum: float = 0.9):
    """Returns (logits [B, E], new_bn_state)."""
    new_bn = []
    h = x
    n_hidden = len(params) - 1
    for i, lp in enumerate(params):
        h = h @ lp["w"] + lp["b"]
        if i < n_hidden:
            st = bn_state[i]
            if train:
                mu = h.mean(0)
                var = h.var(0) + 1e-5
                new_bn.append({
                    "mean": momentum * st["mean"] + (1 - momentum) * mu,
                    "var": momentum * st["var"] + (1 - momentum) * var,
                })
            else:
                mu, var = st["mean"], st["var"] + 1e-5
                new_bn.append(st)
            h = (h - mu) * jax.lax.rsqrt(var)
            h = h * lp["bn_scale"] + lp["bn_bias"]
            h = jax.nn.relu(h)
            if train and rng is not None:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1 - DROPOUT, h.shape)
                h = jnp.where(keep, h / (1 - DROPOUT), 0.0)
    return h, new_bn


def bce_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Eq. 6: multi-label binary cross-entropy over sigmoid outputs."""
    z = logits
    # stable BCE-with-logits: max(z,0) - z*y + log(1+exp(-|z|))
    return jnp.mean(jnp.maximum(z, 0) - z * targets
                    + jnp.log1p(jnp.exp(-jnp.abs(z))))


@dataclasses.dataclass
class TrainedPredictor:
    params: List[Dict]
    bn_state: List[Dict]
    top_k: int

    def predict_logits(self, x: np.ndarray) -> np.ndarray:
        lg, _ = forward(self.params, self.bn_state, jnp.asarray(x), train=False)
        return np.asarray(lg)

    def predict_topk(self, x: np.ndarray, k: int | None = None) -> np.ndarray:
        lg = self.predict_logits(x)
        k = k or self.top_k
        return np.argsort(-lg, axis=-1)[..., :k]


def train_predictor(key, X: np.ndarray, Y: np.ndarray, top_k: int, *,
                    width_scale: float = 1.0, epochs: int = 10,
                    batch: int = 256, lr: float = 1e-3,
                    val_frac: float = 0.1, verbose: bool = False):
    """Offline preprocess training (paper §IV-B). Returns
    (TrainedPredictor, history dict)."""
    n = X.shape[0]
    n_val = max(1, int(n * val_frac))
    rng = np.random.default_rng(0)
    perm = rng.permutation(n)
    Xtr, Ytr = X[perm[n_val:]], Y[perm[n_val:]]
    Xva, Yva = X[perm[:n_val]], Y[perm[:n_val]]

    kinit, key = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    params, bn = init_predictor(kinit, X.shape[1], Y.shape[1], width_scale)
    opt = AdamW(lr=lr, weight_decay=1e-4, grad_clip=1.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, bn, opt_state, xb, yb, rng):
        def loss_fn(p):
            lg, new_bn = forward(p, bn, xb, train=True, rng=rng)
            return bce_loss(lg, yb), new_bn
        (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, new_bn, opt_state, loss

    @jax.jit
    def val_loss(params, bn, xb, yb):
        lg, _ = forward(params, bn, xb, train=False)
        return bce_loss(lg, yb), lg

    history = {"train_loss": [], "val_loss": [], "val_topk": [],
               "val_half": []}
    steps_per_epoch = max(1, len(Xtr) // batch)
    for ep in range(epochs):
        perm = rng.permutation(len(Xtr))
        losses = []
        for i in range(steps_per_epoch):
            idx = perm[i * batch:(i + 1) * batch]
            key, sub = jax.random.split(key)
            params, bn, opt_state, loss = step(
                params, bn, opt_state, jnp.asarray(Xtr[idx]),
                jnp.asarray(Ytr[idx]), sub)
            losses.append(float(loss))
        vl, vlg = val_loss(params, bn, jnp.asarray(Xva), jnp.asarray(Yva))
        tk, half = accuracy_metrics(np.asarray(vlg), Yva, top_k)
        history["train_loss"].append(float(np.mean(losses)))
        history["val_loss"].append(float(vl))
        history["val_topk"].append(tk)
        history["val_half"].append(half)
        if verbose:
            print(f"epoch {ep}: train {np.mean(losses):.4f} val {float(vl):.4f}"
                  f" topk {tk:.3f} half {half:.3f}")
    return TrainedPredictor(params, bn, top_k), history


def accuracy_metrics(logits: np.ndarray, targets: np.ndarray,
                     top_k: int) -> Tuple[float, float]:
    """Paper Table III metrics.

    Top-k: all k routed experts correctly predicted (set equality of the
    predictor's top-k vs ground truth). At-least-half: >= ceil(k/2) of the
    routed experts are in the predictor's top-k.
    """
    pred = np.argsort(-logits, axis=-1)[:, :top_k]
    hits = np.zeros(len(logits))
    for i in range(len(logits)):
        true = np.where(targets[i] > 0)[0]
        hits[i] = len(np.intersect1d(pred[i], true))
    k_true = targets.sum(1)
    exact = float(np.mean(hits >= k_true))
    half = float(np.mean(hits >= np.ceil(k_true / 2)))
    return exact, half
