"""Phase-specialized expert scheduling policies (paper §V + baselines §VI-A).

Four policies, each driving ONE CacheState so hit/miss/eviction/peak-memory
behaviour is identical between the live serving engine and the discrete-event
simulator. The engine passes its `ExpertResidency` (core/cache.py) as the
shared `state` — scheduler and device buffers then share a single ledger by
reference, every plan-time admit/evict/unpin landing symmetrically on device
memory; the simulator omits `state` and gets a plain ledger-only CacheState:

  * ODF  — On-Demand Fetch (HF-Accelerate-style): fetch activated experts
           only after gate selection, serial on the critical path.
  * LFP  — Layer-wise Full Prefetch (MoESys-style): prefetch every expert of
           the next layer; fast but peak-memory heavy.
  * MIF  — MoE-Infinity-style: big activation-aware LRU cache, trace-prior
           (popularity) prefetch of likely experts for upcoming layers.
  * DUO  — DuoServe-MoE: prefill = pipelined per-expert streaming (two
           streams, cache of k slots); decode = ExpertMLP-predicted prefetch
           one layer ahead + synchronous correction on miss.

`prefill_plan` / `decode_plan` mutate the policy's cache state and return
declarative plans the engine executes and the simulator times.

Decode plans accept multi-request selections (paper §V generalized to B>1):
`decode_plan(layer, selections)` takes either one request's [k] expert ids or
a sequence of per-request id lists; nested selections are unioned in
first-appearance order before cache bookkeeping, so the shared ExpertResidency
under continuous batching fetches each distinct expert once per step and the
hit/miss ledger counts distinct experts, not per-request duplicates.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.cache import CacheState
from repro.core.tracer import TraceStats


@dataclasses.dataclass
class PrefillPlan:
    layer: int
    order: List[int]          # expert execution order (active experts)
    fetches: List[int]        # subset of `order` that must be transferred
    overlap_first: bool       # first fetch may overlap non-MoE compute
    pipelined: bool           # fetch e+1 overlaps compute of e
    prefetch_all_first: bool  # all fetches complete before first compute


@dataclasses.dataclass
class DecodePlan:
    layer: int
    hits: List[int]           # selected experts already resident
    misses: List[int]         # selected experts needing a blocking fetch
    prefetch_next: List[int]  # experts to prefetch for layer+1 (async)
    predicted: List[int]      # what the policy predicted for THIS layer


def union_selection(selected) -> List[int]:
    """Flatten one request's [k] ids or B requests' [[k], ...] into a
    duplicate-free list, preserving first-appearance order (request 0's
    top-1 first). Order stability keeps fetch schedules deterministic."""
    seen: Set[int] = set()
    out: List[int] = []
    stack = list(selected)[::-1]
    while stack:
        e = stack.pop()
        if isinstance(e, (list, tuple, np.ndarray)):
            stack.extend(list(e)[::-1])
            continue
        e = int(e)
        if e not in seen:
            seen.add(e)
            out.append(e)
    return out


def default_capacity(name: str, n_layers: int, n_experts: int, top_k: int,
                     batch: int = 1) -> int:
    """Policy-default residency capacity (single source of truth; the engine
    uses it to size the ExpertResidency slot pool BEFORE constructing the
    scheduler that will share it).

    batch: max concurrent decode requests the cache must absorb per step."""
    name = name.lower()
    if name == "odf":
        return 2 * top_k * batch
    if name == "lfp":
        # staging is per-layer (all E experts), independent of batch size
        return 2 * n_experts
    if name == "mif":
        # MoE-Infinity holds a large activation-aware cache (Table II shows
        # its footprint is by far the largest of the compared systems)
        return max(4 * top_k * batch, int(0.6 * n_layers * n_experts))
    if name in ("duo", "duoserve"):
        # must cover one batched step's churn: the selected union
        # (<= batch*k) plus the widened next-layer prefetch (<= batch*k)
        return 2 * top_k * batch
    if name in ("duo+", "duo_plus"):
        # Beyond-paper variant (EXPERIMENTS.md §Perf): same dual-phase
        # scheduling, but the decode cache retains hot experts across steps.
        # Capacity must exceed one step's churn (selected + mispredicted
        # prefetches across all layers, ~1.5*L*k) or LRU evicts everything
        # before reuse; at that size temporal locality turns repeats into
        # zero-byte hits (measured: misses -5.4x, prefetch transfers -11x on
        # Mixtral) at ~half of MIF's footprint.
        return max(2 * top_k * batch,
                   3 * n_layers * top_k // 2 + 2 * top_k * batch)
    raise KeyError(name)


class BaseScheduler:
    name = "base"
    uses_predictor = False

    def __init__(self, n_layers: int, n_experts: int, top_k: int,
                 bytes_per_expert: int, capacity: int,
                 state: Optional[CacheState] = None):
        self.L = n_layers
        self.E = n_experts
        self.k = top_k
        if state is not None:
            # shared-ledger mode: the engine's ExpertResidency IS the cache;
            # grow it if this policy needs more room than it was built with
            if capacity > state.capacity:
                state.rescale(capacity)
            self.cache = state
        else:
            self.cache = CacheState(capacity, bytes_per_expert)
        self._next_prefetched: Dict[int, List[int]] = {}
        self.decode_hits = 0
        self.decode_misses = 0

    # -- shared helpers ----------------------------------------------------
    def begin_request(self) -> None:
        self._next_prefetched.clear()
        self.cache.unpin_all()

    def _fetch_missing(self, layer: int, experts: Sequence[int],
                       pinned: bool = True) -> List[int]:
        fetches = []
        for e in experts:
            key = (layer, int(e))
            if not self.cache.lookup(key):
                self.cache.admit(key, pinned=pinned)
                # an unpinned (speculative) admit into an all-pinned full
                # cache is declined — then there is nothing to transfer
                if self.cache.contains(key):
                    fetches.append(int(e))
        return fetches

    def _split_hits(self, layer: int, experts: Sequence[int]
                    ) -> Tuple[List[int], List[int]]:
        hits, misses = [], []
        for e in experts:
            key = (layer, int(e))
            if self.cache.lookup(key):
                hits.append(int(e))
            else:
                self.cache.admit(key)
                misses.append(int(e))
        self.decode_hits += len(hits)
        self.decode_misses += len(misses)
        return hits, misses

    @property
    def decode_hit_rate(self) -> float:
        tot = self.decode_hits + self.decode_misses
        return self.decode_hits / tot if tot else 0.0

    def end_layer(self, layer: int) -> None:
        """Unpin this layer's experts once its computation is done."""
        for key in list(self.cache.resident):
            if key[0] == layer:
                self.cache.unpin(key)

    # -- to override --------------------------------------------------------
    def prefill_plan(self, layer: int, active: Sequence[int]) -> PrefillPlan:
        raise NotImplementedError

    def decode_plan(self, layer: int, selected: Sequence[int],
                    features: Optional[np.ndarray] = None) -> DecodePlan:
        raise NotImplementedError


class ODFScheduler(BaseScheduler):
    """On-Demand Fetch (HF Accelerate semantics): offloaded module weights
    are loaded when the module runs and FREED after it — no cross-step reuse
    (`stateless=True`, the faithful baseline). Transfers sit on the critical
    path after the gate."""
    name = "odf"

    def __init__(self, n_layers, n_experts, top_k, bytes_per_expert,
                 capacity: Optional[int] = None, stateless: bool = True,
                 batch: int = 1, state=None):
        super().__init__(n_layers, n_experts, top_k, bytes_per_expert,
                         capacity or default_capacity(
                             "odf", n_layers, n_experts, top_k, batch),
                         state=state)
        self.stateless = stateless

    def prefill_plan(self, layer, active):
        fetches = self._fetch_missing(layer, active)
        return PrefillPlan(layer, list(map(int, active)), fetches,
                           overlap_first=False, pipelined=False,
                           prefetch_all_first=False)

    def decode_plan(self, layer, selected, features=None):
        selected = union_selection(selected)
        if self.stateless:
            # accelerate frees offloaded weights after each module forward;
            # drop() routes the free through the residency hooks so the
            # device slot is released too (no event: not a capacity evict)
            for key in [k for k in self.cache.resident if k[0] != layer]:
                self.cache.drop(key)
        hits, misses = self._split_hits(layer, selected)
        self.end_layer(layer)
        return DecodePlan(layer, hits, misses, prefetch_next=[], predicted=[])


class LFPScheduler(BaseScheduler):
    """Layer-wise Full Prefetch: all E experts of a layer are staged before
    expert computation; the next layer's experts prefetch during compute."""
    name = "lfp"

    def __init__(self, n_layers, n_experts, top_k, bytes_per_expert,
                 capacity: Optional[int] = None, batch: int = 1, state=None):
        super().__init__(n_layers, n_experts, top_k, bytes_per_expert,
                         capacity or default_capacity(
                             "lfp", n_layers, n_experts, top_k, batch),
                         state=state)

    def prefill_plan(self, layer, active):
        fetches = self._fetch_missing(layer, range(self.E))
        return PrefillPlan(layer, list(map(int, active)), fetches,
                           overlap_first=True, pipelined=False,
                           prefetch_all_first=True)

    def decode_plan(self, layer, selected, features=None):
        selected = union_selection(selected)
        hits, misses = self._split_hits(layer, selected)
        nxt = list(range(self.E)) if layer + 1 < self.L else []
        if nxt:
            self.end_layer(layer)  # free this layer before staging the next
            self._fetch_missing(layer + 1, nxt)
        return DecodePlan(layer, hits, misses, prefetch_next=nxt, predicted=[])


class MIFScheduler(BaseScheduler):
    """MoE-Infinity-style: large LRU cache + trace-prior (popularity)
    prefetch. Needs TraceStats; its 'prediction' for a layer is the top-k most
    popular experts (request-level tracing prior)."""
    name = "mif"
    uses_predictor = False

    def __init__(self, n_layers, n_experts, top_k, bytes_per_expert,
                 stats: TraceStats, capacity: Optional[int] = None,
                 batch: int = 1, state=None):
        cap = capacity or default_capacity("mif", n_layers, n_experts,
                                           top_k, batch)
        super().__init__(n_layers, n_experts, top_k, bytes_per_expert, cap,
                         state=state)
        self.stats = stats

    def _prior(self, layer: int) -> List[int]:
        return list(np.argsort(-self.stats.popularity[layer])[: self.k])

    def prefill_plan(self, layer, active):
        # prefetch trace-prior first, then whatever the gate actually needs
        prior = self._prior(layer)
        fetches = self._fetch_missing(layer, prior)
        fetches += self._fetch_missing(layer, active)
        act = set(map(int, active))
        order = ([e for e in prior if e in act]
                 + [e for e in map(int, active) if e not in prior])
        return PrefillPlan(layer, order, fetches, overlap_first=True,
                           pipelined=False, prefetch_all_first=True)

    def decode_plan(self, layer, selected, features=None):
        selected = union_selection(selected)
        predicted = self._prior(layer)
        hits, misses = self._split_hits(layer, selected)
        self.end_layer(layer)
        nxt = []
        if layer + 1 < self.L:
            nxt = [e for e in self._prior(layer + 1)
                   if not self.cache.contains((layer + 1, e))]
            # keep only what was actually admitted (speculative admits are
            # declined when the cache is full of pinned entries)
            nxt = self._fetch_missing(layer + 1, nxt, pinned=False)
        return DecodePlan(layer, hits, misses, prefetch_next=nxt,
                          predicted=predicted)


class DuoServeScheduler(BaseScheduler):
    """DuoServe-MoE.

    Prefill: two-stream pipeline — cache of k slots; expert e+1 streams in
    while e computes; the first fetch overlaps non-MoE compute.
    Decode: the ExpertMLP (trained offline) predicts layer l+1's experts
    during layer l's expert computation; predicted experts prefetch on the
    communication stream; gate-time mismatches trigger a blocking correction
    fetch (sync point #1 in the paper).
    """
    name = "duo"
    uses_predictor = True

    def __init__(self, n_layers, n_experts, top_k, bytes_per_expert,
                 predictor=None, state_constructor=None,
                 capacity: Optional[int] = None, batch: int = 1, state=None):
        super().__init__(n_layers, n_experts, top_k, bytes_per_expert,
                         capacity or default_capacity(
                             "duo", n_layers, n_experts, top_k, batch),
                         state=state)
        self.predictor = predictor
        self.state_constructor = state_constructor
        self._path: List[np.ndarray] = []

    def begin_request(self):
        super().begin_request()
        self._path = []

    def begin_decode_step(self):
        self._path = []
        self._next_prefetched.clear()

    def prefill_plan(self, layer, active):
        fetches = self._fetch_missing(layer, active)
        return PrefillPlan(layer, list(map(int, active)), fetches,
                           overlap_first=True, pipelined=True,
                           prefetch_all_first=False)

    def _predict(self, layer: int, width: Optional[int] = None) -> List[int]:
        if self.predictor is None or self.state_constructor is None:
            return []
        width = min(self.E, width or self.k)
        feat = self.state_constructor.features(self._path, layer)
        top = self.predictor.predict_topk(feat[None], k=width)[0]
        return [int(e) for e in top[:width]]

    def decode_plan(self, layer, selected, features=None):
        # a batched step needs up to n_req*k distinct experts at layer l+1;
        # widen the prediction stream accordingly (single request: k).
        n_req = sum(1 for s in selected
                    if isinstance(s, (list, tuple, np.ndarray))) or 1
        selected = union_selection(selected)
        predicted = self._next_prefetched.get(layer, [])
        hits, misses = self._split_hits(layer, selected)
        self._path.append(np.asarray(selected, np.int32))
        nxt = []
        if layer + 1 < self.L:
            nxt = self._predict(layer + 1, width=n_req * self.k)
            self.end_layer(layer)
            nxt = self._fetch_missing(layer + 1, nxt)
            self._next_prefetched[layer + 1] = nxt
        return DecodePlan(layer, hits, misses, prefetch_next=nxt,
                          predicted=predicted)


def make_scheduler(name: str, n_layers: int, n_experts: int, top_k: int,
                   bytes_per_expert: int, *, stats: Optional[TraceStats] = None,
                   predictor=None, state_constructor=None,
                   capacity: Optional[int] = None,
                   batch: int = 1, state: Optional[CacheState] = None
                   ) -> BaseScheduler:
    """batch: max concurrent decode requests the cache must absorb per
    step (continuous batching); scales the policy default capacities.
    state: a shared CacheState/ExpertResidency to drive instead of
    constructing a private ledger — the engine passes its residency here so
    exactly ONE ledger exists per engine; the simulator omits it."""
    name = name.lower()
    if name == "odf":
        return ODFScheduler(n_layers, n_experts, top_k, bytes_per_expert,
                            capacity, batch=batch, state=state)
    if name == "lfp":
        return LFPScheduler(n_layers, n_experts, top_k, bytes_per_expert,
                            capacity, batch=batch, state=state)
    if name == "mif":
        assert stats is not None, "MIF needs TraceStats"
        return MIFScheduler(n_layers, n_experts, top_k, bytes_per_expert,
                            stats, capacity, batch=batch, state=state)
    if name in ("duo", "duoserve"):
        return DuoServeScheduler(n_layers, n_experts, top_k, bytes_per_expert,
                                 predictor, state_constructor, capacity,
                                 batch=batch, state=state)
    if name in ("duo+", "duo_plus"):
        # see default_capacity("duo+"): cross-step retention variant
        return DuoServeScheduler(n_layers, n_experts, top_k, bytes_per_expert,
                                 predictor, state_constructor,
                                 capacity or default_capacity(
                                     "duo+", n_layers, n_experts, top_k,
                                     batch),
                                 state=state)
    raise KeyError(name)
