"""QoS metrics (paper §VI-A Metrics) + SLO-aware admission control.

Metrics: TTFT, E2E, tail latency, throughput summaries over request sets.

Admission (continuous-batching front-end): the paper's QoS claim is that
TTFT/E2E stay under the SLO; under concurrent load that only holds if the
queue sheds requests whose deadline is already unmeetable. `LatencyModel`
keeps EWMA estimates of prefill cost per token and per-step decode cost;
`AdmissionController` predicts a candidate's TTFT from the work queued ahead
of it and rejects when the prediction breaches the request's TTFT deadline.

Chunk-aware TTFT: under chunked prefill (serving/batching.py) a prompt is
processed `chunk_budget` tokens per engine iteration and every iteration
also runs one batched decode step for the in-flight decoders, so predicted
TTFT = queue wait + (backlog + own) prefill cost + #iterations x decode-step
interference. `TBTLedger` records the dual metric — per-request inter-token
gaps — which chunking bounds and monolithic prefill blows through.

Cluster routing (serving/cluster.py): `ReplicaLoad` is one engine replica's
load snapshot (queue depth, prefill backlog, outstanding decode tokens,
free KV slots) — the signal the least-loaded router ranks by — and
`AdmissionController.headroom` scores how much margin a candidate request's
SLOs would have on that replica (the slo_headroom routing policy: dispatch
to the replica with the most margin, reject only when every replica is
negative).
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class QoSSummary:
    mean_ttft: float
    mean_e2e: float
    p50_e2e: float
    p95_e2e: float
    p99_e2e: float
    tokens_per_s: float
    peak_bytes: float
    hit_rate: float
    n_requests: int

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def summarize(ttfts: Sequence[float], e2es: Sequence[float],
              total_tokens: int, peak_bytes: float = 0.0,
              hit_rate: float = 0.0) -> QoSSummary:
    e = np.asarray(e2es, float)
    return QoSSummary(
        mean_ttft=float(np.mean(ttfts)),
        mean_e2e=float(e.mean()),
        p50_e2e=float(np.percentile(e, 50)),
        p95_e2e=float(np.percentile(e, 95)),
        p99_e2e=float(np.percentile(e, 99)),
        tokens_per_s=float(total_tokens / max(e.sum(), 1e-12)),
        peak_bytes=float(peak_bytes),
        hit_rate=float(hit_rate),
        n_requests=len(e2es),
    )


def slo_attainment(e2es: Sequence[float], slo: float) -> float:
    e = np.asarray(e2es, float)
    return float((e <= slo).mean())


def percentile_report(samples: Sequence[float],
                      qs: Sequence[float] = (50, 99)) -> Dict[str, float]:
    """{'p50': ..., 'p99': ...} over a latency sample set (empty -> nan)."""
    a = np.asarray(list(samples), float)
    if a.size == 0:
        return {f"p{int(q)}": float("nan") for q in qs}
    return {f"p{int(q)}": float(np.percentile(a, q)) for q in qs}


class P2Quantile:
    """Streaming quantile estimator (P^2 algorithm, Jain & Chlamtac 1985).

    Five markers, O(1) memory and update cost, no samples retained — the
    piece that lets a days-long serving process report p50/p99 inter-token
    gaps over its WHOLE lifetime while the ledger itself only keeps a
    bounded window of raw samples.
    """

    def __init__(self, q: float):
        assert 0.0 < q < 1.0
        self.q = q
        self.count = 0
        self._init: List[float] = []          # first five observations
        self._h: List[float] = []             # marker heights
        self._n: List[float] = []             # marker positions (1-based)
        self._np: List[float] = []            # desired positions
        self._dn = [0.0, q / 2, q, (1 + q) / 2, 1.0]

    def update(self, x: float) -> None:
        self.count += 1
        if len(self._init) < 5:
            self._init.append(x)
            if len(self._init) == 5:
                self._h = sorted(self._init)
                self._n = [1.0, 2.0, 3.0, 4.0, 5.0]
                q = self.q
                self._np = [1.0, 1 + 2 * q, 1 + 4 * q, 3 + 2 * q, 5.0]
            return
        h, n = self._h, self._n
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = next(i for i in range(4) if h[i] <= x < h[i + 1])
        for i in range(k + 1, 5):
            n[i] += 1
        for i in range(5):
            self._np[i] += self._dn[i]
        for i in (1, 2, 3):
            d = self._np[i] - n[i]
            if (d >= 1 and n[i + 1] - n[i] > 1) or \
                    (d <= -1 and n[i - 1] - n[i] < -1):
                d = 1.0 if d > 0 else -1.0
                # parabolic (P^2) marker height update; linear fallback
                # when the parabola would break marker monotonicity
                hp = h[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (h[i + 1] - h[i])
                    / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (h[i] - h[i - 1])
                    / (n[i] - n[i - 1]))
                if not h[i - 1] < hp < h[i + 1]:
                    j = i + int(d)
                    hp = h[i] + d * (h[j] - h[i]) / (n[j] - n[i])
                h[i] = hp
                n[i] += d

    def value(self) -> float:
        if self.count == 0:
            return float("nan")
        if len(self._init) < 5:
            return float(np.percentile(self._init, self.q * 100))
        return self._h[2]


class TBTLedger:
    """Per-request inter-token-gap (time-between-tokens) ledger.

    `observe(rid, t)` marks request `rid` emitting a token at wall time `t`
    and records the gap since its previous token; `close(rid)` forgets a
    finished request's baseline. The max/p99 of these gaps is the stall
    metric chunked prefill bounds (benchmarks/bench_stall.py): a monolithic
    prefill of S tokens freezes every in-flight decoder for the whole
    prefill, which shows up here as a gap of ~ S * prefill_per_token.

    Retention: raw gap samples live in bounded deques (`window` overall,
    `per_rid_window` per request), and the per-request dict itself is
    bounded — `close(rid)` enrolls the request in a `closed_window`-deep
    FIFO whose evictees lose their `by_rid` entry — so a long-running
    server leaks neither samples nor per-request deques. Lifetime p50/p99
    survive eviction via streaming P^2 sketches and the lifetime max/count
    as scalars. Passing None for a window keeps that dimension unbounded
    (exact, benchmark mode).
    """

    def __init__(self, window: Optional[int] = 8192,
                 per_rid_window: Optional[int] = 1024,
                 closed_window: Optional[int] = 512,
                 sketch_qs: Sequence[float] = (50, 99)):
        self._last: Dict[int, float] = {}
        self.gaps: Deque[float] = collections.deque(maxlen=window)
        self.by_rid: Dict[int, Deque[float]] = {}
        self._per_rid_window = per_rid_window
        self._closed: Deque[int] = collections.deque()
        self._closed_window = closed_window
        self.sketches = {q: P2Quantile(q / 100.0) for q in sketch_qs}
        self.total_gaps = 0
        self._max = 0.0

    def observe(self, rid: int, t: float) -> None:
        last = self._last.get(rid)
        if last is not None:
            gap = t - last
            self.gaps.append(gap)
            self.by_rid.setdefault(
                rid, collections.deque(maxlen=self._per_rid_window)
            ).append(gap)
            for sk in self.sketches.values():
                sk.update(gap)
            self.total_gaps += 1
            self._max = max(self._max, gap)
        self._last[rid] = t

    def close(self, rid: int) -> None:
        """Forget a finished request's baseline; its gap history survives
        for the `closed_window` most recently closed requests, then the
        whole per-request deque is dropped (the dict itself is bounded,
        not just each deque)."""
        self._last.pop(rid, None)
        if self._closed_window is None:
            return
        if rid in self.by_rid:
            self._closed.append(rid)
        while len(self._closed) > self._closed_window:
            self.by_rid.pop(self._closed.popleft(), None)

    def reopen(self, rid: int, gaps: Sequence[float] = ()) -> None:
        """Re-seed a restored request's gap history after a host-side pause
        (serving snapshot/restore: preemption, prefill->decode handoff,
        drain migration). Deliberately sets NO baseline: the first token
        after resume records no gap, so wall time spent paused or in
        transit is never charged as an inter-token gap — without this every
        preempted request would spuriously blow its TBT SLO. The carried
        per-request gaps seed `by_rid` (so `attainment` stays correct) but
        are NOT re-fed to the aggregate window/sketches: they were already
        observed once, on the ledger that recorded them."""
        if gaps:
            self.by_rid[rid] = collections.deque(
                gaps, maxlen=self._per_rid_window)

    def max_gap(self) -> float:
        """Lifetime maximum gap (scalar — survives window eviction)."""
        return self._max

    def attainment(self, rid: int, slo: float) -> float:
        """Fraction of request `rid`'s retained gaps that met `slo` (its
        per-request TBT-SLO attainment; nan if no gaps recorded). Computed
        over the per-request window — call before the request ages out of
        `closed_window` for exact numbers."""
        gaps = self.by_rid.get(rid)
        if not gaps:
            return float("nan")
        return float(np.mean([g <= slo for g in gaps]))

    def report(self, qs: Sequence[float] = (50, 99)) -> Dict[str, float]:
        """Exact percentiles over the retained window, plus lifetime
        `max`/`n` and `p<q>_stream` P^2 estimates over everything ever
        observed (identical to the window stats until eviction starts)."""
        rep = percentile_report(self.gaps, qs)
        rep["max"] = self.max_gap()
        rep["n"] = float(self.total_gaps)
        for q, sk in self.sketches.items():
            rep[f"p{int(q)}_stream"] = sk.value()
        return rep


@dataclasses.dataclass(frozen=True)
class ReplicaLoad:
    """One engine replica's load snapshot (serving/cluster.py routing
    signal). All token counts are OUTSTANDING work, not historical: what
    the replica still has to do for everything it has accepted."""
    queue_depth: int        # requests waiting in the arrival queue
    queued_tokens: int      # their prompt tokens (prefill not started)
    prefill_backlog: int    # prompt tokens left for admitted 'prefilling'
    running: int            # requests in batched decode
    decode_backlog: int     # decode tokens outstanding (incl. prefilling
    #                         requests' full decode budget — committed work,
    #                         EXCEPT on role='prefill' replicas, whose
    #                         requests decode elsewhere after KV handoff)
    free_slots: int         # KV slots available for new admissions
    held: int = 0           # finished-prefill requests awaiting KV handoff
    #                         (role='prefill' replicas; they occupy a slot
    #                         but contribute no decode backlog here).
    #                         Host-PAUSED requests appear in NO field at all:
    #                         a snapshot released every engine resource, so
    #                         load — and AdmissionController.headroom, which
    #                         consumes these numbers — excludes them.

    @property
    def total_tokens(self) -> int:
        """Scalar load score: every token of work the replica has accepted
        but not yet produced (the least-loaded router's ranking key)."""
        return self.queued_tokens + self.prefill_backlog + self.decode_backlog


class Admission(enum.Enum):
    ADMIT = "admit"
    QUEUE = "queue"      # keep waiting: deadline still reachable later
    REJECT = "reject"    # predicted TTFT already breaches the deadline


class LatencyModel:
    """EWMA cost model observed from the live engine.

    prefill_per_token: seconds of prefill work per prompt token.
    decode_step: seconds per batched decode step (amortized over the batch
    by the caller if it wants per-token cost).
    Seeds are optimistic-but-nonzero so the first decisions are sane before
    any observation lands.
    """

    def __init__(self, alpha: float = 0.3, prefill_per_token: float = 1e-4,
                 decode_step: float = 1e-3):
        self.alpha = alpha
        self.prefill_per_token = prefill_per_token
        self.decode_step = decode_step
        self.n_prefills = 0
        self.n_steps = 0

    def _ewma(self, cur: float, obs: float) -> float:
        return (1 - self.alpha) * cur + self.alpha * obs

    def observe_prefill(self, n_tokens: int, wall_s: float) -> None:
        if n_tokens <= 0:
            return
        self.prefill_per_token = self._ewma(self.prefill_per_token,
                                            wall_s / n_tokens)
        self.n_prefills += 1

    def observe_decode_step(self, wall_s: float) -> None:
        self.decode_step = self._ewma(self.decode_step, wall_s)
        self.n_steps += 1

    def predict_prefill(self, n_tokens: int) -> float:
        return n_tokens * self.prefill_per_token

    def predict_tbt(self, chunk_budget: Optional[int] = None) -> float:
        """Steady-state inter-token gap a decoder sees per engine iteration:
        one batched decode step, plus — on a chunked engine — up to one
        prefill chunk of interference when prompts are prefilling. Monolithic
        engines (chunk_budget None) report the decode step only; their gaps
        are UNBOUNDED while a prefill runs (the whole point of chunking), so
        a monolithic prediction is a floor, not a guarantee."""
        gap = self.decode_step
        if chunk_budget is not None and chunk_budget > 0:
            gap += chunk_budget * self.prefill_per_token
        return gap

    def suggest_chunk(self, tbt_slo: float, floor: int = 1,
                      ceiling: int = 4096) -> int:
        """Largest prefill chunk (tokens) such that one chunk of prefill
        plus one batched decode step fits the inter-token-gap target:
        ``chunk * prefill_per_token + decode_step <= tbt_slo``. This is the
        chunk-size auto-tuner behind ``prefill_budget="auto"``
        (serving/batching.py): as the EWMA model tracks the live engine,
        the budget adapts instead of being a hand-chosen constant. Clamped
        to [floor, ceiling]; an unmeetable SLO degrades to `floor` (maximal
        chunking) rather than stalling prefill entirely."""
        room = tbt_slo - self.decode_step
        if room <= 0:
            return floor
        chunk = int(room / max(self.prefill_per_token, 1e-12))
        return int(np.clip(chunk, floor, ceiling))


class AdmissionController:
    """Predicts a candidate request's TTFT and gates admission on its SLO.

    Predicted TTFT = time already spent queued + prefill cost of the prompts
    queued ahead + the candidate's own prefill cost + decode interference.
    Monolithic engines prefill every same-round admission back-to-back
    inside one scheduler iteration, so only the single batched-step drain
    (new arrivals wait for the in-flight step to finish) separates the
    candidate from its first token. A chunked engine (`chunk_budget`)
    instead interleaves one batched decode step per chunk iteration, so
    with decoders running (`running_batch` > 0) the candidate pays one
    `decode_step` per ceil(total/chunk_budget) iterations.
    """

    def __init__(self, model: Optional[LatencyModel] = None,
                 default_ttft_slo: Optional[float] = None):
        self.model = model or LatencyModel()
        self.default_ttft_slo = default_ttft_slo
        self.n_rejected = 0

    def predict_ttft(self, now: float, arrival: float, prompt_len: int,
                     queued_tokens_ahead: int, *, running_batch: int = 0,
                     chunk_budget: Optional[int] = None) -> float:
        waited = max(now - arrival, 0.0)
        total = queued_tokens_ahead + prompt_len
        if chunk_budget is not None and chunk_budget > 0 and running_batch:
            steps = max(1, -(-total // chunk_budget))
        else:
            steps = 1
        return (waited + self.model.predict_prefill(total)
                + steps * self.model.decode_step)

    def decide(self, now: float, arrival: float, prompt_len: int,
               queued_tokens_ahead: int,
               ttft_slo: Optional[float] = None, *,
               running_batch: int = 0,
               chunk_budget: Optional[int] = None,
               tbt_slo: Optional[float] = None,
               chunk_adaptive: bool = False) -> Admission:
        """ADMIT if the predicted TTFT (incl. the backlog ahead) fits the
        deadline; QUEUE if only the backlog breaches it (it may drain, the
        deadline is still reachable); REJECT if even an immediate start
        would breach — the request is hopeless and is shed.

        tbt_slo (per-request): a structurally unmeetable inter-token-gap
        target is REJECTED outright — waiting never improves the steady
        per-step gap, so a QUEUE verdict would be a lie. The prediction
        charges the chunk the engine will actually run for this request:
        a fixed-budget engine keeps `chunk_budget` no matter what, while an
        adaptive one (`chunk_adaptive`, prefill_budget="auto") shrinks its
        chunk to the tightest in-flight tbt_slo — so only then does the
        check use min(current budget, suggest_chunk(tbt_slo))."""
        if tbt_slo is not None:
            cb = chunk_budget
            if cb is not None and chunk_adaptive:
                cb = min(cb, self.model.suggest_chunk(tbt_slo))
            if self.model.predict_tbt(cb) > tbt_slo:
                self.n_rejected += 1
                return Admission.REJECT
        slo = ttft_slo if ttft_slo is not None else self.default_ttft_slo
        if slo is None:
            return Admission.ADMIT
        if self.predict_ttft(now, arrival, prompt_len, queued_tokens_ahead,
                             running_batch=running_batch,
                             chunk_budget=chunk_budget) <= slo:
            return Admission.ADMIT
        if self.predict_ttft(now, arrival, prompt_len, 0,
                             running_batch=running_batch,
                             chunk_budget=chunk_budget) <= slo:
            return Admission.QUEUE
        self.n_rejected += 1
        return Admission.REJECT

    def headroom(self, now: float, arrival: float, prompt_len: int,
                 backlog_tokens: int, *,
                 ttft_slo: Optional[float] = None,
                 tbt_slo: Optional[float] = None,
                 running_batch: int = 0,
                 chunk_budget: Optional[int] = None,
                 chunk_adaptive: bool = False) -> float:
        """Worst-case SLO margin (seconds) this replica would leave the
        candidate: min over its deadlines of (slo - prediction). Positive =
        every deadline predicted met with that much slack; negative = at
        least one predicted breached; +inf when the request carries no SLO
        (then only load can rank replicas). The slo_headroom router
        dispatches to the max-headroom replica and rejects only when NO
        replica is non-negative."""
        h = float("inf")
        if tbt_slo is not None:
            cb = chunk_budget
            if cb is not None and chunk_adaptive:
                cb = min(cb, self.model.suggest_chunk(tbt_slo))
            h = min(h, tbt_slo - self.model.predict_tbt(cb))
        slo = ttft_slo if ttft_slo is not None else self.default_ttft_slo
        if slo is not None:
            h = min(h, slo - self.predict_ttft(
                now, arrival, prompt_len, backlog_tokens,
                running_batch=running_batch, chunk_budget=chunk_budget))
        return h
