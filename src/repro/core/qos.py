"""QoS metrics (paper §VI-A Metrics): TTFT, E2E, tail latency, throughput."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np


@dataclasses.dataclass
class QoSSummary:
    mean_ttft: float
    mean_e2e: float
    p50_e2e: float
    p95_e2e: float
    p99_e2e: float
    tokens_per_s: float
    peak_bytes: float
    hit_rate: float
    n_requests: int

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def summarize(ttfts: Sequence[float], e2es: Sequence[float],
              total_tokens: int, peak_bytes: float = 0.0,
              hit_rate: float = 0.0) -> QoSSummary:
    e = np.asarray(e2es, float)
    return QoSSummary(
        mean_ttft=float(np.mean(ttfts)),
        mean_e2e=float(e.mean()),
        p50_e2e=float(np.percentile(e, 50)),
        p95_e2e=float(np.percentile(e, 95)),
        p99_e2e=float(np.percentile(e, 99)),
        tokens_per_s=float(total_tokens / max(e.sum(), 1e-12)),
        peak_bytes=float(peak_bytes),
        hit_rate=float(hit_rate),
        n_requests=len(e2es),
    )


def slo_attainment(e2es: Sequence[float], slo: float) -> float:
    e = np.asarray(e2es, float)
    return float((e <= slo).mean())
