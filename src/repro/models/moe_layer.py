"""Mixture-of-Experts FFN with sort+capacity dispatch, expert-parallel ready.

Design (see DESIGN.md §4):
  * Tokens arrive replicated over the tensor axis (post-attention all-reduce,
    Megatron pattern). Each model-rank owns a contiguous slice of experts
    ('expert' sharding) or a slice of every expert's hidden dim ('hidden'
    sharding, used when E < mesh model size, e.g. Mixtral's 8 experts).
  * Dispatch is fully local: sort token-expert assignments, scatter into a
    per-rank capacity buffer [E_local, C, d], run the batched expert FFN,
    gather back, weight by router probs. The only collective is one psum of
    the combined output over the model axis per MoE layer — the same cost as
    a Megatron FFN all-reduce. No all-to-all is needed because activations
    are replicated over the tensor axis.
  * Experts whose count doesn't divide the axis are padded with dummy experts
    that the router can never select (qwen2-moe: 60 -> 64).
  * Shared experts are fused into one wide SwiGLU whose hidden dim is sharded
    over the model axis; their partial output folds into the same psum.

The capacity path (tokens above capacity dropped) is used for sharded
training and dry-run lowering; single-device calls default to an exact
capacity of T (top-k ids are distinct per token, so no expert can receive
more than T assignments), so prefill/decode/teacher-forced eval never drop
and agree
bit-for-tolerance. The *serving engine* uses the exact sequential per-expert
path (`expert_ffn_exact`) — that is the paper's own execution model (experts
run one at a time from a small cache).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.configs.base import ArchConfig
from repro.models.layers import PDT


def n_experts_padded(cfg: ArchConfig, n_model: int = 16) -> int:
    e = cfg.n_experts
    if e >= n_model and e % n_model:
        return -(-e // n_model) * n_model
    return e


def expert_shard_mode(cfg: ArchConfig, n_model: int = 16) -> str:
    """'expert' = experts over model axis; 'hidden' = d_expert over model."""
    return "expert" if cfg.n_experts >= n_model else "hidden"


def moe_params(key, cfg: ArchConfig, n_model: int = 16, dtype=PDT):
    d, de = cfg.d_model, cfg.d_expert
    ep = n_experts_padded(cfg, n_model)
    ks = jax.random.split(key, 7)
    p = {
        "router": (jax.random.normal(ks[0], (d, ep)) * d ** -0.5).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (ep, d, de)) * d ** -0.5).astype(dtype),
        "w3": (jax.random.normal(ks[2], (ep, d, de)) * d ** -0.5).astype(dtype),
        "w2": (jax.random.normal(ks[3], (ep, de, d)) * de ** -0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        sh = cfg.n_shared_experts * de
        p["sw1"] = (jax.random.normal(ks[4], (d, sh)) * d ** -0.5).astype(dtype)
        p["sw3"] = (jax.random.normal(ks[5], (d, sh)) * d ** -0.5).astype(dtype)
        p["sw2"] = (jax.random.normal(ks[6], (sh, d)) * sh ** -0.5).astype(dtype)
    return p


def route(x2d: jax.Array, router: jax.Array, n_real: int, top_k: int):
    """Router: returns (weights [T,k] f32, ids [T,k] i32, probs [T,E] f32)."""
    logits = x2d.astype(jnp.float32) @ router  # [T, E_pad]
    e_pad = router.shape[1]
    if e_pad > n_real:
        pad_mask = jnp.arange(e_pad) >= n_real
        logits = jnp.where(pad_mask[None], -1e9, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, ids, probs


def _dispatch_compute_combine(x2d, w, ids, w1, w3, w2, *, capacity: int,
                              e_start, active_max: Optional[int] = None,
                              use_pallas: bool = False) -> jax.Array:
    """Capacity dispatch against a local expert slice [E_loc, d, de].

    e_start: first global expert id owned locally (0 in 'hidden' mode).
    active_max (REPRO_OPT_ACTIVE_GATHER, §Perf): for tiny token counts
    (decode) gather only the `active_max` most-loaded local experts' weights
    instead of running the dense [E_loc, C, d] einsum over every local
    expert — HBM weight traffic drops E_loc/active_max x. Assignments beyond
    the A busiest local experts drop (capacity-style bound; the serving
    engine's exact path is unaffected).
    Returns the (partial) combined output [T, d].
    """
    T, d = x2d.shape
    k = ids.shape[1]
    e_loc = w1.shape[0]
    flat = ids.reshape(-1)  # [T*k]
    order = jnp.argsort(flat)
    sid = flat[order]
    stok = order // k
    # position of each assignment within its expert's run (sorted => runs)
    pos = jnp.arange(T * k) - jnp.searchsorted(sid, sid, side="left")
    lid = sid - e_start  # local expert id; OOB rows dropped by scatter/gather
    oob = (lid < 0) | (lid >= e_loc) | (pos >= capacity)

    if active_max is not None and active_max < e_loc:
        # loads per local expert -> top-A busiest; remap lid into [0, A)
        loads = jnp.zeros((e_loc,), jnp.int32).at[lid].add(
            (~oob).astype(jnp.int32), mode="drop")
        _, sel = lax.top_k(loads, active_max)          # [A] local ids
        inv_sel = jnp.full((e_loc,), -1, jnp.int32).at[sel].set(
            jnp.arange(active_max, dtype=jnp.int32))
        lid = inv_sel.at[jnp.clip(lid, 0, e_loc - 1)].get(mode="clip")
        oob = oob | (lid < 0)
        w1 = jnp.take(w1, sel, axis=0)                 # [A, d, de] gather
        w3 = jnp.take(w3, sel, axis=0)
        w2 = jnp.take(w2, sel, axis=0)
        e_loc = active_max

    buf = jnp.zeros((e_loc, capacity, d), x2d.dtype)
    buf = buf.at[lid, pos].set(
        jnp.where(oob[:, None], 0, x2d[stok]), mode="drop")
    if use_pallas:
        # grouped GEMMs via the double-buffered expert-streaming kernel
        # (the paper's prefill pipeline, TPU-native; interpret=True on CPU)
        from repro.kernels.ops import expert_ffn_op
        bf = min(512, w1.shape[2])
        y = expert_ffn_op(buf, w1, w3, w2, block_f=bf)
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, w1)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, w3)
        y = jnp.einsum("ecf,efd->ecd", h, w2)  # [E_loc, C, d]
    g = y.at[lid, pos].get(mode="fill", fill_value=0)  # [T*k, d]
    g = jnp.where(oob[:, None], 0, g)
    inv = jnp.argsort(order)
    g = g[inv].reshape(T, k, d)
    return (g.astype(jnp.float32) * w[..., None]).sum(1).astype(x2d.dtype)


def active_gather_max(t_loc: int, top_k: int, e_loc: int, e_pad: int
                      ) -> Optional[int]:
    """A = 2x the expected active local experts, floor top_k — None if the
    dense path is already as cheap (large-T training/prefill)."""
    from repro.models import opt_flags
    if not opt_flags.active_gather() or t_loc * top_k > 512:
        return None
    expected = t_loc * top_k * e_loc / max(e_pad, 1)
    a = int(max(top_k, -(-2 * expected // 1)))
    a = min(a, e_loc)
    return a if a < e_loc else None


def load_balance_loss(probs: jax.Array, ids: jax.Array, n_real: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e (over real experts)."""
    T, k = ids.shape
    sel = jax.nn.one_hot(ids, probs.shape[1], dtype=jnp.float32).sum(1)  # [T,E]
    f = sel.mean(0)                       # fraction routed (counts/T, sums to k)
    p = probs.mean(0)
    return n_real * jnp.sum(f[:n_real] * p[:n_real]) / k


def moe_ffn_local(x2d, p, cfg: ArchConfig, *, capacity: int, e_start=0,
                  axis: Optional[str] = None):
    """Local (per-shard) MoE FFN body. If `axis` is set, runs under shard_map
    and psums the combined output over that axis."""
    w, ids, probs = route(x2d, p["router"], cfg.n_experts, cfg.top_k)
    e_loc = p["w1"].shape[0]
    amax = active_gather_max(x2d.shape[0], cfg.top_k, e_loc,
                             n_experts_padded(cfg))
    import os
    y = _dispatch_compute_combine(
        x2d, w, ids, p["w1"], p["w3"], p["w2"], capacity=capacity,
        e_start=e_start, active_max=amax,
        use_pallas=os.environ.get("REPRO_MOE_PALLAS", "0") == "1")
    if "sw1" in p:
        h = jax.nn.silu(x2d @ p["sw1"]) * (x2d @ p["sw3"])
        y = y + h @ p["sw2"]
    if axis is not None:
        y = lax.psum(y, axis)
    aux = load_balance_loss(probs, ids, cfg.n_experts)
    return y, aux


def capacity_for(t_local: int, cfg: ArchConfig, e_pad: int,
                 factor: Optional[float] = None) -> int:
    f = cfg.capacity_factor if factor is None else factor
    c = int(t_local * cfg.top_k * f / max(e_pad, 1)) + 1
    c = min(-(-c // 8) * 8, t_local * cfg.top_k)
    return max(c, 8) if t_local >= 8 else max(c, cfg.top_k)


def moe_ffn(x, p, cfg: ArchConfig, *, mesh_info=None, capacity_factor=None):
    """MoE FFN on [B, S, d] (or [T, d]). Handles optional shard_map wrapping.

    mesh_info: None for single-device, else dict(mesh=Mesh, dp=(axes,),
    tp='model'). Expert weights must already be passed with global shapes;
    shard_map slices them via in_specs.
    """
    shp = x.shape
    x2d = x.reshape(-1, shp[-1]) if x.ndim == 3 else x
    e_pad = p["w1"].shape[0]

    if mesh_info is None:
        # Single-device dispatch is exact by default: top-k ids are distinct
        # per token, so no expert can receive more than T assignments and
        # capacity = T guarantees zero drops. Prefill, decode and teacher-
        # forced eval therefore agree, and the KV-cache exactness tests hold
        # for MoE families too. The capacity-bounded path stays available via
        # an explicit capacity_factor (and is always used under shard_map,
        # where the buffer bounds per-rank work).
        t_loc = x2d.shape[0]
        if capacity_factor is None:
            cap = t_loc
        else:
            cap = capacity_for(t_loc, cfg, e_pad, capacity_factor)
        y, aux = moe_ffn_local(x2d, p, cfg, capacity=cap)
        return y.reshape(shp), aux

    mesh, dp, tp = mesh_info["mesh"], mesh_info["dp"], mesh_info["tp"]
    n_model = mesh.shape[tp]
    mode = expert_shard_mode(cfg, n_model)
    P = jax.sharding.PartitionSpec
    if mode == "expert":
        wspec = {"router": P(), "w1": P(tp), "w3": P(tp), "w2": P(tp)}
        e_loc = e_pad // n_model
    else:
        wspec = {"router": P(), "w1": P(None, None, tp), "w3": P(None, None, tp),
                 "w2": P(None, tp, None)}
        e_loc = e_pad
    if "sw1" in p:
        wspec.update({"sw1": P(None, tp), "sw3": P(None, tp), "sw2": P(tp, None)})

    B = shp[0]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if B % n_dp:  # tiny/odd batches: replicate tokens over the data axes
        dp = ()
        n_dp = 1
    t_loc = (B // n_dp) * (shp[1] if x.ndim == 3 else 1)
    cap = capacity_for(t_loc, cfg, e_pad, capacity_factor)

    def body(xl, pl):
        xl2 = xl.reshape(-1, xl.shape[-1])
        if mode == "expert":
            e0 = lax.axis_index(tp) * e_loc
        else:
            e0 = 0
        y, aux = moe_ffn_local(xl2, pl, cfg, capacity=cap, e_start=e0, axis=tp)
        aux = lax.pmean(aux, dp + (tp,))
        return y.reshape(xl.shape), aux

    xspec = P(dp, *([None] * (x.ndim - 1)))
    out = compat.shard_map(
        body, mesh=mesh, in_specs=(xspec, wspec),
        out_specs=(xspec, P()), check_vma=False)(x, {k: p[k] for k in wspec})
    return out


# ---------------------------------------------------------------------------
# exact per-expert path (serving engine; paper's execution model) + oracle
# ---------------------------------------------------------------------------


def expert_ffn_exact(x2d, w, ids, expert_weights):
    """Sequential exact MoE: loop experts, mask-select tokens (no drops).

    expert_weights: list of (w1, w3, w2) per real expert — in the engine these
    come from the *device expert cache*, not a monolithic array.
    """
    T, d = x2d.shape
    y = jnp.zeros((T, d), jnp.float32)
    for e, (w1, w3, w2) in enumerate(expert_weights):
        m = (ids == e)                       # [T, k]
        gate = (w * m).sum(-1)               # [T]
        h = jax.nn.silu(x2d @ w1) * (x2d @ w3)
        y = y + (h @ w2).astype(jnp.float32) * gate[:, None]
    return y.astype(x2d.dtype)


def moe_ffn_ref(x2d, p, cfg: ArchConfig):
    """Dense-loop oracle (no capacity drops) for tests."""
    w, ids, probs = route(x2d, p["router"], cfg.n_experts, cfg.top_k)
    ew = [(p["w1"][e], p["w3"][e], p["w2"][e]) for e in range(cfg.n_experts)]
    y = expert_ffn_exact(x2d, w, ids, ew)
    if "sw1" in p:
        h = jax.nn.silu(x2d @ p["sw1"]) * (x2d @ p["sw3"])
        y = y + h @ p["sw2"]
    return y, load_balance_loss(probs, ids, cfg.n_experts)
