"""Mamba2 SSD (state-space duality) layer — chunked scan + O(1) decode step.

Follows arXiv:2405.21060: per-head scalar decay A, depthwise causal conv on
(x, B, C), softplus dt, gated RMSNorm output. The chunked form computes
intra-chunk contributions as a decay-masked attention-like matmul (MXU
friendly) and carries inter-chunk states through a lax.scan — the same
structure the Pallas ``ssd_scan`` kernel implements with explicit VMEM tiles.

in_proj is split into separate z/x/B/C/dt matrices so tensor-parallel sharding
is expressible per-matrix (x/z/dt sharded over heads, B/C replicated when
ngroups=1). Head axis shards over 'model'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models.layers import PDT, rms_norm


def ssm_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def ssm_params(key, cfg: ArchConfig, dtype=PDT):
    d = cfg.d_model
    n, g, kconv = cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv
    d_inner, h, _ = ssm_dims(cfg)
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    return {
        "wz": (jax.random.normal(ks[0], (d, d_inner)) * s).astype(dtype),
        "wx": (jax.random.normal(ks[1], (d, d_inner)) * s).astype(dtype),
        "wB": (jax.random.normal(ks[2], (d, g * n)) * s).astype(dtype),
        "wC": (jax.random.normal(ks[3], (d, g * n)) * s).astype(dtype),
        "wdt": (jax.random.normal(ks[4], (d, h)) * s).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (d_inner, kconv)) * 0.3).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (g * n, kconv)) * 0.3).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (g * n, kconv)) * 0.3).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[8], (d_inner, d)) * d_inner ** -0.5).astype(dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. u: [B,S,C]; w: [C,K] -> [B,S,C]."""
    k = w.shape[1]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_k u[t-K+1+k] * w[:, k]
    out = sum(up[:, i:i + u.shape[1]] * w[:, i] for i in range(k))
    return out


def _conv_step(state: jax.Array, new: jax.Array, w: jax.Array):
    """Ring-free conv state step. state: [B,C,K]; new: [B,C]; w: [C,K]."""
    state = jnp.concatenate([state[:, :, 1:], new[:, :, None]], axis=2)
    return (state * w[None]).sum(-1), state


def _project(x, p, cfg: ArchConfig):
    z = x @ p["wz"]
    xs = x @ p["wx"]
    bv = x @ p["wB"]
    cv = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    return z, xs, bv, cv, dt


def ssd_forward(x, p, cfg: ArchConfig, chunk: int = 256):
    """Full-sequence SSD. x: [B,S,d] -> (y [B,S,d], final_state, conv_states)."""
    B, S, d = x.shape
    n, g = cfg.ssm_state, cfg.ssm_groups
    pdim = cfg.ssm_head_dim
    d_inner, h, _ = ssm_dims(cfg)
    z, xs, bv, cv, dt = _project(x, p, cfg)

    # conv tail states (last K raw inputs per stream) for decode continuation
    k = cfg.ssm_conv

    def tail(u):  # [B,S,C] -> [B,C,K]
        up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
        return up[:, -k:].transpose(0, 2, 1)

    conv_tails = {"x": tail(xs), "B": tail(bv), "C": tail(cv)}
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x"]))
    bv = jax.nn.silu(_causal_conv(bv, p["conv_B"]))
    cv = jax.nn.silu(_causal_conv(cv, p["conv_C"]))

    q = min(chunk, S)
    nc = -(-S // q)
    pad = nc * q - S

    def pads(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xs, bv, cv, dt = pads(xs), pads(bv), pads(cv), pads(dt)
    xh = xs.reshape(B, nc, q, h, pdim).astype(jnp.float32)
    rep = h // g
    bh = jnp.repeat(bv.reshape(B, nc, q, g, n), rep, axis=3).astype(jnp.float32)
    ch = jnp.repeat(cv.reshape(B, nc, q, g, n), rep, axis=3).astype(jnp.float32)
    dtc = dt.reshape(B, nc, q, h)
    a = -jnp.exp(p["A_log"])          # [h], negative decay rate
    da = dtc * a                      # [B,nc,q,h]
    cs = jnp.cumsum(da, axis=2)       # inclusive cumsum within chunk

    # intra-chunk: y_t += sum_{j<=t} exp(cs_t - cs_j) dt_j (C_t.B_j) x_j
    gmat = jnp.einsum("bcqhn,bckhn->bchqk", ch, bh)
    tri = jnp.tril(jnp.ones((q, q), bool))
    ldec = jnp.where(tri[None, None, None],
                     jnp.exp(cs.transpose(0, 1, 3, 2)[..., :, None]
                             - cs.transpose(0, 1, 3, 2)[..., None, :]), 0.0)
    m = gmat * ldec * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", m, xh)

    # chunk states: S_c = sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j
    dec_end = jnp.exp(cs[:, :, -1:, :] - cs) * dtc  # [B,nc,q,h]
    s_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", dec_end, bh, xh)
    chunk_decay = jnp.exp(cs[:, :, -1])  # [B,nc,h]

    def step(hst, xs_):
        sc, cdec, ch_c, cs_c = xs_
        y_inter = jnp.einsum("bqhn,bhnp,bqh->bqhp", ch_c, hst, jnp.exp(cs_c))
        hst = cdec[..., None, None] * hst + sc
        return hst, y_inter

    h0 = jnp.zeros((B, h, n, pdim), jnp.float32)
    hfin, y_inter = lax.scan(
        step, h0,
        (s_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2),
         ch.transpose(1, 0, 2, 3, 4), cs.transpose(1, 0, 2, 3)))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # [B,nc,q,h,p]

    y = y_intra + y_inter + p["D"][None, None, None, :, None] * xh
    y = y.reshape(B, nc * q, d_inner)[:, :S]
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["norm"], cfg.rms_eps)
    out = y @ p["out_proj"]
    return out, hfin, conv_tails


def ssd_decode_step(x, p, cfg: ArchConfig, ssm_state, conv_states):
    """One-token step. x: [B,1,d]; ssm_state: [B,h,n,p];
    conv_states: dict of [B,C,K]. Returns (y [B,1,d], new_ssm, new_conv)."""
    B = x.shape[0]
    n, g = cfg.ssm_state, cfg.ssm_groups
    pdim = cfg.ssm_head_dim
    d_inner, h, _ = ssm_dims(cfg)
    z, xs, bv, cv, dt = _project(x[:, 0], p, cfg)
    xs, cx = _conv_step(conv_states["x"], xs, p["conv_x"])
    bv, cb = _conv_step(conv_states["B"], bv, p["conv_B"])
    cv, cc = _conv_step(conv_states["C"], cv, p["conv_C"])
    xs, bv, cv = jax.nn.silu(xs), jax.nn.silu(bv), jax.nn.silu(cv)

    xh = xs.reshape(B, h, pdim).astype(jnp.float32)
    rep = h // g
    bh = jnp.repeat(bv.reshape(B, g, n), rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(cv.reshape(B, g, n), rep, axis=1).astype(jnp.float32)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)  # [B,h]
    new_state = (da[..., None, None] * ssm_state
                 + jnp.einsum("bh,bhn,bhp->bhnp", dt, bh, xh))
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_state) + p["D"][None, :, None] * xh
    y = y.reshape(B, d_inner)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["norm"], cfg.rms_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, new_state, {"x": cx, "B": cb, "C": cc}


def ssd_ref(x, p, cfg: ArchConfig):
    """Sequential-recurrence oracle for tests: step token by token."""
    B, S, d = x.shape
    d_inner, h, _ = ssm_dims(cfg)
    state = jnp.zeros((B, h, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32)
    k = cfg.ssm_conv
    conv = {
        "x": jnp.zeros((B, d_inner, k), x.dtype),
        "B": jnp.zeros((B, cfg.ssm_groups * cfg.ssm_state, k), x.dtype),
        "C": jnp.zeros((B, cfg.ssm_groups * cfg.ssm_state, k), x.dtype),
    }
    ys = []
    for t in range(S):
        y, state, conv = ssd_decode_step(x[:, t:t + 1], p, cfg, state, conv)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=PDT):
    d_inner, h, _ = ssm_dims(cfg)
    k = cfg.ssm_conv
    gn = cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, h, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, d_inner, k), dtype),
        "conv_B": jnp.zeros((batch, gn, k), dtype),
        "conv_C": jnp.zeros((batch, gn, k), dtype),
    }
