"""Unified model builder for all assigned architecture families.

Every family exposes the same four entry points through ``build(cfg)``:

  * ``init(key)``            -> params pytree (bf16; call under eval_shape for
                                 abstract dry-run params)
  * ``forward(params, batch)``-> (logits [B,S,Vp], aux) — teacher-forced, used
                                 by train_step
  * ``prefill(params, batch)``-> (last_logits [B,Vp], cache)
  * ``decode_step(params, step, cache)`` -> (logits [B,Vp], cache)

Layers are stacked and driven by ``lax.scan`` so compile time is O(1) in
depth (88–100-layer configs lower in seconds). Heterogeneous stacks use
pattern-block nesting (VLM: 20×[4 self + 1 cross]; Zamba2: 13×[6 mamba +
shared-attn] + 3 tail) instead of per-layer branching.

Decode uses a ring-buffer KV cache with absolute slot positions (exact for
sliding-window and bounded long-context decode). ``step`` = {'token': [B,1]}.
``batch`` = {'tokens': [B,S]} (+ 'patch_embeds' for vlm, 'frames' for
audio enc-dec).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe_layer as M
from repro.models import ssm as S
from repro.models.layers import PDT

CHUNKED_MIN_SEQ = 2048  # use flash-style chunked attention above this length


def attn_dims(cfg: ArchConfig) -> L.AttnDims:
    return L.AttnDims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                      cfg.qk_norm, cfg.qkv_bias, cfg.rope_theta, cfg.rms_eps)


def _stack_init(fn: Callable, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _logits(x, embed):
    return (x @ embed.T.astype(x.dtype))


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    forward: Callable      # (params, batch) -> (logits, aux)
    prefill: Callable      # (params, batch) -> (last_logits, cache)
    decode_step: Callable  # (params, step, cache) -> (logits, cache)
    init_cache: Callable   # (batch_size, capacity, batch_extras) -> cache
    ring_axes: Dict[str, int] = dataclasses.field(default_factory=dict)


def pad_cache(cache, new_capacity: int, ring_axes: Dict[str, int]):
    """Grow ring-buffer KV caches to `new_capacity` slots.

    Valid immediately after prefill (entries at slot == pos, or a full rolled
    ring): appended empty slots keep the invariant slot == pos % capacity as
    long as the prefill length <= old capacity <= new capacity.
    """
    new = dict(cache)
    for k, ax in ring_axes.items():
        if k not in cache:
            continue
        arr = cache[k]
        extra = new_capacity - arr.shape[ax]
        if extra <= 0:
            continue
        pads = [(0, 0)] * arr.ndim
        pads[ax] = (0, extra)
        new[k] = jnp.pad(arr, pads)
    sp = cache.get("slot_pos")
    if sp is not None and sp.shape[0] < new_capacity:
        new["slot_pos"] = jnp.pad(sp, (0, new_capacity - sp.shape[0]),
                                  constant_values=-1)
    return new


# ---------------------------------------------------------------------------
# dense / moe decoder family (qwen3, granite, qwen1.5, gemma3, qwen2-moe,
# kimi-k2, mixtral)
# ---------------------------------------------------------------------------


def _dense_block_params(key, cfg: ArchConfig, d_ff: int):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_params(cfg.d_model),
        "attn": L.attn_params(k1, attn_dims(cfg)),
        "ln2": L.norm_params(cfg.d_model),
        "mlp": L.mlp_params(k2, cfg.d_model, d_ff),
    }


def _moe_block_params(key, cfg: ArchConfig, n_model: int):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.norm_params(cfg.d_model),
        "attn": L.attn_params(k1, attn_dims(cfg)),
        "ln2": L.norm_params(cfg.d_model),
        "moe": M.moe_params(k2, cfg, n_model),
    }


def _ffn_apply(xn, lp, cfg: ArchConfig, mesh_info, dropless: bool):
    if "moe" in lp:
        cf = None
        if dropless:
            cf = float(cfg.top_k * M.n_experts_padded(cfg))  # => C = T*k
        return M.moe_ffn(xn, lp["moe"], cfg, mesh_info=mesh_info,
                         capacity_factor=cf)
    return L.swiglu(xn, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"]), 0.0


def _seq_parallel_pin(x, mesh_info):
    """REPRO_OPT_SEQ_PARALLEL (§Perf): residual stream seq-sharded over the
    tensor axis between blocks -> GSPMD lowers the block-output all-reduces
    to reduce-scatter + all-gather (Megatron sequence parallelism)."""
    from repro.models import opt_flags
    if mesh_info is None or not opt_flags.seq_parallel() or x.ndim != 3:
        return x
    mesh, dp, tp = mesh_info["mesh"], mesh_info["dp"], mesh_info["tp"]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if x.shape[0] % n_dp or x.shape[1] % mesh.shape[tp]:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, tp, None)))


def _block_full(x, lp, win, cfg, mesh_info, chunked, emit_kv):
    h, kv = L.self_attn_full(L.rms_norm(x, lp["ln1"], cfg.rms_eps), lp["attn"],
                             attn_dims(cfg), window=win, chunked=chunked)
    x = _seq_parallel_pin(x + h, mesh_info)
    y, aux = _ffn_apply(L.rms_norm(x, lp["ln2"], cfg.rms_eps), lp, cfg,
                        mesh_info, dropless=False)
    return _seq_parallel_pin(x + y, mesh_info), (kv if emit_kv else None), aux


def _block_decode(x, lp, win, cfg, mesh_info, ck, cv, sp, slot, pos):
    h, ck, cv = L.self_attn_decode(
        L.rms_norm(x, lp["ln1"], cfg.rms_eps), lp["attn"], attn_dims(cfg),
        ck, cv, sp, slot, pos, window=win)
    x = x + h
    y, aux = _ffn_apply(L.rms_norm(x, lp["ln2"], cfg.rms_eps), lp, cfg,
                        mesh_info, dropless=True)
    return x + y, ck, cv, aux


def build_dense(cfg: ArchConfig, mesh_info=None) -> ModelBundle:
    vp = L.vocab_pad_of(cfg.vocab)
    n_model = mesh_info["mesh"].shape[mesh_info["tp"]] if mesh_info else 16
    n_scan = cfg.n_layers - cfg.n_dense_layers
    windows = jnp.array(
        [cfg.window_for_layer(l) for l in range(cfg.n_dense_layers, cfg.n_layers)],
        jnp.int32)

    def init(key):
        ks = jax.random.split(key, 4)
        block = ((lambda k: _moe_block_params(k, cfg, n_model)) if cfg.is_moe
                 else (lambda k: _dense_block_params(k, cfg, cfg.d_ff)))
        p = {
            "embed": L.embed_params(ks[0], vp, cfg.d_model),
            "ln_f": L.norm_params(cfg.d_model),
            "layers": _stack_init(block, ks[1], n_scan),
        }
        if cfg.n_dense_layers:
            p["dense0"] = _stack_init(
                lambda k: _dense_block_params(k, cfg, cfg.dense_d_ff),
                ks[2], cfg.n_dense_layers)
        return p

    def forward(params, batch):
        tokens = batch["tokens"]
        Bsz, Ssz = tokens.shape
        x = params["embed"].at[tokens].get(mode="clip")
        chunked = Ssz >= CHUNKED_MIN_SEQ
        aux0 = 0.0
        if cfg.n_dense_layers:
            @jax.checkpoint
            def body0(carry, lp):
                xx, aux = carry
                xx, _, a = _block_full(xx, lp, jnp.int32(-1), cfg, mesh_info,
                                       chunked, False)
                return (xx, aux + a), None
            (x, aux0), _ = lax.scan(body0, (x, 0.0), params["dense0"])

        @jax.checkpoint
        def body(carry, xs):
            xx, aux = carry
            lp, win = xs
            xx, _, a = _block_full(xx, lp, win, cfg, mesh_info, chunked, False)
            return (xx, aux + a), None

        (x, aux), _ = lax.scan(body, (x, aux0), (params["layers"], windows))
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x, params["embed"]), aux / cfg.n_layers

    def prefill(params, batch):
        tokens = batch["tokens"]
        Bsz, Ssz = tokens.shape
        x = params["embed"].at[tokens].get(mode="clip")
        chunked = Ssz >= CHUNKED_MIN_SEQ
        caches = {}
        if cfg.n_dense_layers:
            def body0(carry, lp):
                xx, kv, a = _block_full(carry, lp, jnp.int32(-1), cfg,
                                        mesh_info, chunked, True)
                return xx, kv
            x, kv0 = lax.scan(body0, x, params["dense0"])
            caches["k0"], caches["v0"] = kv0

        def body(carry, xs):
            lp, win = xs
            xx, kv, a = _block_full(carry, lp, win, cfg, mesh_info, chunked, True)
            return xx, kv

        x, (ks_, vs_) = lax.scan(body, x, (params["layers"], windows))
        caches["k"], caches["v"] = ks_, vs_
        caches["slot_pos"] = jnp.arange(Ssz, dtype=jnp.int32)
        caches["pos"] = jnp.int32(Ssz)
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x[:, -1], params["embed"]), caches

    def decode_step(params, step, cache):
        token = step["token"]
        x = params["embed"].at[token].get(mode="clip")
        pos = cache["pos"]
        W = cache["k"].shape[2]
        slot = pos % W
        sp = lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
        new = dict(cache)
        if cfg.n_dense_layers:
            def body0(carry, xs):
                lp, ck, cv = xs
                xx, ck, cv, _ = _block_decode(carry, lp, jnp.int32(-1), cfg,
                                              mesh_info, ck, cv, sp, slot, pos)
                return xx, (ck, cv)
            x, (k0, v0) = lax.scan(body0, x,
                                   (params["dense0"], cache["k0"], cache["v0"]))
            new["k0"], new["v0"] = k0, v0

        def body(carry, xs):
            lp, win, ck, cv = xs
            xx, ck, cv, _ = _block_decode(carry, lp, win, cfg, mesh_info,
                                          ck, cv, sp, slot, pos)
            return xx, (ck, cv)

        x, (ks_, vs_) = lax.scan(
            body, x, (params["layers"], windows, cache["k"], cache["v"]))
        new.update(k=ks_, v=vs_, slot_pos=sp, pos=pos + 1)
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x[:, -1], params["embed"]), new

    def init_cache(batch_size, capacity, extras=None):
        hkv, hd = cfg.n_kv_heads, cfg.hd
        c = {
            "k": jnp.zeros((n_scan, batch_size, capacity, hkv, hd), PDT),
            "v": jnp.zeros((n_scan, batch_size, capacity, hkv, hd), PDT),
            "slot_pos": jnp.full((capacity,), -1, jnp.int32),
            "pos": jnp.int32(0),
        }
        if cfg.n_dense_layers:
            c["k0"] = jnp.zeros((cfg.n_dense_layers, batch_size, capacity, hkv, hd), PDT)
            c["v0"] = jnp.zeros_like(c["k0"])
        return c

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_cache,
                       ring_axes={"k": 2, "v": 2, "k0": 2, "v0": 2})


# ---------------------------------------------------------------------------
# pattern-block dense variant (REPRO_OPT_STATIC_WINDOW, §Perf):
# local:global stacks (gemma3) scan over pattern blocks with STATIC windows
# per inner position, enabling the band-restricted attention path.
# ---------------------------------------------------------------------------


def build_dense_pattern(cfg: ArchConfig, mesh_info=None) -> ModelBundle:
    assert cfg.local_global_pattern and not cfg.is_moe \
        and not cfg.n_dense_layers
    vp = L.vocab_pad_of(cfg.vocab)
    per = cfg.local_global_pattern + 1
    n_pat = cfg.n_layers // per
    n_tail = cfg.n_layers - n_pat * per
    win_in = [cfg.window_for_layer(i) for i in range(per)]          # static
    win_tail = [cfg.window_for_layer(n_pat * per + i) for i in range(n_tail)]

    def init(key):
        ks = jax.random.split(key, 3)
        blk = lambda k: _dense_block_params(k, cfg, cfg.d_ff)
        p = {
            "embed": L.embed_params(ks[0], vp, cfg.d_model),
            "ln_f": L.norm_params(cfg.d_model),
            "blocks": jax.vmap(lambda k: _stack_init(blk, k, per))(
                jax.random.split(ks[1], n_pat)),
        }
        if n_tail:
            p["tail"] = _stack_init(blk, ks[2], n_tail)
        return p

    def _run_full(params, tokens, emit_kv):
        Bsz, Ssz = tokens.shape
        chunked = Ssz >= CHUNKED_MIN_SEQ
        x = params["embed"].at[tokens].get(mode="clip")

        def outer(x, blk):
            kvs = []
            for i in range(per):
                lp = jax.tree.map(lambda a: a[i], blk)
                x, kv, _ = _block_full(x, lp, win_in[i], cfg, mesh_info,
                                       chunked, emit_kv)
                if emit_kv:
                    kvs.append(kv)
            ys = (jnp.stack([k for k, _ in kvs]),
                  jnp.stack([v for _, v in kvs])) if emit_kv else None
            return x, ys

        body = outer if emit_kv else jax.checkpoint(
            lambda c, b: outer(c, b))
        x, kvs = lax.scan(body, x, params["blocks"])
        tail_kv = None
        if n_tail:
            tk, tv = [], []
            for i in range(n_tail):
                lp = jax.tree.map(lambda a: a[i], params["tail"])
                x, kv, _ = _block_full(x, lp, win_tail[i], cfg, mesh_info,
                                       chunked, emit_kv)
                if emit_kv:
                    tk.append(kv[0])
                    tv.append(kv[1])
            if emit_kv:
                tail_kv = (jnp.stack(tk), jnp.stack(tv))
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return x, kvs, tail_kv

    def forward(params, batch):
        x, _, _ = _run_full(params, batch["tokens"], emit_kv=False)
        return _logits(x, params["embed"]), 0.0

    def prefill(params, batch):
        Ssz = batch["tokens"].shape[1]
        x, (ks_, vs_), tail_kv = _run_full(params, batch["tokens"], True)
        cache = {"k": ks_, "v": vs_,
                 "slot_pos": jnp.arange(Ssz, dtype=jnp.int32),
                 "pos": jnp.int32(Ssz)}
        if n_tail:
            cache["kt"], cache["vt"] = tail_kv
        return _logits(x[:, -1], params["embed"]), cache

    def decode_step(params, step, cache):
        token = step["token"]
        x = params["embed"].at[token].get(mode="clip")
        pos = cache["pos"]
        W = cache["k"].shape[3]
        slot = pos % W
        sp = lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))

        def outer(x, xs):
            blk, ck, cv = xs
            nk, nv = [], []
            for i in range(per):
                lp = jax.tree.map(lambda a: a[i], blk)
                x, cki, cvi, _ = _block_decode(
                    x, lp, jnp.int32(win_in[i]), cfg, mesh_info,
                    ck[i], cv[i], sp, slot, pos)
                nk.append(cki)
                nv.append(cvi)
            return x, (jnp.stack(nk), jnp.stack(nv))

        x, (ks_, vs_) = lax.scan(outer, x,
                                 (params["blocks"], cache["k"], cache["v"]))
        new = dict(cache)
        if n_tail:
            tk, tv = [], []
            for i in range(n_tail):
                lp = jax.tree.map(lambda a: a[i], params["tail"])
                x, cki, cvi, _ = _block_decode(
                    x, lp, jnp.int32(win_tail[i]), cfg, mesh_info,
                    cache["kt"][i], cache["vt"][i], sp, slot, pos)
                tk.append(cki)
                tv.append(cvi)
            new["kt"], new["vt"] = jnp.stack(tk), jnp.stack(tv)
        new.update(k=ks_, v=vs_, slot_pos=sp, pos=pos + 1)
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x[:, -1], params["embed"]), new

    def init_cache(batch_size, capacity, extras=None):
        hkv, hd = cfg.n_kv_heads, cfg.hd
        c = {
            "k": jnp.zeros((n_pat, per, batch_size, capacity, hkv, hd), PDT),
            "v": jnp.zeros((n_pat, per, batch_size, capacity, hkv, hd), PDT),
            "slot_pos": jnp.full((capacity,), -1, jnp.int32),
            "pos": jnp.int32(0),
        }
        if n_tail:
            c["kt"] = jnp.zeros((n_tail, batch_size, capacity, hkv, hd), PDT)
            c["vt"] = jnp.zeros_like(c["kt"])
        return c

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_cache,
                       ring_axes={"k": 3, "v": 3, "kt": 2, "vt": 2})


# ---------------------------------------------------------------------------
# vlm family (llama-3.2-vision): blocks of [n_self self-attn + 1 cross-attn]
# ---------------------------------------------------------------------------


def build_vlm(cfg: ArchConfig, mesh_info=None) -> ModelBundle:
    vp = L.vocab_pad_of(cfg.vocab)
    n_self = cfg.cross_attn_every - 1  # 4 self per cross
    n_blocks = cfg.n_layers // cfg.cross_attn_every
    assert n_blocks * cfg.cross_attn_every == cfg.n_layers

    def cross_block_params(key):
        p = _dense_block_params(key, cfg, cfg.d_ff)
        p["gate_attn"] = jnp.zeros((), PDT)
        p["gate_mlp"] = jnp.zeros((), PDT)
        return p

    def init(key):
        ks = jax.random.split(key, 4)
        return {
            "embed": L.embed_params(ks[0], vp, cfg.d_model),
            "ln_f": L.norm_params(cfg.d_model),
            "proj": (jax.random.normal(ks[1], (cfg.frontend_dim, cfg.d_model))
                     * cfg.frontend_dim ** -0.5).astype(PDT),
            "blocks": {
                "self": jax.vmap(lambda k: _stack_init(
                    lambda kk: _dense_block_params(kk, cfg, cfg.d_ff), k, n_self)
                )(jax.random.split(ks[2], n_blocks)),
                "cross": _stack_init(cross_block_params, ks[3], n_blocks),
            },
        }

    def _cross_apply_full(x, cp, mem_k, mem_v):
        h = L.cross_attn_full(L.rms_norm(x, cp["ln1"], cfg.rms_eps), cp["attn"],
                              attn_dims(cfg), mem_k, mem_v)
        x = x + jnp.tanh(cp["gate_attn"].astype(jnp.float32)).astype(x.dtype) * h
        y = L.swiglu(L.rms_norm(x, cp["ln2"], cfg.rms_eps),
                     cp["mlp"]["w1"], cp["mlp"]["w3"], cp["mlp"]["w2"])
        return x + jnp.tanh(cp["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * y

    def _run_full(params, batch, emit_kv):
        tokens = batch["tokens"]
        Bsz, Ssz = tokens.shape
        chunked = Ssz >= CHUNKED_MIN_SEQ
        mem = (batch["patch_embeds"].astype(PDT) @ params["proj"])
        x = params["embed"].at[tokens].get(mode="clip")

        def outer(x, blk):
            def inner(carry, lp):
                xx, kv, _ = _block_full(carry, lp, jnp.int32(-1), cfg,
                                        mesh_info, chunked, emit_kv)
                return xx, kv
            if not emit_kv:
                inner = jax.checkpoint(inner)
            x, kvs = lax.scan(inner, x, blk["self"])
            mk, mv = L.cross_kv(mem, blk["cross"]["attn"], attn_dims(cfg))
            x = _cross_apply_full(x, blk["cross"], mk, mv)
            return x, (kvs, (mk, mv))

        outer_body = (lambda c, blk: outer(c, blk)) if emit_kv else \
            jax.checkpoint(lambda c, blk: outer(c, blk))
        x, (self_kv, cross_kv) = lax.scan(outer_body, x, params["blocks"])
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return x, self_kv, cross_kv

    def forward(params, batch):
        x, _, _ = _run_full(params, batch, emit_kv=False)
        return _logits(x, params["embed"]), 0.0

    def prefill(params, batch):
        Ssz = batch["tokens"].shape[1]
        x, (ks_, vs_), (mk, mv) = _run_full(params, batch, emit_kv=True)
        cache = {
            "k": ks_, "v": vs_, "mk": mk, "mv": mv,
            "slot_pos": jnp.arange(Ssz, dtype=jnp.int32),
            "pos": jnp.int32(Ssz),
        }
        return _logits(x[:, -1], params["embed"]), cache

    def decode_step(params, step, cache):
        token = step["token"]
        x = params["embed"].at[token].get(mode="clip")
        pos = cache["pos"]
        W = cache["k"].shape[3]
        slot = pos % W
        sp = lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))

        def outer(x, xs):
            blk, ck, cv, mk, mv = xs

            def inner(carry, ys):
                lp, ckl, cvl = ys
                xx, ckl, cvl, _ = _block_decode(carry, lp, jnp.int32(-1), cfg,
                                                mesh_info, ckl, cvl, sp, slot, pos)
                return xx, (ckl, cvl)

            x, kv = lax.scan(inner, x, (blk["self"], ck, cv))
            cp = blk["cross"]
            h = L.cross_attn_decode(L.rms_norm(x, cp["ln1"], cfg.rms_eps),
                                    cp["attn"], attn_dims(cfg), mk, mv)
            x = x + jnp.tanh(cp["gate_attn"].astype(jnp.float32)).astype(x.dtype) * h
            y = L.swiglu(L.rms_norm(x, cp["ln2"], cfg.rms_eps),
                         cp["mlp"]["w1"], cp["mlp"]["w3"], cp["mlp"]["w2"])
            x = x + jnp.tanh(cp["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * y
            return x, kv

        x, (ks_, vs_) = lax.scan(
            outer, x, (params["blocks"], cache["k"], cache["v"],
                       cache["mk"], cache["mv"]))
        new = dict(cache)
        new.update(k=ks_, v=vs_, slot_pos=sp, pos=pos + 1)
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x[:, -1], params["embed"]), new

    def init_cache(batch_size, capacity, extras=None):
        hkv, hd = cfg.n_kv_heads, cfg.hd
        p_tok = cfg.n_frontend_tokens
        return {
            "k": jnp.zeros((n_blocks, n_self, batch_size, capacity, hkv, hd), PDT),
            "v": jnp.zeros((n_blocks, n_self, batch_size, capacity, hkv, hd), PDT),
            "mk": jnp.zeros((n_blocks, batch_size, p_tok, hkv, hd), PDT),
            "mv": jnp.zeros((n_blocks, batch_size, p_tok, hkv, hd), PDT),
            "slot_pos": jnp.full((capacity,), -1, jnp.int32),
            "pos": jnp.int32(0),
        }

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_cache,
                       ring_axes={"k": 3, "v": 3})


# ---------------------------------------------------------------------------
# encoder-decoder family (seamless-m4t): audio frames -> encoder; text decoder
# ---------------------------------------------------------------------------


def build_encdec(cfg: ArchConfig, mesh_info=None) -> ModelBundle:
    vp = L.vocab_pad_of(cfg.vocab)

    def dec_block_params(key):
        k1, k2 = jax.random.split(key)
        p = _dense_block_params(k1, cfg, cfg.d_ff)
        p["ln_x"] = L.norm_params(cfg.d_model)
        p["xattn"] = L.attn_params(k2, attn_dims(cfg))
        return p

    def init(key):
        ks = jax.random.split(key, 5)
        return {
            "embed": L.embed_params(ks[0], vp, cfg.d_model),
            "proj": (jax.random.normal(ks[1], (cfg.frontend_dim, cfg.d_model))
                     * cfg.frontend_dim ** -0.5).astype(PDT),
            "enc": _stack_init(lambda k: _dense_block_params(k, cfg, cfg.d_ff),
                               ks[2], cfg.enc_layers),
            "ln_enc": L.norm_params(cfg.d_model),
            "dec": _stack_init(dec_block_params, ks[3], cfg.n_layers),
            "ln_f": L.norm_params(cfg.d_model),
        }

    def encode(params, frames):
        x = frames.astype(PDT) @ params["proj"]
        chunked = x.shape[1] >= CHUNKED_MIN_SEQ

        @jax.checkpoint
        def body(carry, lp):
            h, _ = L.self_attn_full(L.rms_norm(carry, lp["ln1"], cfg.rms_eps),
                                    lp["attn"], attn_dims(cfg), causal=False,
                                    chunked=chunked)
            xx = carry + h
            y = L.swiglu(L.rms_norm(xx, lp["ln2"], cfg.rms_eps),
                         lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
            return xx + y, None

        x, _ = lax.scan(body, x, params["enc"])
        return L.rms_norm(x, params["ln_enc"], cfg.rms_eps)

    def _dec_full(params, tokens, enc_out, emit_kv):
        chunked = tokens.shape[1] >= CHUNKED_MIN_SEQ
        x = params["embed"].at[tokens].get(mode="clip")

        def body(carry, lp):
            h, kv = L.self_attn_full(L.rms_norm(carry, lp["ln1"], cfg.rms_eps),
                                     lp["attn"], attn_dims(cfg), chunked=chunked)
            xx = carry + h
            mk, mv = L.cross_kv(enc_out, lp["xattn"], attn_dims(cfg))
            xx = xx + L.cross_attn_full(L.rms_norm(xx, lp["ln_x"], cfg.rms_eps),
                                        lp["xattn"], attn_dims(cfg), mk, mv)
            y = L.swiglu(L.rms_norm(xx, lp["ln2"], cfg.rms_eps),
                         lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
            return xx + y, ((kv, (mk, mv)) if emit_kv else None)

        if not emit_kv:
            body = jax.checkpoint(body)
        x, kvs = lax.scan(body, x, params["dec"])
        return L.rms_norm(x, params["ln_f"], cfg.rms_eps), kvs

    def forward(params, batch):
        enc_out = encode(params, batch["frames"])
        x, _ = _dec_full(params, batch["tokens"], enc_out, emit_kv=False)
        return _logits(x, params["embed"]), 0.0

    def prefill(params, batch):
        Ssz = batch["tokens"].shape[1]
        enc_out = encode(params, batch["frames"])
        x, ((ks_, vs_), (mk, mv)) = _dec_full(params, batch["tokens"], enc_out,
                                              emit_kv=True)
        cache = {"k": ks_, "v": vs_, "mk": mk, "mv": mv,
                 "slot_pos": jnp.arange(Ssz, dtype=jnp.int32),
                 "pos": jnp.int32(Ssz)}
        return _logits(x[:, -1], params["embed"]), cache

    def decode_step(params, step, cache):
        token = step["token"]
        x = params["embed"].at[token].get(mode="clip")
        pos = cache["pos"]
        W = cache["k"].shape[2]
        slot = pos % W
        sp = lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))

        def body(carry, xs):
            lp, ck, cv, mk, mv = xs
            h, ck, cv = L.self_attn_decode(
                L.rms_norm(carry, lp["ln1"], cfg.rms_eps), lp["attn"],
                attn_dims(cfg), ck, cv, sp, slot, pos)
            xx = carry + h
            xx = xx + L.cross_attn_decode(
                L.rms_norm(xx, lp["ln_x"], cfg.rms_eps), lp["xattn"],
                attn_dims(cfg), mk, mv)
            y = L.swiglu(L.rms_norm(xx, lp["ln2"], cfg.rms_eps),
                         lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"])
            return xx + y, (ck, cv)

        x, (ks_, vs_) = lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["mk"], cache["mv"]))
        new = dict(cache)
        new.update(k=ks_, v=vs_, slot_pos=sp, pos=pos + 1)
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x[:, -1], params["embed"]), new

    def init_cache(batch_size, capacity, extras=None):
        hkv, hd = cfg.n_kv_heads, cfg.hd
        mem_len = extras["mem_len"] if extras else capacity
        return {
            "k": jnp.zeros((cfg.n_layers, batch_size, capacity, hkv, hd), PDT),
            "v": jnp.zeros((cfg.n_layers, batch_size, capacity, hkv, hd), PDT),
            "mk": jnp.zeros((cfg.n_layers, batch_size, mem_len, hkv, hd), PDT),
            "mv": jnp.zeros((cfg.n_layers, batch_size, mem_len, hkv, hd), PDT),
            "slot_pos": jnp.full((capacity,), -1, jnp.int32),
            "pos": jnp.int32(0),
        }

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_cache,
                       ring_axes={"k": 2, "v": 2})


# ---------------------------------------------------------------------------
# ssm family (mamba2)
# ---------------------------------------------------------------------------


def build_ssm(cfg: ArchConfig, mesh_info=None) -> ModelBundle:
    vp = L.vocab_pad_of(cfg.vocab)

    def layer_params(key):
        return {"ln": L.norm_params(cfg.d_model), "ssm": S.ssm_params(key, cfg)}

    def init(key):
        k0, k1 = jax.random.split(key)
        return {
            "embed": L.embed_params(k0, vp, cfg.d_model),
            "ln_f": L.norm_params(cfg.d_model),
            "layers": _stack_init(layer_params, k1, cfg.n_layers),
        }

    def _run_full(params, tokens, emit_state):
        x = params["embed"].at[tokens].get(mode="clip")

        def body(carry, lp):
            y, hfin, tails = S.ssd_forward(
                L.rms_norm(carry, lp["ln"], cfg.rms_eps), lp["ssm"], cfg)
            st = ((hfin, tails) if emit_state else None)
            return carry + y, st

        if not emit_state:
            body = jax.checkpoint(body)
        x, states = lax.scan(body, x, params["layers"])
        return L.rms_norm(x, params["ln_f"], cfg.rms_eps), states

    def forward(params, batch):
        x, _ = _run_full(params, batch["tokens"], emit_state=False)
        return _logits(x, params["embed"]), 0.0

    def prefill(params, batch):
        x, (hfin, tails) = _run_full(params, batch["tokens"], emit_state=True)
        cache = {"ssm": hfin, "conv_x": tails["x"], "conv_B": tails["B"],
                 "conv_C": tails["C"], "pos": jnp.int32(batch["tokens"].shape[1])}
        return _logits(x[:, -1], params["embed"]), cache

    def decode_step(params, step, cache):
        token = step["token"]
        x = params["embed"].at[token].get(mode="clip")

        def body(carry, xs):
            lp, st, cx, cb, cc = xs
            y, st, conv = S.ssd_decode_step(
                L.rms_norm(carry, lp["ln"], cfg.rms_eps), lp["ssm"], cfg, st,
                {"x": cx, "B": cb, "C": cc})
            return carry + y, (st, conv["x"], conv["B"], conv["C"])

        x, (st, cx, cb, cc) = lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv_x"],
                      cache["conv_B"], cache["conv_C"]))
        new = {"ssm": st, "conv_x": cx, "conv_B": cb, "conv_C": cc,
               "pos": cache["pos"] + 1}
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x[:, -1], params["embed"]), new

    def init_cache(batch_size, capacity, extras=None):
        one = S.init_ssm_cache(cfg, batch_size)
        LN = cfg.n_layers
        return {
            "ssm": jnp.zeros((LN,) + one["ssm"].shape, one["ssm"].dtype),
            "conv_x": jnp.zeros((LN,) + one["conv_x"].shape, PDT),
            "conv_B": jnp.zeros((LN,) + one["conv_B"].shape, PDT),
            "conv_C": jnp.zeros((LN,) + one["conv_C"].shape, PDT),
            "pos": jnp.int32(0),
        }

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# hybrid family (zamba2): mamba2 backbone + weight-shared attn block
# ---------------------------------------------------------------------------


def build_hybrid(cfg: ArchConfig, mesh_info=None) -> ModelBundle:
    vp = L.vocab_pad_of(cfg.vocab)
    per = cfg.hybrid_attn_every
    n_blocks = cfg.n_layers // per
    n_tail = cfg.n_layers - n_blocks * per

    def m_layer(key):
        return {"ln": L.norm_params(cfg.d_model), "ssm": S.ssm_params(key, cfg)}

    def init(key):
        ks = jax.random.split(key, 5)
        p = {
            "embed": L.embed_params(ks[0], vp, cfg.d_model),
            "ln_f": L.norm_params(cfg.d_model),
            "mamba": jax.vmap(lambda k: _stack_init(m_layer, k, per))(
                jax.random.split(ks[1], n_blocks)),
            "shared": _dense_block_params(ks[2], cfg, cfg.d_ff),
        }
        if n_tail:
            p["tail"] = _stack_init(m_layer, ks[3], n_tail)
        return p

    def _mamba_scan(x, stack, emit_state):
        def body(carry, lp):
            y, hfin, tails = S.ssd_forward(
                L.rms_norm(carry, lp["ln"], cfg.rms_eps), lp["ssm"], cfg)
            return carry + y, ((hfin, tails) if emit_state else None)
        if not emit_state:
            body = jax.checkpoint(body)
        return lax.scan(body, x, stack)

    def _run_full(params, tokens, emit):
        Ssz = tokens.shape[1]
        chunked = Ssz >= CHUNKED_MIN_SEQ
        x = params["embed"].at[tokens].get(mode="clip")
        sh = params["shared"]
        win = jnp.int32(cfg.sliding_window or -1)

        def outer(x, blk):
            x, st = _mamba_scan(x, blk, emit)
            xx, kv, _ = _block_full(x, sh, win, cfg, None, chunked, emit)
            return xx, (st, kv)

        outer_body = outer if emit else jax.checkpoint(outer)
        x, (m_states, attn_kv) = lax.scan(outer_body, x, params["mamba"])
        tail_states = None
        if n_tail:
            x, tail_states = _mamba_scan(x, params["tail"], emit)
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return x, m_states, attn_kv, tail_states

    def forward(params, batch):
        x, _, _, _ = _run_full(params, batch["tokens"], emit=False)
        return _logits(x, params["embed"]), 0.0

    def prefill(params, batch):
        Ssz = batch["tokens"].shape[1]
        x, (h_m, tails_m), (ks_, vs_), tail_st = _run_full(
            params, batch["tokens"], emit=True)
        W = min(Ssz, cfg.sliding_window or Ssz)
        # Keep only the last W entries of the attn kv (window cache), rolled
        # so the ring invariant slot == pos % W holds for decode continuation.
        shift = (Ssz - W) % W
        ak = jnp.roll(ks_[:, :, -W:], shift, axis=2)
        av = jnp.roll(vs_[:, :, -W:], shift, axis=2)
        sp = jnp.roll(jnp.arange(Ssz - W, Ssz, dtype=jnp.int32), shift)
        cache = {
            "ssm": h_m, "conv_x": tails_m["x"], "conv_B": tails_m["B"],
            "conv_C": tails_m["C"], "ak": ak, "av": av,
            "slot_pos": sp, "pos": jnp.int32(Ssz),
        }
        if n_tail:
            h_t, tails_t = tail_st
            cache.update(ssm_t=h_t, conv_xt=tails_t["x"], conv_Bt=tails_t["B"],
                         conv_Ct=tails_t["C"])
        return _logits(x[:, -1], params["embed"]), cache

    def _mamba_decode_scan(x, stack, st, cx, cb, cc):
        def body(carry, xs):
            lp, s, a, b, c = xs
            y, s, conv = S.ssd_decode_step(
                L.rms_norm(carry, lp["ln"], cfg.rms_eps), lp["ssm"], cfg, s,
                {"x": a, "B": b, "C": c})
            return carry + y, (s, conv["x"], conv["B"], conv["C"])
        return lax.scan(body, x, (stack, st, cx, cb, cc))

    def decode_step(params, step, cache):
        token = step["token"]
        x = params["embed"].at[token].get(mode="clip")
        pos = cache["pos"]
        W = cache["ak"].shape[2]
        slot = pos % W
        sp = lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,))
        sh = params["shared"]
        win = jnp.int32(cfg.sliding_window or -1)

        def outer(x, xs):
            blk, st, cx, cb, cc, ak, av = xs
            x, (st, cx, cb, cc) = _mamba_decode_scan(x, blk, st, cx, cb, cc)
            x, ak, av, _ = _block_decode(x, sh, win, cfg, None, ak, av, sp,
                                         slot, pos)
            return x, (st, cx, cb, cc, ak, av)

        x, (st, cx, cb, cc, ak, av) = lax.scan(
            outer, x, (params["mamba"], cache["ssm"], cache["conv_x"],
                       cache["conv_B"], cache["conv_C"], cache["ak"],
                       cache["av"]))
        new = dict(cache)
        new.update(ssm=st, conv_x=cx, conv_B=cb, conv_C=cc, ak=ak, av=av,
                   slot_pos=sp, pos=pos + 1)
        if n_tail:
            x, (st_t, cxt, cbt, cct) = _mamba_decode_scan(
                x, params["tail"], cache["ssm_t"], cache["conv_xt"],
                cache["conv_Bt"], cache["conv_Ct"])
            new.update(ssm_t=st_t, conv_xt=cxt, conv_Bt=cbt, conv_Ct=cct)
        x = L.rms_norm(x, params["ln_f"], cfg.rms_eps)
        return _logits(x[:, -1], params["embed"]), new

    def init_cache(batch_size, capacity, extras=None):
        one = S.init_ssm_cache(cfg, batch_size)
        W = min(capacity, cfg.sliding_window or capacity)
        hkv, hd = cfg.n_kv_heads, cfg.hd
        c = {
            "ssm": jnp.zeros((n_blocks, per) + one["ssm"].shape, one["ssm"].dtype),
            "conv_x": jnp.zeros((n_blocks, per) + one["conv_x"].shape, PDT),
            "conv_B": jnp.zeros((n_blocks, per) + one["conv_B"].shape, PDT),
            "conv_C": jnp.zeros((n_blocks, per) + one["conv_C"].shape, PDT),
            "ak": jnp.zeros((n_blocks, batch_size, W, hkv, hd), PDT),
            "av": jnp.zeros((n_blocks, batch_size, W, hkv, hd), PDT),
            "slot_pos": jnp.full((W,), -1, jnp.int32),
            "pos": jnp.int32(0),
        }
        if n_tail:
            c.update(
                ssm_t=jnp.zeros((n_tail,) + one["ssm"].shape, one["ssm"].dtype),
                conv_xt=jnp.zeros((n_tail,) + one["conv_x"].shape, PDT),
                conv_Bt=jnp.zeros((n_tail,) + one["conv_B"].shape, PDT),
                conv_Ct=jnp.zeros((n_tail,) + one["conv_C"].shape, PDT))
        return c

    return ModelBundle(cfg, init, forward, prefill, decode_step, init_cache,
                       ring_axes={"ak": 2, "av": 2})


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_BUILDERS = {
    "dense": build_dense,
    "moe": build_dense,   # dense decoder with MoE FFN blocks
    "vlm": build_vlm,
    "encdec": build_encdec,
    "ssm": build_ssm,
    "hybrid": build_hybrid,
}


def build(cfg: ArchConfig, mesh_info=None) -> ModelBundle:
    from repro.models import opt_flags
    if (cfg.family == "dense" and cfg.local_global_pattern
            and opt_flags.static_window()):
        return build_dense_pattern(cfg, mesh_info=mesh_info)
    return _BUILDERS[cfg.family](cfg, mesh_info=mesh_info)
