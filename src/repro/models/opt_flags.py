"""§Perf optimization toggles (EXPERIMENTS.md §Perf records before/after).

All default OFF = paper-faithful / naive-XLA baseline. The dry-run A/Bs each
flag; ``--optimized`` in dryrun.py turns on the whole set.

  REPRO_OPT_STATIC_WINDOW  — gemma3-class local:global stacks restructure
      into pattern blocks with *static* per-position windows, enabling the
      window-restricted attention path (compute only the kv blocks inside
      the window instead of full S^2 + mask).
  REPRO_OPT_ATTN_BF16      — chunked-attention logits tiles stored bf16
      (f32 running max/denominator kept) — halves the dominant HBM tile
      traffic of the jnp flash path.
  REPRO_OPT_ACTIVE_GATHER  — small-T (decode) MoE dispatch gathers only the
      most-loaded A local experts' weights instead of computing all E_local
      densely (the DuoServe insight applied to on-chip HBM traffic).
  REPRO_OPT_GROUPED_FFN    — serving engines route the segment-gathered
      expert sweeps (grouped decode, fused prefill) through the Pallas
      ``expert_ffn_from_pool`` streaming kernel and turn the fused
      single-launch prefill path on by default. OFF = grouped einsum with
      engine-identical numerics (bit-exact vs the dense per-expert path).
"""
from __future__ import annotations

import os


def _flag(name: str) -> bool:
    return os.environ.get(name, "0") not in ("0", "", "false", "False")


def static_window() -> bool:
    return _flag("REPRO_OPT_STATIC_WINDOW")


def attn_bf16_tiles() -> bool:
    return _flag("REPRO_OPT_ATTN_BF16")


def active_gather() -> bool:
    return _flag("REPRO_OPT_ACTIVE_GATHER")


def grouped_ffn() -> bool:
    """Pallas backend for the serving engines' grouped expert execution
    (serving/engine.py): the one-launch-per-layer expert sweeps read their
    weights off the ExpertResidency slot pools via ``expert_ffn_from_pool``
    (f32 kernel accumulation — kernel-grade numerics, pinned by interpret
    parity tests, NOT bit-equal to the engine einsum), and engines default
    ``fused_prefill`` to on."""
    return _flag("REPRO_OPT_GROUPED_FFN")


def seq_parallel() -> bool:
    """Megatron-style sequence parallelism: pin the residual stream
    seq-sharded over the tensor axis at block boundaries, turning the
    attention/MLP output all-reduces into reduce-scatter + all-gather pairs
    (~2x fewer collective bytes, activations sharded)."""
    return _flag("REPRO_OPT_SEQ_PARALLEL")


FLAGS = {
    "static_window": "REPRO_OPT_STATIC_WINDOW",
    "attn_bf16": "REPRO_OPT_ATTN_BF16",
    "active_gather": "REPRO_OPT_ACTIVE_GATHER",
    "grouped_ffn": "REPRO_OPT_GROUPED_FFN",
    "seq_parallel": "REPRO_OPT_SEQ_PARALLEL",
}


def set_all(on: bool) -> None:
    v = "1" if on else "0"
    for env in FLAGS.values():
        os.environ[env] = v


def set_named(names) -> None:
    set_all(False)
    for n in names:
        os.environ[FLAGS[n.strip()]] = "1"
