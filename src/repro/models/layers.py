"""Core transformer primitives: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Pure-functional, params-as-pytrees. All weights bf16; norm/softmax statistics
accumulate in f32. Attention supports:
  * grouped-query (n_kv_heads < n_heads), incl. MQA,
  * optional qk-norm (Qwen3/Gemma3) and qkv bias (Qwen1.5),
  * per-layer sliding windows passed as a *traced* int (so a single scanned
    code path serves Gemma3's 5 local : 1 global pattern),
  * a chunked (flash-style, online-softmax) path for long prefill/train,
  * a ring-buffer KV cache for decode (absolute slot positions carried in the
    cache make windowed/long-context decode exact).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

PDT = jnp.bfloat16  # param / activation dtype

NEG_INF = -1e9  # mask value (f32-safe)


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------


def _mask(qp: jax.Array, kp: jax.Array, window, causal: bool) -> jax.Array:
    """Boolean [..., Sq, Sk] validity from absolute positions.

    window: traced int; <0 (or None) means unbounded. kp<0 marks empty slots.
    """
    qp = qp[..., :, None]
    kp = kp[..., None, :]
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if window is not None:
        w = jnp.asarray(window)
        ok &= (w < 0) | (kp > qp - w)
    return ok


def attention(q, k, v, *, q_pos, k_pos, window=None, causal=True,
              scale: Optional[float] = None) -> jax.Array:
    """Reference full attention. q: [B,Sq,H,D]; k,v: [B,Sk,Hkv,D].

    q_pos: [B,Sq] (or [Sq]); k_pos: [B,Sk] (or [Sk]) absolute positions
    (negative = invalid slot). Returns [B,Sq,H,D].
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if q_pos.ndim == 1:
        q_pos = q_pos[None]
    if k_pos.ndim == 1:
        k_pos = k_pos[None]
    m = _mask(q_pos, k_pos, window, causal)[:, None, None]  # [B,1,1,Sq,Sk]
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def attention_chunked(q, k, v, *, window=None, causal=True, q_block=512,
                      kv_block=512, scale: Optional[float] = None,
                      bf16_tiles: Optional[bool] = None) -> jax.Array:
    """Flash-style online-softmax attention over position-aligned q/k.

    q: [B,S,H,D]; k,v: [B,S,Hkv,D]. Peak memory O(q_block * kv_block) logits
    instead of O(S^2). Causal blocks beyond the diagonal are masked (still
    computed — the Pallas kernel and the §Perf pass remove that waste).
    bf16_tiles (REPRO_OPT_ATTN_BF16): store probability tiles in bf16 to
    halve the dominant HBM tile traffic (running stats stay f32).
    """
    from repro.models import opt_flags
    if bf16_tiles is None:
        bf16_tiles = opt_flags.attn_bf16_tiles()
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qb, kb = q_block, kv_block
    nq, nk = -(-S // qb), -(-S // kb)
    pad_q, pad_k = nq * qb - S, nk * kb - S

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qpos = jnp.arange(nq * qb)
    kpos = jnp.where(jnp.arange(nk * kb) < S, jnp.arange(nk * kb), -1)

    qs = qp.reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,Hkv,G,qb,D]
    ks = kp_.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)       # [nk,B,Hkv,kb,D]
    vs = vp.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    qpos_b = qpos.reshape(nq, qb)
    kpos_b = kpos.reshape(nk, kb)

    def q_step(qi):
        qblk, qpb = qs[qi], qpos_b[qi]

        def kv_step(carry, xs):
            m_prev, l_prev, acc = carry
            kblk, vblk, kpb = xs
            lg = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                            kblk.astype(jnp.float32)) * scale
            msk = _mask(qpb[None], kpb[None], window, causal)[:, None, None]
            lg = jnp.where(msk, lg, NEG_INF)
            m_cur = jnp.maximum(m_prev, lg.max(-1))
            p = jnp.exp(lg - m_cur[..., None])
            corr = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * corr + p.sum(-1)
            if bf16_tiles:
                pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(jnp.bfloat16),
                                vblk.astype(jnp.bfloat16)).astype(jnp.float32)
            else:
                pv = jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                vblk.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (ks, vs, kpos_b))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    out = lax.map(q_step, jnp.arange(nq))  # [nq,B,Hkv,G,qb,D]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, D)
    return out[:, :S].astype(q.dtype)


def _chunked_fwd_with_lse(q, k, v, *, window, causal, q_block, kv_block,
                          scale):
    """attention_chunked + per-row logsumexp (for the flash backward).
    Returns (o [B,S,H,D] f32-accurate, lse [B,Hkv,G,S] f32)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qb, kb = min(q_block, S), min(kv_block, S)
    nq, nk = -(-S // qb), -(-S // kb)
    pad_q, pad_k = nq * qb - S, nk * kb - S
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kpos = jnp.where(jnp.arange(nk * kb) < S, jnp.arange(nk * kb), -1)
    qs = qp.reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)
    ks = kp_.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    vs = vp.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    kpos_b = kpos.reshape(nk, kb)

    def q_step(qi):
        qblk = qs[qi]
        qpb = qi * qb + jnp.arange(qb)

        def kv_step(carry, xs):
            m_prev, l_prev, acc = carry
            kblk, vblk, kpb = xs
            lg = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                            kblk.astype(jnp.float32)) * scale
            msk = _mask(qpb[None], kpb[None], window, causal)[:, None, None]
            lg = jnp.where(msk, lg, NEG_INF)
            m_cur = jnp.maximum(m_prev, lg.max(-1))
            p = jnp.exp(lg - m_cur[..., None])
            corr = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32))
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (ks, vs, kpos_b))
        o = acc / jnp.maximum(l, 1e-20)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-20))
        return o, lse

    o, lse = lax.map(q_step, jnp.arange(nq))  # [nq,B,Hkv,G,qb,(D)]
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, D)[:, :S]
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, nq * qb)[..., :S]
    return o.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def flash_attention_xla(q, k, v, window, causal, q_block, kv_block):
    """Chunked attention with a flash-style custom VJP.

    Without this, differentiating the chunked forward makes lax.scan save
    every online-softmax carry (tens of GB/layer at 32k even under remat);
    the custom backward recomputes probability tiles from (q, k, v, lse) —
    residuals are O(S), the §Perf fix for the train-shape memory terms.
    """
    o, _ = _chunked_fwd_with_lse(q, k, v, window=window, causal=causal,
                                 q_block=q_block, kv_block=kv_block,
                                 scale=q.shape[-1] ** -0.5)
    return o


def _fa_fwd(q, k, v, window, causal, q_block, kv_block):
    o, lse = _chunked_fwd_with_lse(q, k, v, window=window, causal=causal,
                                   q_block=q_block, kv_block=kv_block,
                                   scale=q.shape[-1] ** -0.5)
    return o, (q, k, v, o, lse, window)


def _fa_bwd(causal, q_block, kv_block, res, do):
    import numpy as _np
    q, k, v, o, lse, window = res
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    qb = min(q_block, S)
    kb = min(kv_block, S)
    nq, nk = -(-S // qb), -(-S // kb)
    pad_q, pad_k = nq * qb - S, nk * kb - S

    def padq(a):
        return jnp.pad(a, ((0, 0), (0, pad_q)) + ((0, 0),) * (a.ndim - 2))

    qf = padq(q).reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)
    dof = padq(do).reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)
    of = padq(o).reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)
    lsef = jnp.pad(lse, ((0, 0),) * 3 + ((0, pad_q),), constant_values=0.0)
    lsef = lsef.reshape(B, Hkv, G, nq, qb).transpose(3, 0, 1, 2, 4)
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    ks = kf.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    vs = vf.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    kpos = jnp.where(jnp.arange(nk * kb) < S, jnp.arange(nk * kb), -1)
    kpos_b = kpos.reshape(nk, kb)
    # D_i = rowsum(dO * O) (f32)
    delta = (dof.astype(jnp.float32) * of.astype(jnp.float32)).sum(-1)

    def kv_outer(dq_acc, xs):
        kblk, vblk, kpb, j = xs

        def q_inner(carry, qi):
            dk, dv = carry
            qblk = qf[qi].astype(jnp.float32)
            qpb = qi * qb + jnp.arange(qb)
            lg = jnp.einsum("bhgqd,bhkd->bhgqk", qblk,
                            kblk.astype(jnp.float32)) * scale
            msk = _mask(qpb[None], kpb[None], window, causal)[:, None, None]
            lg = jnp.where(msk, lg, NEG_INF)
            p = jnp.exp(lg - lsef[qi][..., None])          # [B,Hkv,G,qb,kb]
            dov = dof[qi].astype(jnp.float32)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dov,
                            vblk.astype(jnp.float32))
            ds = p * (dp - delta[qi][..., None]) * scale
            dv = dv + jnp.einsum("bhgqk,bhgqd->bhkd", p, dov)
            dk = dk + jnp.einsum("bhgqk,bhgqd->bhkd", ds, qblk)
            dq_blk = jnp.einsum("bhgqk,bhkd->bhgqd", ds,
                                kblk.astype(jnp.float32))
            return (dk, dv), dq_blk

        dk0 = jnp.zeros((B, Hkv, kb, D), jnp.float32)
        dv0 = jnp.zeros((B, Hkv, kb, D), jnp.float32)
        (dk, dv), dq_blocks = lax.scan(q_inner, (dk0, dv0), jnp.arange(nq))
        return dq_acc + dq_blocks, (dk, dv)

    dq0 = jnp.zeros((nq, B, Hkv, G, qb, D), jnp.float32)
    dq, (dks, dvs) = lax.scan(
        kv_outer, dq0, (ks, vs, kpos_b, jnp.arange(nk)))
    dq = dq.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, D)[:, :S]
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, Hkv, D)[:, :S]
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, Hkv, D)[:, :S]
    dwin = _np.zeros(jnp.shape(window), jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dwin)


flash_attention_xla.defvjp(_fa_fwd, _fa_bwd)


def attention_chunked_windowed(q, k, v, *, window: int, q_block=512,
                               kv_block=512,
                               scale: Optional[float] = None) -> jax.Array:
    """Window-restricted chunked attention (REPRO_OPT_STATIC_WINDOW).

    `window` must be a STATIC python int > 0. For query block i only the
    ceil((window + q_block)/kv_block) + 1 kv blocks that can intersect the
    band are computed (dynamic start, static trip count) — at 32k with a
    512 window that is ~2 blocks instead of 64 (a ~30x compute+traffic cut
    on local layers). Out-of-band and future positions are masked as usual.
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qb, kb = min(q_block, S), min(kv_block, S)
    nq, nk = -(-S // qb), -(-S // kb)
    pad_q, pad_k = nq * qb - S, nk * kb - S

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kpos_all = jnp.where(jnp.arange(nk * kb) < S, jnp.arange(nk * kb), -1)

    qs = qp.reshape(B, nq, qb, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)
    ks = kp_.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    vs = vp.reshape(B, nk, kb, Hkv, D).transpose(1, 0, 3, 2, 4)
    kpos_b = kpos_all.reshape(nk, kb)

    trips = min(nk, (window + qb - 1) // kb + 2)

    def q_step(qi):
        qblk = qs[qi]
        qpb = qi * qb + jnp.arange(qb)
        j0 = jnp.clip((qi * qb - window) // kb, 0, max(nk - trips, 0))

        def kv_step(carry, t):
            m_prev, l_prev, acc = carry
            j = j0 + t
            kblk = lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
            vblk = lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
            kpb = lax.dynamic_index_in_dim(kpos_b, j, 0, keepdims=False)
            lg = jnp.einsum("bhgqd,bhkd->bhgqk", qblk.astype(jnp.float32),
                            kblk.astype(jnp.float32)) * scale
            msk = _mask(qpb[None], kpb[None], window, True)[:, None, None]
            lg = jnp.where(msk, lg, NEG_INF)
            m_cur = jnp.maximum(m_prev, lg.max(-1))
            p = jnp.exp(lg - m_cur[..., None])
            corr = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32))
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(trips))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    out = lax.map(q_step, jnp.arange(nq))
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, D)
    return out[:, :S].astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (params + cache plumbing)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6


def attn_params(key, dims: AttnDims, dtype=PDT):
    d, H, Hkv, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, Hkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, Hkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (H * hd, d)) * (H * hd) ** -0.5).astype(dtype),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if dims.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qkv(x, p, dims: AttnDims, positions, use_rope=True):
    B, S, _ = x.shape
    H, Hkv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if dims.qk_norm:
        q = rms_norm(q, p["q_norm"], dims.rms_eps)
        k = rms_norm(k, p["k_norm"], dims.rms_eps)
    if use_rope:
        q = rope(q, positions, dims.rope_theta)
        k = rope(k, positions, dims.rope_theta)
    return q, k, v


def self_attn_full(x, p, dims: AttnDims, *, window=None, causal=True,
                   chunked=False, positions=None, use_rope=True):
    """Full-sequence self attention (train / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(x, p, dims, positions, use_rope)
    if chunked:
        if isinstance(window, int) and window > 0 and causal:
            # static window -> band-restricted kv loop (§Perf)
            o = attention_chunked_windowed(q, k, v, window=window)
        else:
            win = jnp.asarray(-1 if window is None else window, jnp.int32)
            o = flash_attention_xla(q, k, v, win, causal, 512, 512)
    else:
        o = attention(q, k, v, q_pos=positions, k_pos=positions,
                      window=window, causal=causal)
    return o.reshape(B, S, -1) @ p["wo"], (k, v)


def self_attn_decode(x, p, dims: AttnDims, cache_k, cache_v, slot_pos, slot,
                     pos, *, window=None, use_rope=True):
    """One-token decode against a ring-buffer cache.

    x: [B,1,d]; cache_k/v: [B,W,Hkv,hd]; slot_pos: [W] absolute position per
    slot (already updated to include `pos` at `slot`, -1 = empty); pos: scalar
    absolute position of the new token. Returns (out, new_k, new_v).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)
    q, k, v = _qkv(x, p, dims, positions, use_rope)
    ck = lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    o = attention(q, ck, cv, q_pos=positions, k_pos=slot_pos[None],
                  window=window, causal=True)
    return o.reshape(B, 1, -1) @ p["wo"], ck, cv


def self_attn_prefill_chunk(x, p, dims: AttnDims, cache_k, cache_v,
                            slot_pos, start, *, window=None, use_rope=True):
    """Chunked prefill: C prompt tokens attend over the request's
    already-written KV prefix plus themselves, appending their K/V.

    The incremental generalization of `self_attn_full` that chunked prefill
    (serving/batching.py) is built on: positions start..start+C-1 of one
    request arrive as a chunk; earlier chunks already wrote cache slots
    0..start-1. Masking is positional (slot_pos, -1 = empty), so a query at
    absolute position q sees exactly the keys 0..q — the same valid-key set
    as monolithic prefill; masked tail slots contribute exact zeros, which
    keeps the chunked path bit-identical to `self_attn_full` row-wise.

    x: [B,C,d]; cache_k/v: [B,W,Hkv,hd]; slot_pos: [B,W] absolute position
    per slot (chunk positions NOT yet required — they are written here);
    start: scalar absolute position of the chunk's first token (prefill
    never wraps the ring: start+C <= W is the caller's invariant).
    Returns (out, new_k, new_v, new_slot_pos).
    """
    B, C, _ = x.shape
    positions = start + jnp.arange(C, dtype=jnp.int32)[None, :]  # [1, C]
    positions = jnp.broadcast_to(positions, (B, C))
    q, k, v = _qkv(x, p, dims, positions, use_rope)
    ck = lax.dynamic_update_slice(cache_k, k, (0, start, 0, 0))
    cv = lax.dynamic_update_slice(cache_v, v, (0, start, 0, 0))
    sp = lax.dynamic_update_slice(slot_pos, positions, (0, start))
    o = attention(q, ck, cv, q_pos=positions, k_pos=sp,
                  window=window, causal=True)
    return o.reshape(B, C, -1) @ p["wo"], ck, cv, sp


def self_attn_decode_batched(x, p, dims: AttnDims, cache_k, cache_v,
                             slot_pos, slot, pos, *, window=None,
                             use_rope=True):
    """One-token decode for B independent sequences at DIFFERENT positions.

    The continuous-batching generalization of `self_attn_decode`: each batch
    row owns its own ring state, so `slot`/`pos` are [B] vectors and
    `slot_pos` is [B, W] (already updated to include `pos[b]` at `slot[b]`,
    -1 = empty). x: [B,1,d]; cache_k/v: [B,W,Hkv,hd].
    Returns (out, new_k, new_v).
    """
    B = x.shape[0]
    positions = pos.reshape(B, 1)
    q, k, v = _qkv(x, p, dims, positions, use_rope)
    rows = jnp.arange(B)
    ck = cache_k.at[rows, slot].set(k[:, 0])
    cv = cache_v.at[rows, slot].set(v[:, 0])
    o = attention(q, ck, cv, q_pos=positions, k_pos=slot_pos,
                  window=window, causal=True)
    return o.reshape(B, 1, -1) @ p["wo"], ck, cv


def cross_attn_decode(x, p, dims: AttnDims, mem_k, mem_v):
    """Single-token cross attention to cached memory K/V."""
    B = x.shape[0]
    q = (x @ p["wq"]).reshape(B, 1, dims.n_heads, dims.head_dim)
    if dims.qk_norm:
        q = rms_norm(q, p["q_norm"], dims.rms_eps)
    M = mem_k.shape[1]
    o = attention(q, mem_k, mem_v, q_pos=jnp.zeros((B, 1), jnp.int32),
                  k_pos=jnp.arange(M)[None] * 0, causal=False, window=None)
    return o.reshape(B, 1, -1) @ p["wo"]


def cross_attn_full(x, p, dims: AttnDims, mem_k, mem_v):
    """Cross attention to a fixed memory. x: [B,S,d]; mem_k/v: [B,M,Hkv,hd]."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q = (x @ p["wq"]).reshape(B, S, dims.n_heads, dims.head_dim)
    if dims.qk_norm:
        q = rms_norm(q, p["q_norm"], dims.rms_eps)
    M = mem_k.shape[1]
    kpos = jnp.arange(M)
    o = attention(q, mem_k, mem_v, q_pos=positions, k_pos=kpos[None],
                  causal=False, window=None)
    return o.reshape(B, S, -1) @ p["wo"]


def cross_kv(mem, p, dims: AttnDims):
    """Project memory tokens to cross-attention K/V once."""
    B, M, _ = mem.shape
    k = (mem @ p["wk"]).reshape(B, M, dims.n_kv_heads, dims.head_dim)
    v = (mem @ p["wv"]).reshape(B, M, dims.n_kv_heads, dims.head_dim)
    if dims.qk_norm:
        k = rms_norm(k, p["k_norm"], dims.rms_eps)
    return k, v


# ---------------------------------------------------------------------------
# dense FFN params
# ---------------------------------------------------------------------------


def mlp_params(key, d: int, ff: int, dtype=PDT):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": (jax.random.normal(k1, (d, ff)) * d ** -0.5).astype(dtype),
        "w3": (jax.random.normal(k2, (d, ff)) * d ** -0.5).astype(dtype),
        "w2": (jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dtype),
    }


def norm_params(d: int, dtype=PDT):
    return jnp.zeros((d,), dtype)


def embed_params(key, vocab_pad: int, d: int, dtype=PDT):
    return (jax.random.normal(key, (vocab_pad, d)) * d ** -0.5).astype(dtype)


def vocab_pad_of(vocab: int) -> int:
    return -(-vocab // 128) * 128
