"""Checkpointing: pytree <-> single .npz with '/'-joined key paths.

No external deps (orbax unavailable offline); handles bf16 via a uint16 view
with a dtype sidecar. Atomic via tmp-file rename.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


def save(path: str, tree, extra: Dict[str, Any] | None = None) -> None:
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
        arrays[k] = a
    arrays["__meta__"] = np.frombuffer(
        json.dumps({"dtypes": dtypes, "extra": extra or {}}).encode(), np.uint8)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    np.savez(tmp, **arrays)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load(path: str, like=None):
    """Load a checkpoint. If `like` is given, restore into its treedef."""
    z = np.load(path)
    meta = json.loads(bytes(z["__meta__"]).decode())
    flat = {}
    for k in z.files:
        if k == "__meta__":
            continue
        a = z[k]
        if meta["dtypes"][k] == "bfloat16":
            a = a.view(jnp.bfloat16)
        flat[k] = jnp.asarray(a)
    if like is None:
        return flat, meta["extra"]
    leaves_like = _flatten(like)
    assert set(leaves_like) == set(flat), (
        f"checkpoint keys mismatch: {set(leaves_like) ^ set(flat)}")
    treedef = jax.tree_util.tree_structure(like)
    ordered = [flat[k] for k in leaves_like]  # same insertion order as like
    return jax.tree_util.tree_unflatten(treedef, ordered), meta["extra"]
