"""AdamW in pure JAX (no optax in this container).

Moments are f32 regardless of param dtype (mixed-precision convention:
bf16 params, f32 optimizer state). State tree mirrors the param tree, so the
same partition rules apply to both.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        # global-norm clip in f32
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)))
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gn, 1e-9))
        g32 = jax.tree.map(lambda g: g * scale, g32)

        m = jax.tree.map(lambda m_, g: self.b1 * m_ + (1 - self.b1) * g,
                         state.m, g32)
        v = jax.tree.map(lambda v_, g: self.b2 * v_ + (1 - self.b2) * g * g,
                         state.v, g32)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), gn


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak * jnp.where(s < warmup, warm, cos)
    return lr
