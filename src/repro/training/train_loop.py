"""Training step + loss for every architecture family.

``make_train_step(bundle, opt)`` returns a jit-able
``(params, opt_state, batch) -> (params, opt_state, metrics)``.
Loss = next-token cross-entropy (padded-vocab columns are never targets) +
router load-balance aux for MoE archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import ModelBundle
from repro.training.optimizer import AdamW


def lm_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Shifted next-token CE. logits: [B,S,Vp]; tokens: [B,S]."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def make_loss_fn(bundle: ModelBundle):
    aux_w = bundle.cfg.router_aux_loss if bundle.cfg.is_moe else 0.0

    def loss_fn(params, batch):
        logits, aux = bundle.forward(params, batch)
        ce = lm_loss(logits, batch["tokens"])
        total = ce + aux_w * aux
        return total, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(bundle: ModelBundle, opt: AdamW, microbatches: int = 1,
                    mb_constraint=None, acc_constraint=None):
    """microbatches > 1 accumulates grads over a lax.scan of micro-steps —
    activation memory scales down ~1/m (peak = one microbatch's activations
    + the f32 grad accumulator). mb_constraint: optional fn(tree)->tree that
    re-pins each microbatch's sharding (batch stays on the data axes);
    acc_constraint: fn(tree)->tree pinning the f32 grad accumulator (ZeRO
    sharding over the data axes — without it the accumulator is replicated
    and dominates temp memory for >=30B-param models)."""
    loss_fn = make_loss_fn(bundle)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def mb_step(acc, mb):
                if mb_constraint is not None:
                    mb = mb_constraint(mb)
                g_acc, l_acc, a_acc = acc
                (l, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                if acc_constraint is not None:
                    g_acc = acc_constraint(g_acc)
                return (g_acc, l_acc + l, a_acc + parts["aux"]), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            if acc_constraint is not None:
                zeros = acc_constraint(zeros)
            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                mb_step, (zeros, 0.0, 0.0), micro)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            parts = {"ce": loss, "aux": aux_sum * inv}
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm, **parts}
        return params, opt_state, metrics

    return train_step


def make_eval_step(bundle: ModelBundle):
    loss_fn = make_loss_fn(bundle)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return {"loss": loss, **parts}

    return eval_step
