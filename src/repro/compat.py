"""Version-compat shims over moving JAX APIs.

The repo targets the newest JAX surface (``jax.shard_map`` with ``check_vma``)
but must run on older releases where shard_map still lives in
``jax.experimental.shard_map`` and the kwarg is named ``check_rep``. All
shard_map call sites import from here instead of touching ``jax`` directly.
"""
from __future__ import annotations

import inspect
from typing import Optional

import jax


def _check_kwarg_name(fn) -> Optional[str]:
    """Which replication-check kwarg `fn` accepts (there were releases where
    ``jax.shard_map`` was public but still took ``check_rep``, so the kwarg
    name cannot be keyed on where the function lives)."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # opaque wrapper: leave library default
        return None
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` when available, else the experimental fallback.

    check_vma follows the new-API name; on JAX versions whose shard_map
    still takes ``check_rep`` the value is passed under that name. None (or
    an inspectable kwarg not being found) leaves the library default.
    """
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn
    kwargs = {}
    if check_vma is not None:
        name = _check_kwarg_name(fn)
        if name is not None:
            kwargs[name] = check_vma
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
