"""Version-compat shims over moving JAX APIs.

The repo targets the newest JAX surface (``jax.shard_map`` with ``check_vma``)
but must run on older releases where shard_map still lives in
``jax.experimental.shard_map`` and the kwarg is named ``check_rep``. All
shard_map call sites import from here instead of touching ``jax`` directly.
"""
from __future__ import annotations

from typing import Optional

import jax


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` when available, else the experimental fallback.

    check_vma follows the new-API name; on old JAX it maps to ``check_rep``.
    None leaves the library default in place on either version.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
