"""Loop-aware HLO cost analysis from optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body ONCE,
so scan-over-layers models (everything here) are undercounted by ~n_layers x.
This module parses the post-optimization, post-SPMD HLO (``compiled.as_text()``)
and walks the computation graph:

  * dot flops      = 2 * result_elems * prod(lhs contracting dim sizes)
  * elementwise    = 1 flop per result element (dots dominate; documented)
  * while          = (body + cond cost) * known_trip_count  (from XLA's
                     backend_config — exact for lax.scan/fori)
  * fusion/call    = cost of the called computation
  * bytes accessed = sum of (operands + result) buffer sizes of top-level ops
                     (fusions materialize their boundary buffers only — the
                     XLA fusion memory-traffic model), loop bodies x trips
  * collectives    = per-op result bytes x trips, bucketed by collective kind

Used by the dry-run for §Roofline. Per-device numbers (HLO is post-SPMD).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:{[^}]*})?)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# opcodes that don't touch memory / are free
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "token", "partition-id", "replica-id", "iota",
         "reshape", "broadcast"}

_ELEMWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "floor", "ceil", "sign", "compare", "select", "and", "or", "not", "xor",
    "convert", "exponential-minus-one", "log-plus-one", "remainder",
    "clamp", "round-nearest-afz", "cosine", "sine", "atan2", "logistic",
    "erf", "cbrt",
}


def _shape_bytes_elems(type_str: str) -> Tuple[int, int]:
    """Total (bytes, elems) of a (possibly tuple) type string."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        e = 1
        for d in dims.split(","):
            if d:
                e *= int(d)
        total_e += e
        total_b += e * _DTYPE_BYTES[dt]
    return total_b, total_e


def _split_operands(rest: str) -> Tuple[List[str], str]:
    """rest starts right after the opening paren of opcode(...). Returns
    (operand names, attr tail).

    Operands are typed references ("f32[64,128]{1,0} %name"), so the name is
    extracted by %-token rather than by comma splitting (commas also appear
    inside shape/layout brackets).
    """
    depth = 1
    i = 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    inner, tail = rest[: i - 1], rest[i:]
    ops = re.findall(r"%([\w.\-]+)", inner)
    return ops, tail


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            rec = self.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0})
            rec["count"] += v["count"] * mult
            rec["bytes"] += v["bytes"] * mult

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "transcendentals": self.transcendentals,
                "collectives": self.collectives}


class HloModule:
    def __init__(self, text: str):
        self.comps: Dict[str, List[dict]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR.match(line.strip())
            if hdr and ("->" in line) and line.strip().endswith("{"):
                cur = hdr.group(1)
                self.comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, type_str, opcode, rest = m.groups()
            operands, tail = _split_operands(rest)
            self.comps[cur].append({
                "name": name, "type": type_str, "op": opcode,
                "operands": operands, "attrs": tail, "line": line,
            })

    def _fusion_operand_bytes(self, comp: str, operand_names, outer_shapes
                              ) -> float:
        """Effective bytes read for a fusion's operands.

        * a parameter consumed only by dynamic-slice/gather reads the slice;
        * a parameter that is the in-place buffer (operand 0) of a
          dynamic-update-slice reads ~the update size, not the whole buffer
          (scan ys-stacking would otherwise count the full stacked cache
          every iteration).
        """
        ops = self.comps.get(comp, [])
        shapes_in = {o["name"]: o["type"] for o in ops}
        param_of = {}
        for o in ops:
            if o["op"] == "parameter":
                m = re.search(r"parameter\((\d+)\)", o["line"])
                if m:
                    param_of[o["name"]] = int(m.group(1))
        sliced_bytes: Dict[int, float] = {}
        bad = set()
        for o in ops:
            for pos, nm in enumerate(o["operands"]):
                if nm not in param_of:
                    continue
                idx = param_of[nm]
                if o["op"] in ("dynamic-slice", "gather", "slice") and pos == 0:
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + \
                        _shape_bytes_elems(o["type"])[0]
                elif o["op"] == "dynamic-update-slice" and pos == 0:
                    upd = o["operands"][1] if len(o["operands"]) > 1 else None
                    ub = _shape_bytes_elems(shapes_in.get(upd, ""))[0] if upd \
                        else 0
                    sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + ub
                else:
                    bad.add(idx)
        totalb = 0.0
        for i, nm in enumerate(operand_names):
            full = _shape_bytes_elems(outer_shapes.get(nm, ""))[0]
            if i in sliced_bytes and i not in bad:
                totalb += min(full, sliced_bytes[i])
            else:
                totalb += full
        return totalb

    def _fusion_result_bytes(self, comp: str, res_b: float) -> float:
        """Effective bytes written by a fusion: dynamic-update-slice roots
        write the update region, not the whole aliased buffer."""
        ops = self.comps.get(comp, [])
        shapes_in = {o["name"]: o["type"] for o in ops}
        dus_res = dus_upd = 0.0
        for o in ops:
            if o["op"] == "dynamic-update-slice":
                dus_res += _shape_bytes_elems(o["type"])[0]
                if len(o["operands"]) > 1:
                    dus_upd += _shape_bytes_elems(
                        shapes_in.get(o["operands"][1], ""))[0]
        return max(res_b - dus_res, 0.0) + dus_upd

    # -- cost ---------------------------------------------------------------
    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # cycle guard
        shapes = {op["name"]: op["type"] for op in self.comps.get(comp, [])}
        total = Cost()
        for op in self.comps.get(comp, []):
            oc = op["op"]
            if oc in _FREE:
                continue
            res_b, res_e = _shape_bytes_elems(op["type"])
            opnd_b = sum(_shape_bytes_elems(shapes.get(o, ""))[0]
                         for o in op["operands"])

            if oc == "while":
                trips = 1.0
                tm = _TRIP_RE.search(op["attrs"])
                if tm:
                    trips = float(tm.group(1))
                body = _BODY_RE.search(op["attrs"])
                cond = _COND_RE.search(op["attrs"])
                if body:
                    total.add(self.comp_cost(body.group(1)), trips)
                if cond:
                    total.add(self.comp_cost(cond.group(1)), trips)
                continue

            if oc in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(op["attrs"]) or \
                    _TO_APPLY_RE.search(op["attrs"])
                eff_opnd, eff_res = opnd_b, res_b
                if cm:
                    inner = self.comp_cost(cm.group(1))
                    c = Cost()
                    c.add(inner)
                    c.bytes = 0.0  # fusion interior doesn't touch HBM
                    total.add(c)
                    # slice-aware traffic (see helper docstrings)
                    eff_opnd = self._fusion_operand_bytes(
                        cm.group(1), op["operands"], shapes)
                    eff_res = self._fusion_result_bytes(cm.group(1), res_b)
                total.bytes += eff_res + eff_opnd
                continue

            if oc in ("reduce", "reduce-window", "scatter", "gather",
                      "dynamic-slice", "dynamic-update-slice", "sort",
                      "select-and-scatter", "concatenate", "slice", "pad",
                      "copy", "transpose", "rng-bit-generator", "cholesky",
                      "triangular-solve", "clamp", "map"):
                if oc in ("dynamic-slice", "gather", "slice"):
                    # reads the slice, not the whole operand
                    total.bytes += 2 * res_b
                elif oc == "dynamic-update-slice":
                    upd_b = _shape_bytes_elems(
                        shapes.get(op["operands"][1], ""))[0] \
                        if len(op["operands"]) > 1 else res_b
                    total.bytes += 2 * upd_b  # read update, write region
                else:
                    total.bytes += res_b + opnd_b
                if oc == "reduce":
                    opnd_e = sum(_shape_bytes_elems(shapes.get(o, ""))[1]
                                 for o in op["operands"])
                    total.flops += opnd_e  # ~1 flop per element reduced
                continue

            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                rec = total.collectives.setdefault(
                    base, {"count": 0.0, "bytes": 0.0})
                rec["count"] += 1
                rec["bytes"] += res_b
                total.bytes += res_b + opnd_b
                continue
            if oc.endswith("-done"):
                continue

            if oc == "dot":
                lhs = op["operands"][0] if op["operands"] else None
                k = 1
                cm = _LHS_CONTRACT_RE.search(op["attrs"])
                if cm and lhs and lhs in shapes:
                    sm = _SHAPE_RE.search(shapes[lhs])
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        for idx in cm.group(1).split(","):
                            if idx:
                                k *= dims[int(idx)]
                total.flops += 2.0 * res_e * k
                total.bytes += res_b + opnd_b
                continue

            if oc == "convolution":
                total.flops += 2.0 * res_e  # no convs in this codebase
                total.bytes += res_b + opnd_b
                continue

            if oc in _ELEMWISE_FLOPS:
                total.flops += res_e
                if oc in ("exponential", "tanh", "log", "rsqrt", "sqrt",
                          "power", "logistic", "erf", "cosine", "sine"):
                    total.transcendentals += res_e
                total.bytes += res_b + opnd_b
                continue

            # default: count memory traffic only
            total.bytes += res_b + opnd_b
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    return HloModule(hlo_text).entry_cost().as_dict()
