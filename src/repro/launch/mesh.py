"""Production meshes (TPU v5e class).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — 'pod' extends data
parallelism across the DCN/ICI-linked second pod.

Functions (not module constants) so importing never touches jax device state.
"""
from __future__ import annotations

import jax

# hardware constants for roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_info(mesh) -> dict:
    """The dict threaded into model builders for shard_map MoE blocks."""
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data"))
    return {"mesh": mesh, "dp": dp, "tp": "model"}


def n_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
