"""Abstract inputs (ShapeDtypeStruct) + shardings for every (arch, shape).

``input_specs(cfg, shape)`` returns the batch/step/cache stand-ins the
dry-run lowers against — weak-type-correct, shardable, zero allocation.
Modality frontends are stubbed here per the assignment: audio gets
``frames`` (B, S, frontend_dim) embeddings, VLM gets ``patch_embeds``.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.layers import PDT
from repro.models.model import ModelBundle

SDS = jax.ShapeDtypeStruct

# bounded long-context adaptation (DESIGN.md §5): ring-cache capacity used for
# the 500k decode shape on window/hybrid archs.
LONG_CACHE_CAP = 131_072


def train_batch_abs(cfg: ArchConfig, shape: InputShape) -> Dict[str, SDS]:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = SDS((B, cfg.n_frontend_tokens,
                                     cfg.frontend_dim), PDT)
    if cfg.family == "encdec":
        batch["frames"] = SDS((B, S, cfg.frontend_dim), PDT)
    return batch


def decode_capacity(cfg: ArchConfig, shape: InputShape) -> int:
    if shape.name == "long_500k":
        return min(shape.seq_len, LONG_CACHE_CAP)
    return shape.seq_len


def decode_abs(cfg: ArchConfig, shape: InputShape, bundle: ModelBundle
               ) -> Tuple[Dict[str, SDS], Dict]:
    B = shape.global_batch
    cap = decode_capacity(cfg, shape)
    step = {"token": SDS((B, 1), jnp.int32)}
    extras = None
    if cfg.family == "encdec":
        extras = {"mem_len": min(shape.seq_len, LONG_CACHE_CAP)}
    cache = jax.eval_shape(
        functools.partial(bundle.init_cache, B, cap, extras))
    return step, cache


def input_specs(cfg: ArchConfig, shape: InputShape, bundle: ModelBundle):
    """Returns (kind, abstract-args dict) for the step to lower."""
    if shape.kind in ("train", "prefill"):
        return {"batch": train_batch_abs(cfg, shape)}
    step, cache = decode_abs(cfg, shape, bundle)
    return {"step": step, "cache": cache}
