"""Distributed training launcher.

Builds the production mesh, shards params/optimizer/batch with the rule-based
partitioner, and runs the jitted train step. On this CPU container use
--dry-run-devices to emulate the mesh (same code path as a real pod slice —
on TPU the mesh maps onto real devices and nothing else changes).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
      --steps 4 --reduced            # runnable on CPU (1 device)
  PYTHONPATH=src python -m repro.launch.train --arch kimi-k2-1t-a32b \
      --dry-run-devices 512 --multi-pod --steps 1 --compile-only
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + 1-device mesh (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compile-only", action="store_true")
    ap.add_argument("--dry-run-devices", type=int, default=0,
                    help="force N host platform devices (set FIRST)")
    args = ap.parse_args()

    if args.dry_run_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.dry_run_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import INPUT_SHAPES, get_config, reduced
    from repro.data.pipeline import SyntheticLM
    from repro.launch import partition
    from repro.launch.mesh import make_production_mesh, mesh_info, n_chips
    from repro.models.model import build
    from repro.training.optimizer import AdamW, AdamWState
    from repro.training.train_loop import make_train_step

    cfg = get_config(args.arch)
    shape = INPUT_SHAPES[args.shape]
    if args.reduced:
        cfg = reduced(cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        B, S = 4, 32
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        B, S = shape.global_batch, shape.seq_len
    minfo = mesh_info(mesh)
    n_model = mesh.shape["model"]
    n_dp = n_chips(mesh) // n_model
    dp = minfo["dp"] if len(minfo["dp"]) > 1 else minfo["dp"][0]
    print(f"mesh={dict(mesh.shape)} arch={cfg.name} batch={B} seq={S}")

    bundle = build(cfg, mesh_info=minfo if n_chips(mesh) > 1 else None)
    opt = AdamW()
    step_fn = make_train_step(bundle, opt, microbatches=args.microbatches)

    params_abs = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    pspecs = partition.param_specs(cfg, params_abs, n_model=n_model)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    ospecs = AdamWState(P(), pspecs, pspecs)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    bspecs = partition.batch_specs(batch_abs, dp=dp, n_dp=n_dp)

    jstep = jax.jit(step_fn, in_shardings=(ns(pspecs), ns(ospecs),
                                           ns(bspecs)))
    if args.compile_only:
        opt_abs = jax.eval_shape(opt.init, params_abs)
        compiled = jstep.lower(params_abs, opt_abs, batch_abs).compile()
        print("compiled ok;", compiled.memory_analysis())
        return

    with jax.default_device(jax.devices()[0]):
        params = bundle.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    data = SyntheticLM(cfg.vocab, seed=0)
    it = data.batches(B, S)
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(next(it))}
        params, opt_state, m = jstep(params, opt_state, batch)
        print(f"step {i}: loss={float(m['loss']):.4f} "
              f"gnorm={float(m['grad_norm']):.2f}")


if __name__ == "__main__":
    main()
