"""Serving launcher: DuoServe-MoE runtime over a request stream.

Reduced mode runs the live layer-by-layer engine (host expert store + device
expert cache + dual-phase scheduling) on CPU. Full mode lowers the sharded
prefill/decode step functions on the production mesh (the pod-scale serving
path proven by the dry-run).

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --requests 4 --policy duo
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--policy", default="duo",
                    choices=["odf", "lfp", "mif", "duo", "duo+"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    import jax

    from repro.configs.base import get_config, reduced
    from repro.core.predictor import train_predictor
    from repro.core.qos import summarize
    from repro.core.state import StateConstructor
    from repro.data.pipeline import PromptWorkload, squad_like
    from repro.models.model import build
    from repro.serving.engine import MoEServingEngine, collect_traces

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    wl = PromptWorkload(squad_like(cfg.vocab), seed=11)

    stats = predictor = None
    if args.policy in ("mif", "duo", "duo+"):
        tracer, _ = collect_traces(
            cfg, params, [p[:32] for p, _ in wl.prompts(8)], max_new=6)
        stats = tracer.stats()
        if args.policy != "mif":
            sc = StateConstructor(stats)
            X, Y = sc.build_dataset(tracer.as_array())
            predictor, _ = train_predictor(
                jax.random.PRNGKey(1), X, Y, cfg.top_k, width_scale=0.1,
                epochs=5, batch=32)

    eng = MoEServingEngine(cfg, params, policy=args.policy, stats=stats,
                           predictor=predictor)
    ttfts, e2es, toks = [], [], 0
    for i, (p, _) in enumerate(wl.prompts(args.requests)):
        r = eng.serve(p[:32], max_new=args.max_new)
        ttfts.append(r.ttft_wall)
        e2es.append(r.e2e_wall)
        toks += len(r.tokens)
        print(f"req {i}: tokens={r.tokens.tolist()} "
              f"hits={r.hits} misses={r.misses}")
    q = summarize(ttfts, e2es, toks)
    print(f"\npolicy={args.policy} mean_ttft={q.mean_ttft:.2f}s "
          f"mean_e2e={q.mean_e2e:.2f}s p95={q.p95_e2e:.2f}s "
          f"tok/s={q.tokens_per_s:.2f}")


if __name__ == "__main__":
    main()
