"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

This is the proof that the distribution config is coherent without hardware:
``jax.jit(step, in_shardings=...).lower(**abstract).compile()`` must succeed
on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh for every pair.
Memory/cost analysis and the collective schedule are dumped to
``results/dryrun/*.json`` for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax locks
# the device count at first backend init, so this precedes EVERY other import.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import INPUT_SHAPES, get_config, pairs  # noqa: E402
from repro.launch import partition  # noqa: E402
from repro.launch.input_specs import decode_abs, train_batch_abs  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_info, n_chips  # noqa: E402
from repro.models.model import build  # noqa: E402
from repro.training.optimizer import AdamW, AdamWState  # noqa: E402
from repro.training.train_loop import make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def parse_collectives(hlo_text: str):
    """Sum result-operand bytes of every collective op in optimized HLO."""
    out = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        op = m.group(1)
        lhs = line.split("=")[1]
        sm = _SHAPE_RE.search(lhs)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        size = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                size *= int(d)
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += size
    return out


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               skip_compile: bool = False,
               microbatches: int = 0) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    minfo = mesh_info(mesh)
    n_model = mesh.shape["model"]
    n_dp = n_chips(mesh) // n_model
    dp = minfo["dp"] if len(minfo["dp"]) > 1 else minfo["dp"][0]

    bundle = build(cfg, mesh_info=minfo)
    params_abs = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    pspecs = partition.param_specs(cfg, params_abs, n_model=n_model)

    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": n_chips(mesh), "kind": shape.kind,
           "microbatches": microbatches if shape.kind == "train" else None}
    t0 = time.time()

    if shape.kind == "train":
        if not microbatches:
            # bigger models need smaller activation working sets; the
            # per-layer residual carries scale with the microbatch size
            microbatches = 4 if cfg.d_model < 4096 else \
                (8 if cfg.d_model < 6144 else 16)
        # each microbatch must stay divisible by the data-parallel world
        microbatches = min(microbatches, max(shape.global_batch // n_dp, 1))
        rec["microbatches"] = microbatches
        opt = AdamW()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        # FSDP/ZeRO-3 via GSPMD: params AND moments stored sharded over the
        # data axes on top of tensor parallelism; the per-layer weight
        # all-gathers appear automatically in the lowered module. Enabled
        # when params+optimizer at tensor-parallel-only sharding would blow
        # the 16 GB/chip budget (100B-1T configs); small models keep plain
        # DP+TP (FSDP's per-layer gathers only cost them). See §Perf.
        param_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(params_abs))
        state_gb_dev = param_bytes * (1 + 8 / 2) / n_model / 1e9
        zspecs = partition.zero_specs(params_abs, pspecs, dp=dp, n_dp=n_dp)
        rec["fsdp"] = bool(state_gb_dev > 8.0)
        if rec["fsdp"]:
            pspecs = zspecs
        ospecs = AdamWState(P(), zspecs, zspecs)
        batch_abs = train_batch_abs(cfg, shape)
        bspecs = partition.batch_specs(batch_abs, dp=dp, n_dp=n_dp)
        mb = microbatches

        def mb_constraint(tree):
            # applied to one already-sliced microbatch: leaf dim 0 is batch
            def pin(leaf):
                spec = [None] * leaf.ndim
                if leaf.shape[0] % n_dp == 0:
                    spec[0] = dp
                return jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, P(*spec)))
            return jax.tree.map(pin, tree)

        def acc_constraint(tree):
            return jax.tree.map(
                lambda leaf, sp: jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, sp)),
                tree, zspecs)

        step_fn = make_train_step(bundle, opt, microbatches=mb,
                                  mb_constraint=mb_constraint,
                                  acc_constraint=acc_constraint)
        jfn = jax.jit(step_fn, in_shardings=(
            _ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)))
        lowered = jfn.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = train_batch_abs(cfg, shape)
        bspecs = partition.batch_specs(batch_abs, dp=dp, n_dp=n_dp)
        jfn = jax.jit(bundle.prefill,
                      in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)))
        lowered = jfn.lower(params_abs, batch_abs)
    else:  # decode
        step_abs, cache_abs = decode_abs(cfg, shape, bundle)
        sspecs = partition.batch_specs(step_abs, dp=dp, n_dp=n_dp)
        cspecs = partition.cache_specs(cfg, cache_abs, dp=dp,
                                       n_model=n_model, n_dp=n_dp)
        jfn = jax.jit(bundle.decode_step, in_shardings=(
            _ns(mesh, pspecs), _ns(mesh, sspecs), _ns(mesh, cspecs)))
        lowered = jfn.lower(params_abs, step_abs, cache_abs)

    rec["lower_s"] = round(time.time() - t0, 2)
    if skip_compile:
        return rec

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                            None),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: v for k, v in ca.items()
                       if k in ("flops", "bytes accessed")
                       or k.startswith("bytes accessed")}
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}

    try:
        from repro.launch import hlo_cost
        hc = hlo_cost.analyze(compiled.as_text())
        rec["hlo_cost"] = {"flops": hc["flops"], "bytes": hc["bytes"],
                           "transcendentals": hc["transcendentals"]}
        rec["collectives"] = hc["collectives"]
    except Exception as e:  # pragma: no cover
        rec["hlo_cost"] = {"error": str(e)}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="enable all REPRO_OPT_* §Perf flags; results are "
                         "suffixed __opt")
    ap.add_argument("--opts", default=None,
                    help="comma list of §Perf flags to enable "
                         "(static_window,attn_bf16,active_gather,"
                         "seq_parallel); suffix __opt-<names>")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    suffix = ""
    if args.optimized:
        from repro.models import opt_flags
        opt_flags.set_all(True)
        suffix = "__opt"
    elif args.opts:
        from repro.models import opt_flags
        names = args.opts.split(",")
        opt_flags.set_named(names)
        suffix = "__opt-" + "-".join(n.strip() for n in names)

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        todo = [(c.name, s.name) for c, s in pairs()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    meshes = [False, True]
    if args.multi_pod:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    failures = []
    for arch, shape in todo:
        for mp in meshes:
            tag = (f"{arch.replace('.', '_')}__{shape}__"
                   f"{'multi' if mp else 'single'}{suffix}")
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = lower_pair(arch, shape, mp)
                rec["ok"] = True
                print(f"  ok: lower {rec['lower_s']}s compile "
                      f"{rec.get('compile_s')}s flops/dev="
                      f"{rec.get('cost', {}).get('flops'):.3e}"
                      if rec.get('cost', {}).get('flops') else
                      f"  ok: lower {rec['lower_s']}s", flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                failures.append(tag)
                print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:\n" + "\n".join(failures))
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
