"""Rule-based parameter/cache partitioner.

Specs are assigned by leaf *name* (last pytree key) + context (inside a 'moe'
subtree?) with dims addressed from the END so stacking prefixes ([L, ...] or
[blocks, per, ...]) never matter. Every rule is guarded by divisibility — a
dim that doesn't divide the axis falls back to replication (e.g. Gemma3's 4
query heads on a 16-way tensor axis).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.moe_layer import expert_shard_mode


def _key_name(entry) -> str:
    return str(getattr(entry, "key", getattr(entry, "name",
               getattr(entry, "idx", entry))))


def _spec_last(leaf, tp, n_model, offset=1):
    """Shard dim -offset over tp if divisible."""
    dim = leaf.ndim - offset
    if dim >= 0 and leaf.shape[dim] % n_model == 0:
        spec = [None] * leaf.ndim
        spec[dim] = tp
        return P(*spec)
    return P()


def param_specs(cfg: ArchConfig, params_abs, *, tp: str = "model",
                n_model: int = 16):
    """PartitionSpec tree matching the abstract param tree."""
    moe_mode = expert_shard_mode(cfg, n_model) if cfg.is_moe else None

    def rule(path, leaf):
        names = [_key_name(p) for p in path]
        name = names[-1]
        in_moe = "moe" in names
        nd = leaf.ndim

        if name == "embed":
            return P(tp, None) if leaf.shape[0] % n_model == 0 else P()
        if name == "proj":
            return _spec_last(leaf, tp, n_model)

        if in_moe and name in ("w1", "w3", "w2"):
            if moe_mode == "expert":
                # [..., E, d, de] / [..., E, de, d] -> expert dim = -3
                if leaf.shape[nd - 3] % n_model == 0:
                    spec = [None] * nd
                    spec[nd - 3] = tp
                    return P(*spec)
                return P()
            # hidden mode: shard d_expert
            off = 1 if name in ("w1", "w3") else 2
            return _spec_last(leaf, tp, n_model, offset=off)
        if name == "router":
            return P()
        if name in ("sw1", "sw3"):
            return _spec_last(leaf, tp, n_model)
        if name == "sw2":
            return _spec_last(leaf, tp, n_model, offset=2)

        if name in ("wq", "bq"):
            ok = cfg.n_heads and cfg.n_heads % n_model == 0
            return _spec_last(leaf, tp, n_model) if ok else P()
        if name in ("wk", "wv", "bk", "bv"):
            ok = cfg.n_kv_heads and cfg.n_kv_heads % n_model == 0
            return _spec_last(leaf, tp, n_model) if ok else P()
        if name == "wo":
            ok = cfg.n_heads and cfg.n_heads % n_model == 0
            return _spec_last(leaf, tp, n_model, offset=2) if ok else P()

        if name in ("w1", "w3"):
            return _spec_last(leaf, tp, n_model)
        if name == "w2":
            return _spec_last(leaf, tp, n_model, offset=2)

        # ssm
        if name in ("wz", "wx"):
            return _spec_last(leaf, tp, n_model)
        if name in ("wB", "wC", "conv_B", "conv_C"):
            return P()
        if name == "wdt":
            return _spec_last(leaf, tp, n_model)
        if name == "conv_x":
            return _spec_last(leaf, tp, n_model, offset=2)
        if name in ("A_log", "D", "dt_bias"):
            return _spec_last(leaf, tp, n_model)
        if name == "out_proj":
            return _spec_last(leaf, tp, n_model, offset=2)
        if name == "norm":  # ssm gated-norm scale over d_inner
            return _spec_last(leaf, tp, n_model)

        return P()  # norms, gates, biases, scalars

    return jax.tree_util.tree_map_with_path(rule, params_abs)


def cache_specs(cfg: ArchConfig, cache_abs, *, dp, tp: str = "model",
                n_model: int = 16, n_dp: int = 16):
    """PartitionSpec tree for a decode cache.

    KV ring caches [.., B, W, Hkv, hd]: batch over dp when divisible; heads
    over tp when divisible, else the W (sequence) dim shards over tp.
    SSM states [.., B, h, n, p] / conv [.., B, C, K]: batch over dp, channel
    dim over tp.
    """
    def rule(path, leaf):
        name = _key_name(path[-1])
        nd = leaf.ndim
        if name in ("pos",):
            return P()
        if name == "slot_pos":
            return P()
        spec = [None] * nd
        if name in ("k", "v", "k0", "v0", "ak", "av", "mk", "mv"):
            b_dim, w_dim, h_dim = nd - 4, nd - 3, nd - 2
            if leaf.shape[b_dim] % n_dp == 0:
                spec[b_dim] = dp
            if leaf.shape[h_dim] % n_model == 0:
                spec[h_dim] = tp
            elif leaf.shape[w_dim] % n_model == 0:
                spec[w_dim] = tp
            return P(*spec)
        if name.startswith("ssm"):
            b_dim, h_dim = nd - 4, nd - 3
            if leaf.shape[b_dim] % n_dp == 0:
                spec[b_dim] = dp
            if leaf.shape[h_dim] % n_model == 0:
                spec[h_dim] = tp
            return P(*spec)
        if name.startswith("conv_"):
            b_dim, c_dim = nd - 3, nd - 2
            if leaf.shape[b_dim] % n_dp == 0:
                spec[b_dim] = dp
            if leaf.shape[c_dim] % n_model == 0:
                spec[c_dim] = tp
            return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_abs)


def batch_specs(batch_abs, *, dp, n_dp: int):
    """tokens/frames/patch_embeds: batch over dp when divisible."""
    def rule(path, leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim >= 1 and leaf.shape[0] % n_dp == 0:
            spec[0] = dp
        return P(*spec)
    return jax.tree_util.tree_map_with_path(rule, batch_abs)


def zero_specs(params_abs, pspecs, *, dp, n_dp: int):
    """ZeRO-1: additionally shard optimizer moments over the data axes.

    For each leaf, add `dp` on the largest dim that is (a) unsharded in the
    param spec and (b) divisible by the data-parallel world size. GSPMD then
    reduce-scatters grads into the sharded moments and all-gathers updated
    params — optimizer state per device drops ~n_dp x (the difference
    between a 1T-param model fitting the pod or not; see EXPERIMENTS §Perf).
    """
    def rule(leaf, spec):
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        best, best_size = None, 0
        for i in range(leaf.ndim):
            if dims[i] is None and leaf.shape[i] % n_dp == 0 \
                    and leaf.shape[i] > best_size:
                best, best_size = i, leaf.shape[i]
        if best is None:
            return P(*dims) if any(d is not None for d in dims) else P()
        dims[best] = dp
        return P(*dims)

    return jax.tree.map(rule, params_abs, pspecs,
                        is_leaf=lambda x: isinstance(x, P))
