"""repro.obs — the observability spine of the serving stack.

Three pieces, one discipline (enforced by the ``obs-discipline`` lint in
``repro.analysis``):

  * ``metrics``      — the typed metric registry (Counter / Gauge /
    Histogram with labels). Every number the stack tracks lives here;
    legacy attributes (``PerfCounters`` fields, ``prefilled_tokens``,
    ``ReplicaPool.handoff_bytes``, ...) are thin read-only views over it.
  * ``spans``        — low-overhead request-lifecycle + engine-phase span
    recorder (ring-buffer bounded, off by default, sampled when on) and
    the ONE monotonic clock every serving timestamp shares.
  * ``chrome_trace`` — export recorded spans as a Perfetto /
    chrome://tracing JSON: one track (pid) per replica, one lane (tid)
    per phase, flow arrows linking disagg handoff hops across tracks.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               validate_metrics_snapshot)
from repro.obs.spans import SPAN_LANES, Span, SpanRecorder, monotonic
from repro.obs.chrome_trace import (to_chrome_trace, validate_trace,
                                    write_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "validate_metrics_snapshot",
    "SPAN_LANES", "Span", "SpanRecorder", "monotonic",
    "to_chrome_trace", "validate_trace", "write_trace",
]
