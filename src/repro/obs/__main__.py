"""Validate observability artifacts against their schemas.

    python -m repro.obs --trace results/trace.json --metrics results/metrics.json

Exits nonzero listing every schema violation — CI runs this over the
Perfetto trace + metrics snapshot dumped by the disagg bench smoke so a
drifting exporter fails the build rather than producing a file Perfetto
silently refuses to load.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.chrome_trace import validate_trace
from repro.obs.metrics import validate_metrics_snapshot


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--trace", help="Perfetto/chrome-trace JSON to validate")
    ap.add_argument("--metrics", help="metrics snapshot JSON to validate")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to validate: pass --trace and/or --metrics")

    n_err = 0
    for label, path, validate in (("trace", args.trace, validate_trace),
                                  ("metrics", args.metrics,
                                   validate_metrics_snapshot)):
        if not path:
            continue
        with open(path) as f:
            obj = json.load(f)
        errs = validate(obj)
        if errs:
            n_err += len(errs)
            for e in errs:
                print(f"{label} {path}: {e}")
        else:
            kind = ("traceEvents" if label == "trace" else "metrics")
            n = len(obj.get("traceEvents", obj)) if isinstance(obj, dict) else 0
            print(f"{label} {path}: OK ({n} {kind})")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
