"""Typed metric registry — the single home for every number the stack tracks.

Three instrument kinds, all label-aware:

  * :class:`Counter`   — monotonically increasing float (``inc``).
  * :class:`Gauge`     — point-in-time value: pushed (``set`` /
    ``max_update``) or *pulled* through a zero-arg callback evaluated at
    read time (``fn=``), which is how derived quantities (TBT sketch
    percentiles, residency hit counts, paused KV bytes) surface without
    double bookkeeping.
  * :class:`Histogram` — count/sum/min/max plus streaming P² quantile
    sketches (reusing :class:`repro.core.qos.P2Quantile`).

A registry hands out instruments keyed by ``(name, sorted(labels))`` —
asking twice returns the same object, so hot paths hold pre-bound handles
and never do a dict lookup per event. ``snapshot()`` returns a plain dict
(JSON-ready) and ``exposition()`` renders Prometheus text format. No
external dependencies; everything is hand-rolled on stdlib.

Legacy attributes elsewhere in the stack (``PerfCounters`` fields,
``BatchedServingEngine.prefilled_tokens``, ``ReplicaPool.handoff_bytes``,
``QosAutopilot.by_reason``, ...) are thin read-only views over registry
instruments; the ``obs-discipline`` lint in ``repro.analysis`` rejects
direct writes to them.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.qos import P2Quantile

Number = Union[int, float]

# Snapshot schema identifier, embedded by dump helpers and checked by
# validate_metrics_snapshot on the CI artifacts.
METRICS_SCHEMA = "repro.obs.metrics/1"


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class Counter:
    """Monotonic counter. ``inc`` only; negative increments are a bug."""

    __slots__ = ("name", "labels", "_v")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._v = 0.0

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {n})")
        self._v += n

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    """Point-in-time value; pushed via ``set``/``max_update`` or pulled
    through ``fn`` (a zero-arg callable evaluated at every read)."""

    __slots__ = ("name", "labels", "_v", "fn")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = (),
                 fn: Optional[Callable[[], Number]] = None):
        self.name = name
        self.labels = labels
        self._v = 0.0
        self.fn = fn

    def set(self, v: Number) -> None:
        if self.fn is not None:
            raise ValueError(f"gauge {self.name} is pull-mode (fn=); cannot set")
        self._v = float(v)

    def max_update(self, v: Number) -> None:
        if self.fn is not None:
            raise ValueError(f"gauge {self.name} is pull-mode (fn=); cannot set")
        if v > self._v:
            self._v = float(v)

    @property
    def value(self) -> float:
        if self.fn is not None:
            return float(self.fn())
        return self._v


class Histogram:
    """count/sum/min/max plus P² streaming quantile sketches."""

    __slots__ = ("name", "labels", "qs", "count", "sum", "min", "max", "_sketch")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = (),
                 qs: Sequence[int] = (50, 99)):
        self.name = name
        self.labels = labels
        self.qs = tuple(qs)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._sketch = {q: P2Quantile(q / 100.0) for q in self.qs}

    def observe(self, x: Number) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        for sk in self._sketch.values():
            sk.update(x)

    def quantile(self, q: int) -> float:
        return float(self.sketch_value(q))

    def sketch_value(self, q: int) -> float:
        v = self._sketch[q].value()
        return float(v) if v is not None else float("nan")

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {"count": float(self.count), "sum": self.sum}
        if self.count:
            out["min"] = self.min
            out["max"] = self.max
            for q in self.qs:
                out[f"p{q}"] = self.sketch_value(q)
        return out


class MetricsRegistry:
    """Get-or-create instrument factory plus snapshot/exposition."""

    def __init__(self) -> None:
        # name -> (kind, help); instruments keyed by (name, label_key).
        self._meta: Dict[str, Tuple[str, str]] = {}
        self._instruments: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                                Union[Counter, Gauge, Histogram]] = {}

    # -- factories ---------------------------------------------------------
    def _get(self, kind: str, name: str, help: str, key, build):
        meta = self._meta.get(name)
        if meta is None:
            self._meta[name] = (kind, help)
        elif meta[0] != kind:
            raise ValueError(
                f"metric {name} already registered as {meta[0]}, not {kind}")
        inst = self._instruments.get((name, key))
        if inst is None:
            inst = build()
            self._instruments[(name, key)] = inst
        return inst

    def counter(self, name: str, help: str = "", **labels: str) -> Counter:
        key = _label_key(labels)
        return self._get("counter", name, help, key, lambda: Counter(name, key))

    def gauge(self, name: str, help: str = "",
              fn: Optional[Callable[[], Number]] = None, **labels: str) -> Gauge:
        key = _label_key(labels)
        g = self._get("gauge", name, help, key, lambda: Gauge(name, key, fn))
        if fn is not None and g.fn is None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "", qs: Sequence[int] = (50, 99),
                  **labels: str) -> Histogram:
        key = _label_key(labels)
        return self._get("histogram", name, help, key,
                         lambda: Histogram(name, key, qs))

    # -- views -------------------------------------------------------------
    def names(self) -> List[str]:
        return sorted(self._meta)

    def series(self, name: str):
        """All instruments registered under ``name`` (one per label set)."""
        return [inst for (n, _), inst in sorted(self._instruments.items())
                if n == name]

    def snapshot(self) -> Dict[str, Union[float, Dict[str, float]]]:
        """Flat dict: ``name{label="v"}`` -> value (hist -> summary dict)."""
        out: Dict[str, Union[float, Dict[str, float]]] = {}
        for (name, key), inst in sorted(self._instruments.items()):
            full = name + _label_str(key)
            if isinstance(inst, Histogram):
                out[full] = inst.summary()
            else:
                out[full] = inst.value
        return out

    def exposition(self) -> str:
        """Prometheus text exposition (histograms as summaries)."""
        lines: List[str] = []
        for name in self.names():
            kind, help = self._meta[name]
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {'summary' if kind == 'histogram' else kind}")
            for inst in self.series(name):
                ls = _label_str(inst.labels)
                if isinstance(inst, Histogram):
                    for q in inst.qs:
                        qk = list(inst.labels) + [("quantile", f"{q / 100.0:g}")]
                        v = inst.sketch_value(q)
                        lines.append(f"{name}{_label_str(tuple(qk))} {_fmt(v)}")
                    lines.append(f"{name}_sum{ls} {_fmt(inst.sum)}")
                    lines.append(f"{name}_count{ls} {inst.count}")
                else:
                    lines.append(f"{name}{ls} {_fmt(inst.value)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    if v != v:  # NaN
        return "NaN"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def validate_metrics_snapshot(obj) -> List[str]:
    """Schema check for a dumped metrics snapshot (possibly nested:
    ``{"schema": ..., "cluster": {...}, "replicas": [{...}, ...]}``).
    Returns a list of error strings; empty means valid."""
    errs: List[str] = []

    def leaves(prefix: str, v) -> None:
        if isinstance(v, dict):
            for k, sub in v.items():
                if not isinstance(k, str):
                    errs.append(f"{prefix}: non-string key {k!r}")
                else:
                    leaves(f"{prefix}.{k}" if prefix else k, sub)
        elif isinstance(v, list):
            for i, sub in enumerate(v):
                leaves(f"{prefix}[{i}]", sub)
        elif isinstance(v, bool) or v is None:
            errs.append(f"{prefix}: metric value must be a number, got {v!r}")
        elif isinstance(v, (int, float)):
            if isinstance(v, float) and math.isinf(v):
                errs.append(f"{prefix}: non-finite value {v!r}")
        elif isinstance(v, str):
            pass  # schema tag / annotations
        else:
            errs.append(f"{prefix}: unsupported type {type(v).__name__}")

    if not isinstance(obj, dict):
        return [f"snapshot must be a dict, got {type(obj).__name__}"]
    if obj.get("schema") != METRICS_SCHEMA:
        errs.append(f"schema must be {METRICS_SCHEMA!r}, got {obj.get('schema')!r}")
    leaves("", obj)
    return errs
