"""Export recorded spans as Perfetto / chrome://tracing JSON.

Layout: one *process* (pid) per replica, one *thread* (tid) per lane —
so a 2-replica disagg run renders as two stacked tracks, each with
``lifecycle`` / ``prefill-chunk`` / ``batched-decode`` /
``expert-prefetch`` lanes, and the two-stream overlap (prefetch vs.
compute) is visible as parallel bars rather than inferred from counters.

Disagg handoff hops are drawn as flow arrows: ``ReplicaPool.migrate``
emits a ``handoff.snapshot`` instant on the source recorder and a
``handoff.restore`` instant on the destination recorder sharing a
``flow`` id; the exporter pairs them into ``ph="s"`` / ``ph="f"``
events, which Perfetto renders as an arrow from the source track to the
destination track.

Open the output at https://ui.perfetto.dev (or chrome://tracing): load
the JSON file directly, no conversion needed.
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence

from repro.obs.spans import SPAN_LANES, SpanRecorder

TRACE_SCHEMA = "repro.obs.trace/1"

# Lane -> tid; names shown in the Perfetto track list.
LANE_TID = {lane: i for i, lane in enumerate(SPAN_LANES)}
LANE_NAMES = {
    "lifecycle": "lifecycle",
    "prefill": "prefill-chunk",
    "decode": "batched-decode",
    "prefetch": "expert-prefetch",
}

_FLOW_START = "handoff.snapshot"
_FLOW_FINISH = "handoff.restore"


def to_chrome_trace(recorders: Sequence[SpanRecorder]) -> Dict[str, object]:
    """Merge per-replica recorders into one chrome://tracing dict."""
    all_spans = [(rec.replica, s) for rec in recorders for s in rec.spans()]
    t_zero = min((s.t0 for _, s in all_spans), default=0.0)

    def us(t: float) -> float:
        return round((t - t_zero) * 1e6, 3)

    events: List[Dict[str, object]] = []
    for rec in recorders:
        pid = rec.replica
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name",
                       "args": {"name": f"replica {pid}"}})
        for lane, tid in LANE_TID.items():
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": LANE_NAMES[lane]}})
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_sort_index",
                       "args": {"sort_index": pid}})

    for pid, s in all_spans:
        tid = LANE_TID.get(s.lane, 0)
        args = {k: v for k, v in s.args.items()}
        if s.rid is not None:
            args["rid"] = s.rid
        if s.t1 > s.t0:
            events.append({"ph": "X", "pid": pid, "tid": tid, "name": s.name,
                           "cat": s.lane, "ts": us(s.t0),
                           "dur": round(s.dur * 1e6, 3), "args": args})
        else:
            events.append({"ph": "i", "pid": pid, "tid": tid, "name": s.name,
                           "cat": s.lane, "ts": us(s.t0), "s": "t",
                           "args": args})
        flow = s.args.get("flow")
        if flow is not None and s.name in (_FLOW_START, _FLOW_FINISH):
            ph = "s" if s.name == _FLOW_START else "f"
            ev = {"ph": ph, "pid": pid, "tid": tid, "name": "handoff",
                  "cat": "handoff", "id": int(flow), "ts": us(s.t0)}
            if ph == "f":
                ev["bp"] = "e"
            events.append(ev)

    return {"schema": TRACE_SCHEMA, "displayTimeUnit": "ms",
            "traceEvents": events}


def validate_trace(trace) -> List[str]:
    """Schema check for an exported trace. Returns error strings; empty
    means valid. Also checks flow pairing: every flow id must have both a
    start ("s") and a finish ("f") event."""
    errs: List[str] = []
    if not isinstance(trace, dict):
        return [f"trace must be a dict, got {type(trace).__name__}"]
    if trace.get("schema") != TRACE_SCHEMA:
        errs.append(f"schema must be {TRACE_SCHEMA!r}, got {trace.get('schema')!r}")
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return errs + ["traceEvents must be a list"]
    flows: Dict[int, set] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"traceEvents[{i}]: not a dict")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "s", "f"):
            errs.append(f"traceEvents[{i}]: bad ph {ph!r}")
            continue
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                errs.append(f"traceEvents[{i}]: {k} must be an int")
        if not isinstance(ev.get("name"), str):
            errs.append(f"traceEvents[{i}]: name must be a string")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                errs.append(f"traceEvents[{i}]: ts must be a number >= 0")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"traceEvents[{i}]: dur must be a number >= 0")
        if ph in ("s", "f"):
            flows.setdefault(ev.get("id"), set()).add(ph)
    for fid, phs in sorted(flows.items(), key=lambda kv: (str(kv[0]),)):
        if phs != {"s", "f"}:
            errs.append(f"flow {fid}: unpaired (has {sorted(phs)}, "
                        f"needs both 's' and 'f')")
    return errs


def write_trace(path: str, recorders: Sequence[SpanRecorder]) -> Dict[str, object]:
    """Export + validate + write; returns the trace dict."""
    trace = to_chrome_trace(recorders)
    errs = validate_trace(trace)
    if errs:
        raise ValueError("invalid trace: " + "; ".join(errs[:5]))
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace
