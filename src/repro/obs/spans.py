"""Request-lifecycle and engine-phase span recorder.

One :class:`SpanRecorder` per engine (``engine.obs``). Spans are opened
and closed ONLY at the lifecycle points declared in
``repro.analysis.rules.SPAN_SCOPES`` — anywhere else is a lint finding —
so the taxonomy stays small enough to read as a timeline:

  lifecycle lane : request.queued → request.admitted → request.paused /
                   request.restored → terminal (length / stop_token /
                   cancelled / slo_shed / rejected), plus
                   handoff.snapshot / handoff.restore flow endpoints and
                   autopilot.shed / autopilot.preempt annotations
  prefill lane   : prefill (monolithic) / prefill.chunk spans
  decode lane    : decode.step spans, ffn.launch / kv.scatter instants
  prefetch lane  : prefetch.correction spans, prefetch.dispatch instants

Design constraints, in order:

  * **Off by default, cheap when off.** Every public method starts with
    an ``enabled`` check; disabled cost is one attribute load + branch.
  * **Bounded.** Closed spans live in a ``deque(maxlen=capacity)`` ring;
    open spans live in a separate dict keyed by the token ``begin``
    returned, so ring eviction structurally cannot orphan an open span.
  * **Sampled.** Per-request spans are kept for a deterministic hash
    subset of rids (``sample=``); engine-phase spans (``rid=None``) are
    always kept when enabled, they are O(1) per step.
  * **One clock.** ``monotonic()`` is the single time source for spans
    AND for ``RequestSnapshot.t_snapshot`` / ``RequestHandle.handoffs``
    ``t_restore`` — handoff latency can never go negative under
    wall-clock adjustment because nothing here reads wall clock.
  * **Terminal integrity.** ``terminal(rid, reason)`` raises on a second
    terminal for the same rid; tests drive every finish path through
    this check. (A restored request has a NEW rid — handoff chains are
    linked by flow ids, not by rid reuse.)
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

# The one monotonic clock shared by spans, snapshot/restore stamps, and
# handoff records. perf_counter is monotonic and unaffected by NTP slew.
monotonic = time.perf_counter

# Lane taxonomy: maps to one Perfetto track-thread per replica-process.
SPAN_LANES = ("lifecycle", "prefill", "decode", "prefetch")

# Knuth multiplicative hash for deterministic rid sampling.
_HASH_K = 2654435761
_HASH_M = float(1 << 32)


@dataclass
class Span:
    """One recorded interval (or instant, when ``t1 == t0``)."""
    name: str
    lane: str
    t0: float
    t1: float
    rid: Optional[int] = None
    args: Dict[str, object] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class SpanRecorder:
    """Ring-buffer-bounded span sink for one engine/replica."""

    def __init__(self, enabled: bool = False, capacity: int = 8192,
                 sample: float = 1.0, replica: int = 0):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.sample = float(sample)
        self.replica = int(replica)
        self.closed: Deque[Span] = collections.deque(maxlen=self.capacity)
        self._open: Dict[int, Span] = {}
        self._next_token = 0
        # rid -> terminal reason; bounded FIFO so a long-lived server
        # doesn't accumulate one entry per request forever.
        self._terminal: Dict[int, str] = {}
        self._terminal_order: Deque[int] = collections.deque()
        self._terminal_window = max(4 * self.capacity, 65536)
        self.n_dropped = 0  # closed spans evicted by the ring

    # -- sampling ----------------------------------------------------------
    def sampled(self, rid: Optional[int]) -> bool:
        """Deterministic: the same rid is kept or dropped consistently, so
        a kept request's lifecycle is complete rather than gap-toothed."""
        if rid is None or self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        return ((abs(int(rid)) * _HASH_K) & 0xFFFFFFFF) / _HASH_M < self.sample

    # -- span API ----------------------------------------------------------
    def begin(self, name: str, lane: str = "lifecycle",
              rid: Optional[int] = None, **args) -> Optional[int]:
        """Open a span; returns an opaque token for ``end`` (None when
        disabled or sampled out — ``end(None)`` is a no-op)."""
        if not self.enabled or not self.sampled(rid):
            return None
        tok = self._next_token
        self._next_token += 1
        self._open[tok] = Span(name, lane, monotonic(), 0.0, rid, args)
        return tok

    def end(self, token: Optional[int], **args) -> None:
        if token is None:
            return
        span = self._open.pop(token, None)
        if span is None:
            raise ValueError(f"span token {token} ended twice or never opened")
        span.t1 = monotonic()
        if args:
            span.args.update(args)
        if len(self.closed) == self.capacity:
            self.n_dropped += 1
        self.closed.append(span)

    def instant(self, name: str, lane: str = "lifecycle",
                rid: Optional[int] = None, **args) -> None:
        if not self.enabled or not self.sampled(rid):
            return
        t = monotonic()
        if len(self.closed) == self.capacity:
            self.n_dropped += 1
        self.closed.append(Span(name, lane, t, t, rid, args))

    def terminal(self, rid: int, reason: str, **args) -> None:
        """Record the request's ONE terminal transition. A second terminal
        for the same rid is a lifecycle bug and raises immediately."""
        if not self.enabled:
            return
        prev = self._terminal.get(rid)
        if prev is not None:
            raise RuntimeError(
                f"rid {rid} reached a second terminal {reason!r} "
                f"(already {prev!r})")
        self._terminal[rid] = reason
        self._terminal_order.append(rid)
        while len(self._terminal_order) > self._terminal_window:
            self._terminal.pop(self._terminal_order.popleft(), None)
        self.instant(f"request.{reason}", lane="lifecycle", rid=rid,
                     reason=reason, **args)

    # -- views -------------------------------------------------------------
    def spans(self) -> List[Span]:
        """Closed spans, oldest first."""
        return list(self.closed)

    def open_spans(self) -> List[Span]:
        return list(self._open.values())

    def terminal_reasons(self) -> Dict[int, str]:
        return dict(self._terminal)

    def clear(self) -> None:
        self.closed.clear()
        self._open.clear()
        self._terminal.clear()
        self._terminal_order.clear()
        self.n_dropped = 0
