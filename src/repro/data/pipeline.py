"""Deterministic synthetic data pipeline.

Two roles:
  1. LM training batches — Zipfian token streams with short-range structure
     (Markov bigram mixing) so losses actually decrease.
  2. Serving/trace workloads — stand-ins for the paper's SQuAD and Orca-Math
     datasets. Each "dataset" is a family of prompts drawn from topic
     clusters; clusters induce *structured expert routing* (popularity +
     inter-layer affinity) exactly the property the DuoServe predictor
     exploits. SQuAD-like = shorter prompts, more clusters; Orca-like =
     longer prompts, fewer, mathier clusters.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    vocab: int
    n_clusters: int
    prompt_len: Tuple[int, int]   # (min, max)
    zipf_a: float = 1.3


def squad_like(vocab: int) -> DatasetSpec:
    return DatasetSpec("squad", vocab, n_clusters=12, prompt_len=(32, 128))


def orca_like(vocab: int) -> DatasetSpec:
    return DatasetSpec("orca", vocab, n_clusters=6, prompt_len=(64, 256),
                       zipf_a=1.15)


class SyntheticLM:
    """Zipf+bigram token stream for training runs."""

    def __init__(self, vocab: int, seed: int = 0, zipf_a: float = 1.3):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        # sparse bigram successor table: each token prefers 4 successors
        self.succ = np.random.default_rng(seed + 1).integers(
            0, vocab, size=(min(vocab, 4096), 4))

    def _zipf(self, n: int) -> np.ndarray:
        z = self.rng.zipf(self.zipf_a, size=n)
        return np.minimum(z - 1, self.vocab - 1).astype(np.int32)

    def sequence(self, length: int) -> np.ndarray:
        toks = self._zipf(length)
        # 50% of positions follow the bigram table (structure to learn)
        follow = self.rng.random(length) < 0.5
        for i in range(1, length):
            if follow[i]:
                prev = toks[i - 1] % self.succ.shape[0]
                toks[i] = self.succ[prev, self.rng.integers(0, 4)]
        return toks

    def batches(self, batch: int, seq: int) -> Iterator[np.ndarray]:
        while True:
            yield np.stack([self.sequence(seq) for _ in range(batch)])


class PromptWorkload:
    """Serving workload: prompts drawn from topic clusters.

    Each cluster biases tokens to a band of the vocab; MoE routers therefore
    develop cluster-conditioned expert preferences, giving the activation
    traces genuine popularity/affinity structure.
    """

    def __init__(self, spec: DatasetSpec, seed: int = 0):
        self.spec = spec
        self.rng = np.random.default_rng(seed)
        self.centers = self.rng.integers(
            0, spec.vocab, size=(spec.n_clusters,))
        self.band = max(spec.vocab // (2 * spec.n_clusters), 16)

    def prompt(self) -> Tuple[np.ndarray, int]:
        c = int(self.rng.integers(0, self.spec.n_clusters))
        lo, hi = self.spec.prompt_len
        n = int(self.rng.integers(lo, hi + 1))
        base = self.centers[c]
        toks = (base + self.rng.integers(-self.band, self.band, size=n))
        toks = np.mod(toks, self.spec.vocab).astype(np.int32)
        return toks, c

    def prompts(self, n: int) -> List[Tuple[np.ndarray, int]]:
        return [self.prompt() for _ in range(n)]


def pad_batch(prompts: List[np.ndarray], pad_id: int = 0):
    """Left-pad to a rectangle; returns (tokens [B,S], lengths [B])."""
    lens = np.array([len(p) for p in prompts])
    s = int(lens.max())
    out = np.full((len(prompts), s), pad_id, np.int32)
    for i, p in enumerate(prompts):
        out[i, s - len(p):] = p
    return out, lens
