"""Static-analysis suite guarding the serving stack's architectural
invariants: an AST lint (sync, emit, residency, jit, recompile discipline)
and a jaxpr auditor (host callbacks, captured constants, donation,
compile-key enumeration).  Run as ``python -m repro.analysis``; see
docs/INVARIANTS.md for the rule catalogue."""
from .lint import AllowEntry, Finding, LintReport, load_allowlist, run_lint
from .rules import ALL_RULES

__all__ = [
    "AllowEntry",
    "Finding",
    "LintReport",
    "load_allowlist",
    "run_lint",
    "ALL_RULES",
]
