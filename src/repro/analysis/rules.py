"""The repo-specific lint rules.

Each rule encodes an architectural invariant of the serving stack (see
docs/INVARIANTS.md for the catalogue).  Rules are deliberately
*codebase-aware*: the scope registries below name the exact hot paths,
sanctioned writers, and registered bucketing helpers, so a new call site
has to either follow the discipline or earn an allowlist entry with a
written justification.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .lint import Finding, ModuleInfo, Rule, dotted_name, first_arg_src


# ==========================================================================
# registries (the codebase-aware part)
# ==========================================================================

# --- sync-point -----------------------------------------------------------
# Per-token / per-chunk hot scopes: any host<->device synchronization here
# must be a declared dispatch point (allowlisted) or it stalls the decode
# tail that DuoServe's prefetch overlap is supposed to protect.
SYNC_HOT_SCOPES: Dict[str, Tuple[str, ...]] = {
    "serving/engine.py": (
        "EngineCore._grouped_ffn_raw",
        "EngineCore._run_experts_prefill",
        "EngineCore._run_experts_prefill_fused",
        "EngineCore._prefill_moe",
        "EngineCore._sample",
        "MoEServingEngine.prefill",
        "MoEServingEngine.prefill_chunk",
        "MoEServingEngine._prefill_layers_chunked",
        "MoEServingEngine.decode",
    ),
    "serving/batching.py": (
        "BatchedServingEngine.step",
        "BatchedServingEngine._decode_step",
        "BatchedServingEngine._prefill_work",
        "BatchedServingEngine._run_prefill_chunk",
        "BatchedServingEngine._admit_and_prefill",
        "BatchedServingEngine._sample_req",
        "BatchedServingEngine._emit_token",
    ),
    "core/cache.py": (
        "CacheState.*",
        "ExpertResidency.*",
        "_pool_write",
    ),
    "kernels/*.py": ("*",),
}

# Callables that force a host sync (or a host->device transfer) when handed
# a device value.  jnp.asarray is deliberately absent: it dispatches on
# device without a readback.
SYNC_CALLS: Set[str] = {
    "np.asarray", "np.array", "np.fromiter",
    "numpy.asarray", "numpy.array", "numpy.fromiter",
    "asarray", "fromiter",
    "jax.device_put", "jax.device_get", "device_put", "device_get",
    "float",
}
SYNC_METHODS: Set[str] = {"item", "block_until_ready", "tolist", "to_py"}

# --- emit-discipline ------------------------------------------------------
# The one sink every streamed token funnels through (PR 4); the event
# buffer itself is only touched by EngineCore._emit.
EMIT_BUFFER_OWNER = "EngineCore._emit"
TOKEN_EVENT_SINKS: Tuple[str, ...] = (
    "BatchedServingEngine._emit_token",
    "MoEServingEngine._emit_token",
)

# --- residency-discipline -------------------------------------------------
# Device-resident state with exactly one owner: the expert slot pools
# belong to ExpertResidency (PR 3); the KV slot pools and the slot_pos
# ledger belong to the declared engine writers below.
PROTECTED_STATE: Set[str] = {"_pools", "_K", "_V", "_slot_pos"}
RESIDENCY_WRITERS: Tuple[str, ...] = (
    "ExpertResidency.*",                     # the pools' owner (core/cache.py)
    "BatchedServingEngine.__init__",         # allocation
    "BatchedServingEngine._decode_step",     # per-step KV append
    "BatchedServingEngine._run_prefill_chunk",
    "BatchedServingEngine._admit_and_prefill",
    "BatchedServingEngine.restore",          # snapshot handoff scatter
    "BatchedServingEngine._release_slot",    # slot_pos invalidation
)

# --- jit-hygiene ----------------------------------------------------------
# In the serving stack every jitted kernel is defined once, at engine
# construction, inside EngineCore._jit_fns; core/ and kernels/ may define
# module-level jitted functions.  jax.jit in a loop body or invoked inline
# re-traces per call.
JIT_SETUP_SCOPES: Tuple[str, ...] = ("EngineCore._jit_fns", "EngineCore._jit_fns.*")
SERVING_JIT_FILES: Tuple[str, ...] = (
    "serving/engine.py", "serving/batching.py", "serving/cluster.py",
    "serving/frontend.py",
)
# self.<attr> that jitted closures must NOT capture: mutable per-request /
# per-step state.  Capturing one freezes a stale value into the trace (or
# worse, retraces per object identity).
JIT_MUTABLE_SELF: Set[str] = {
    "cache", "sched", "store", "perf", "prefix", "queue", "dev",
    "running", "prefilling", "_K", "_V", "_slot_pos", "_events", "_pools",
    "_free_slots", "_arrivals", "metrics", "obs",
}

# --- recompile-hazard -----------------------------------------------------
# Jitted callees reachable from the engines; an argument whose shape is
# data-dependent (slice bounds / constructed shapes from un-bucketed
# values) recompiles per value.
REGISTERED_JIT_CALLEES: Set[str] = {
    "_attn_prefill", "_attn_prefill_chunk", "_attn_decode",
    "_attn_decode_batched", "_gate", "_expert_raw", "_grouped_raw",
    "_expert", "_shared", "_head",
    "expert_ffn", "expert_ffn_from_pool", "_pool_write",
}
# Helpers whose results are *sanctioned* shape sources: power-of-two
# bucketing keeps the distinct-shape count logarithmic.
BUCKETING_HELPERS: Set[str] = {"_bucket", "group_by_expert", "vocab_pad_of"}

# --- obs-discipline -------------------------------------------------------
# (a) Aggregates migrated onto the repro.obs metrics registry (PR 10).
# The old attribute names survive as read-only registry views; a direct
# write bypasses the registry and silently forks the bookkeeping.
MIGRATED_METRICS: Set[str] = {
    # BatchedServingEngine
    "prefilled_tokens",
    # ReplicaPool
    "n_handoffs", "n_migrated", "handoff_bytes", "handoff_bytes_saved",
    "n_tail_handoffs",
    # QosAutopilot
    "n_shed", "by_reason", "n_preempted", "n_resumed",
}
# (b) Span lifecycle discipline: SpanRecorder mutators may be called only
# at the declared request-lifecycle / engine-phase points below, so the
# span taxonomy stays small enough to read as a timeline.  Read-only
# recorder views (spans(), terminal_reasons(), ...) are fine anywhere.
SPAN_METHODS: Set[str] = {"begin", "end", "instant", "terminal"}
SPAN_SCOPES: Dict[str, Tuple[str, ...]] = {
    "serving/engine.py": (
        "MoEServingEngine.decode",
    ),
    "serving/batching.py": (
        "BatchedServingEngine.submit_request",
        "BatchedServingEngine._admit_and_prefill",
        "BatchedServingEngine._run_prefill_chunk",
        "BatchedServingEngine._decode_step",
        "BatchedServingEngine.cancel",
        "BatchedServingEngine._retire",
        "BatchedServingEngine.snapshot",
        "BatchedServingEngine.restore",
    ),
    "serving/cluster.py": (
        "ReplicaPool.migrate",
        "QosAutopilot.scan",
        "QosAutopilot._scan_preempt",
    ),
    "serving/frontend.py": (
        "CooperativeDriver._cancel_paused",
    ),
}


# ==========================================================================
# helpers
# ==========================================================================


def _scope_matches(scope: str, patterns: Sequence[str]) -> bool:
    return any(fnmatch.fnmatch(scope, p) for p in patterns)


def _is_host_literal(node: ast.AST) -> bool:
    """Args that are plainly host-side: literals and comprehensions over
    host lists.  np.asarray over these is list->array packing, not a
    device sync."""
    return isinstance(
        node,
        (ast.List, ast.Tuple, ast.Set, ast.Dict, ast.ListComp,
         ast.SetComp, ast.DictComp, ast.GeneratorExp, ast.Constant),
    )


def _attr_root(node: ast.AST) -> Optional[str]:
    """For a target like ``self._K[l]`` or ``self._pools["w1"]`` return the
    protected attribute name (``_K``); None if not an attribute chain."""
    while isinstance(node, (ast.Subscript, ast.Starred)):
        node = node.value
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _iter_target_roots(target: ast.AST) -> Iterable[ast.AST]:
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _iter_target_roots(elt)
    else:
        yield target


def _is_jax_jit(node: ast.AST) -> bool:
    """True for ``jax.jit`` / ``jit`` names and for
    ``functools.partial(jax.jit, ...)`` calls."""
    name = dotted_name(node)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        if fn in ("functools.partial", "partial"):
            return any(_is_jax_jit(a) for a in node.args)
    return False


# ==========================================================================
# rules
# ==========================================================================


class SyncPointRule(Rule):
    id = "sync-point"
    paths = tuple(SYNC_HOT_SCOPES)

    def check(self, mod: ModuleInfo) -> Iterable[Finding]:
        patterns: Tuple[str, ...] = ()
        for glob, pats in SYNC_HOT_SCOPES.items():
            if fnmatch.fnmatch(mod.relpath, glob):
                patterns = patterns + pats
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            scope = mod.scope(node)
            if not _scope_matches(scope, patterns):
                continue
            name = dotted_name(node.func)
            finding = None
            if name in SYNC_CALLS:
                if node.args and _is_host_literal(node.args[0]):
                    continue
                finding = name
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in SYNC_METHODS
            ):
                finding = dotted_name(node.func)
            if finding is None:
                continue
            yield Finding(
                rule=self.id,
                path=mod.relpath,
                line=node.lineno,
                scope=scope,
                message=(
                    f"host sync `{finding}` on a per-token/per-chunk path; "
                    "syncs belong at declared dispatch points "
                    "(allowlist with justification if this is one)"
                ),
                call=finding,
                arg=first_arg_src(node),
            )


class EmitDisciplineRule(Rule):
    id = "emit-discipline"
    paths = ("serving/*.py",)

    def check(self, mod: ModuleInfo) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            scope = mod.scope(node)
            name = dotted_name(node.func)
            # (a) direct event-buffer append
            if name.endswith("._events.append") and scope != EMIT_BUFFER_OWNER:
                yield Finding(
                    rule=self.id, path=mod.relpath, line=node.lineno,
                    scope=scope, call=name, arg=first_arg_src(node),
                    message=(
                        "event buffer appended outside EngineCore._emit; "
                        "route events through self._emit(...)"
                    ),
                )
            # (b) TokenEvent construction outside the one token sink
            if name.split(".")[-1] == "TokenEvent" and not _scope_matches(
                scope, TOKEN_EVENT_SINKS
            ):
                yield Finding(
                    rule=self.id, path=mod.relpath, line=node.lineno,
                    scope=scope, call="TokenEvent", arg=first_arg_src(node),
                    message=(
                        "TokenEvent constructed outside the _emit_token sink; "
                        "every streamed token must funnel through one sink "
                        "so cancellation/TBT accounting stay exact"
                    ),
                )


class ResidencyDisciplineRule(Rule):
    id = "residency-discipline"
    paths = ("serving/*.py", "core/*.py")

    def check(self, mod: ModuleInfo) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            else:
                continue
            scope = mod.scope(node)
            for t in targets:
                for root in _iter_target_roots(t):
                    attr = _attr_root(root)
                    if attr not in PROTECTED_STATE:
                        continue
                    # ExpertResidency owns _pools; engine writers own KV
                    if _scope_matches(scope, RESIDENCY_WRITERS):
                        continue
                    yield Finding(
                        rule=self.id, path=mod.relpath, line=node.lineno,
                        scope=scope, call=attr,
                        arg=ast.unparse(t) if hasattr(ast, "unparse") else "",
                        message=(
                            f"mutation of protected device state `{attr}` "
                            "outside its declared owner scopes "
                            "(ExpertResidency / registered engine KV writers)"
                        ),
                    )


class JitHygieneRule(Rule):
    id = "jit-hygiene"
    paths = ("serving/*.py", "core/*.py", "kernels/*.py")

    def check(self, mod: ModuleInfo) -> Iterable[Finding]:
        yield from self._check_jit_calls(mod)
        if mod.relpath == "serving/engine.py":
            yield from self._check_closures(mod)

    def _check_jit_calls(self, mod: ModuleInfo) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and _is_jax_jit(node.func)):
                continue
            scope = mod.scope(node)
            if mod.loops(node) > 0:
                yield Finding(
                    rule=self.id, path=mod.relpath, line=node.lineno,
                    scope=scope, call="jax.jit",
                    message=(
                        "jax.jit invoked inside a loop body: a fresh jitted "
                        "callable per iteration defeats the compile cache"
                    ),
                )
                continue
            if (
                mod.relpath in SERVING_JIT_FILES
                and scope
                and not _scope_matches(scope, JIT_SETUP_SCOPES)
            ):
                yield Finding(
                    rule=self.id, path=mod.relpath, line=node.lineno,
                    scope=scope, call="jax.jit",
                    message=(
                        "jax.jit in a serving method body; jitted kernels are "
                        "defined once in EngineCore._jit_fns at construction"
                    ),
                )
        # immediately-invoked form: Call(func=Call(func=jax.jit))
        for node in ast.walk(mod.tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Call)
                and _is_jax_jit(node.func.func)
            ):
                yield Finding(
                    rule=self.id, path=mod.relpath, line=node.lineno,
                    scope=mod.scope(node), call="jax.jit",
                    message=(
                        "jax.jit(f)(...) invoked inline: the wrapper is "
                        "rebuilt (and retraced) on every call"
                    ),
                )

    def _check_closures(self, mod: ModuleInfo) -> Iterable[Finding]:
        """Inside EngineCore._jit_fns, jitted inner defs must not close over
        mutable per-request engine state."""
        fn = mod.functions.get("EngineCore._jit_fns")
        if fn is None:
            return
        for node in ast.walk(fn):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            jitted = any(_is_jax_jit(d) for d in node.decorator_list)
            if not jitted:
                continue
            for sub in ast.walk(node):
                if (
                    isinstance(sub, ast.Attribute)
                    and isinstance(sub.value, ast.Name)
                    and sub.value.id == "self"
                    and sub.attr in JIT_MUTABLE_SELF
                ):
                    yield Finding(
                        rule=self.id, path=mod.relpath, line=sub.lineno,
                        scope=f"EngineCore._jit_fns.{node.name}",
                        call=f"self.{sub.attr}",
                        message=(
                            f"jitted kernel closes over mutable engine state "
                            f"`self.{sub.attr}`: the traced value goes stale "
                            "(pass it as an argument instead)"
                        ),
                    )


class RecompileHazardRule(Rule):
    id = "recompile-hazard"
    paths = ("serving/*.py", "kernels/*.py")

    def check(self, mod: ModuleInfo) -> Iterable[Finding]:
        for qual, fn in mod.functions.items():
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            blessed = self._blessed_names(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if mod.scope(node) != qual:
                    continue
                callee = dotted_name(node.func).split(".")[-1]
                if callee not in REGISTERED_JIT_CALLEES:
                    continue
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    for issue, line in self._shape_hazards(arg, blessed):
                        yield Finding(
                            rule=self.id, path=mod.relpath, line=line,
                            scope=qual, call=callee,
                            arg=ast.unparse(arg),
                            message=(
                                f"data-dependent shape crosses the jit "
                                f"boundary of `{callee}`: {issue}; route it "
                                "through a registered bucketing helper "
                                "(_bucket / group_by_expert / vocab_pad_of)"
                            ),
                        )

    # -- taint: names derived from bucketing helpers are sanctioned --------

    def _blessed_names(self, fn: ast.AST) -> Set[str]:
        blessed: Set[str] = set()
        changed = True
        while changed:
            changed = False
            for node in ast.walk(fn):
                if not isinstance(node, ast.Assign):
                    continue
                if self._value_blessed(node.value, blessed):
                    for t in node.targets:
                        for root in _iter_target_roots(t):
                            if isinstance(root, ast.Name) and root.id not in blessed:
                                blessed.add(root.id)
                                changed = True
        return blessed

    def _value_blessed(self, value: ast.AST, blessed: Set[str]) -> bool:
        if isinstance(value, ast.Call):
            callee = dotted_name(value.func).split(".")[-1]
            if callee in BUCKETING_HELPERS:
                return True
        # attribute / subscript of a blessed name (disp.row_idx, shp[0])
        node = value
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        if isinstance(node, ast.Name) and node.id in blessed:
            return True
        return False

    # -- hazard detection --------------------------------------------------

    def _shape_hazards(
        self, arg: ast.AST, blessed: Set[str]
    ) -> Iterable[Tuple[str, int]]:
        for node in ast.walk(arg):
            if isinstance(node, ast.Slice):
                for bound in (node.lower, node.upper):
                    if bound is None or self._static_or_blessed(bound, blessed):
                        continue
                    yield (
                        f"slice bound `{ast.unparse(bound)}` is a runtime "
                        "value, so the sliced shape recompiles per value",
                        getattr(bound, "lineno", getattr(arg, "lineno", 0)),
                    )
            elif isinstance(node, ast.Call):
                ctor = dotted_name(node.func)
                if ctor.split(".")[-1] in ("zeros", "full", "empty", "ones"):
                    shape = node.args[0] if node.args else None
                    if shape is not None and not self._static_or_blessed(
                        shape, blessed
                    ):
                        yield (
                            f"array constructed with runtime shape "
                            f"`{ast.unparse(shape)}`",
                            node.lineno,
                        )

    def _static_or_blessed(self, node: ast.AST, blessed: Set[str]) -> bool:
        """A shape expression is static if its every leaf is a constant, a
        blessed name (derived from a bucketing helper), or an attribute
        chain rooted at ``self`` (per-engine config) or a blessed name."""
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return node.id in blessed or node.id == "self"
        if isinstance(node, (ast.Attribute, ast.Subscript)):
            root: ast.AST = node
            while isinstance(root, (ast.Attribute, ast.Subscript)):
                root = root.value
            return isinstance(root, ast.Name) and (
                root.id in blessed or root.id == "self"
            )
        if isinstance(node, ast.BinOp):
            return self._static_or_blessed(
                node.left, blessed
            ) and self._static_or_blessed(node.right, blessed)
        if isinstance(node, ast.UnaryOp):
            return self._static_or_blessed(node.operand, blessed)
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(self._static_or_blessed(e, blessed) for e in node.elts)
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func).split(".")[-1]
            if callee in BUCKETING_HELPERS:
                return True
            if callee in ("len", "min", "max", "int"):
                return all(
                    self._static_or_blessed(a, blessed) for a in node.args
                )
            return False
        return False


class ObsDisciplineRule(Rule):
    """Two disciplines from the observability layer (PR 10):

    (a) metrics migrated onto the registry are mutated ONLY through their
        registry instruments — writes to the legacy attribute names (now
        read-only views) or to ``*.perf.<field>`` fork the bookkeeping;
    (b) ``SpanRecorder`` mutators (``*.obs.begin/end/instant/terminal``)
        are called only at the lifecycle points declared in SPAN_SCOPES.
    """

    id = "obs-discipline"
    paths = ("serving/*.py", "core/*.py")

    def check(self, mod: ModuleInfo) -> Iterable[Finding]:
        yield from self._check_metric_writes(mod)
        yield from self._check_span_sites(mod)

    def _check_metric_writes(self, mod: ModuleInfo) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                targets: List[ast.AST] = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            else:
                continue
            scope = mod.scope(node)
            for t in targets:
                for root in _iter_target_roots(t):
                    while isinstance(root, (ast.Subscript, ast.Starred)):
                        root = root.value
                    if not isinstance(root, ast.Attribute):
                        continue
                    owner = root.value
                    owner_attr = (owner.attr
                                  if isinstance(owner, ast.Attribute) else None)
                    if root.attr in MIGRATED_METRICS:
                        yield Finding(
                            rule=self.id, path=mod.relpath, line=node.lineno,
                            scope=scope, call=root.attr,
                            arg=ast.unparse(t) if hasattr(ast, "unparse") else "",
                            message=(
                                f"write to `{root.attr}`, a metric migrated "
                                "to the repro.obs registry (the attribute is "
                                "a read-only view); mutate the registry "
                                "instrument instead"
                            ),
                        )
                    elif owner_attr == "perf":
                        yield Finding(
                            rule=self.id, path=mod.relpath, line=node.lineno,
                            scope=scope, call=f"perf.{root.attr}",
                            arg=ast.unparse(t) if hasattr(ast, "unparse") else "",
                            message=(
                                f"direct write to PerfCounters field "
                                f"`{root.attr}`; mutate via "
                                "perf.inc()/perf.max_update() so the registry "
                                "stays the single source of truth"
                            ),
                        )

    def _check_span_sites(self, mod: ModuleInfo) -> Iterable[Finding]:
        patterns: Tuple[str, ...] = ()
        for glob, pats in SPAN_SCOPES.items():
            if fnmatch.fnmatch(mod.relpath, glob):
                patterns = patterns + pats
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if not (isinstance(fn, ast.Attribute) and fn.attr in SPAN_METHODS):
                continue
            recv = fn.value
            if not (isinstance(recv, ast.Attribute) and recv.attr == "obs"):
                continue
            scope = mod.scope(node)
            if _scope_matches(scope, patterns):
                continue
            yield Finding(
                rule=self.id, path=mod.relpath, line=node.lineno,
                scope=scope, call=f"obs.{fn.attr}", arg=first_arg_src(node),
                message=(
                    f"span recorder `{fn.attr}()` outside the declared "
                    "lifecycle scopes (rules.SPAN_SCOPES); spans open/close "
                    "only at declared request-lifecycle / engine-phase points"
                ),
            )


ALL_RULES: Tuple[Rule, ...] = (
    SyncPointRule(),
    EmitDisciplineRule(),
    ResidencyDisciplineRule(),
    JitHygieneRule(),
    RecompileHazardRule(),
    ObsDisciplineRule(),
)
