"""Layer 2: jaxpr audit of every registered jitted kernel.

Traces each kernel with abstract shapes (``jax.make_jaxpr`` — no
compilation, no device work) and statically checks the properties the
benchmarks otherwise only observe dynamically:

* **jaxpr-callback** — no host callbacks / infeed / outfeed inside any
  kernel: a hidden host round-trip on the decode path is exactly the stall
  DuoServe's prefetch overlap exists to avoid.
* **jaxpr-const** — no oversized captured constants: a jitted closure that
  captures a weight array duplicates it in device memory *outside* the
  ExpertResidency ledger, silently breaking the capacity*bytes_per_expert
  HBM bound.
* **jaxpr-donation** — declared donations actually lower to aliased
  buffers (``_pool_write`` must update the pool in place, not copy it).
* **compile-keys** — enumerate the grouped-FFN compile-cache keys across
  every feasible (B, U, max-group-size) of a serving sweep, through the
  *real* ``group_by_expert`` bucketing, and assert the distinct-key count
  satisfies the O(log B)·O(log U) claim per batch size.

Run via ``python -m repro.analysis``; ``run_audit(extra=...)`` lets tests
register deliberately-bad kernels and assert they are flagged.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# a captured const larger than this is treated as an accidentally-baked-in
# weight (the embed table of even the reduced config is ~0.5 MiB; genuine
# scalars/masks are a few hundred bytes)
CONST_BYTES_LIMIT = 64 * 1024

# substrings of primitive names that mean "host round-trip"
CALLBACK_PRIMS = ("callback", "infeed", "outfeed", "host_local")


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    rule: str      # jaxpr-callback | jaxpr-const | jaxpr-donation | compile-keys
    kernel: str
    message: str

    def format(self) -> str:
        return f"{self.rule:<22} kernel:{self.kernel}  {self.message}"


@dataclasses.dataclass
class KernelSpec:
    """One registered jitted kernel: a callable plus example abstract args.

    ``donate`` lists argnums whose buffers the kernel declares donated —
    the audit verifies the lowering actually aliases them."""
    name: str
    fn: Callable
    args: Tuple
    donate: Tuple[int, ...] = ()


@dataclasses.dataclass
class AuditReport:
    findings: List[AuditFinding]
    kernels: List[str]
    compile_keys: int
    compile_key_bound: int

    @property
    def ok(self) -> bool:
        return not self.findings


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------


def _iter_eqns(jaxpr):
    """Yield every eqn in a jaxpr, recursing into sub-jaxprs (pjit bodies,
    scan/while/cond branches, pallas kernels).  Duck-typed so it works
    across jax versions: anything with ``.eqns`` is a jaxpr, anything with
    ``.jaxpr`` is a closed jaxpr."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _sub_jaxprs(v):
    if hasattr(v, "eqns"):
        yield v
    elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def _check_callbacks(name: str, closed) -> List[AuditFinding]:
    out = []
    for eqn in _iter_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        if any(s in prim for s in CALLBACK_PRIMS):
            out.append(AuditFinding(
                "jaxpr-callback", name,
                f"primitive `{prim}` is a host round-trip inside a jitted "
                "kernel — a synchronization the dispatch-point discipline "
                "does not account for",
            ))
    return out


def _all_consts(closed):
    """Consts of a closed jaxpr AND of every nested closed jaxpr (a
    ``jax.jit`` wrapper hides closure captures inside the pjit eqn's
    sub-jaxpr)."""
    seen = [closed]
    consts = list(closed.consts)
    stack = [closed.jaxpr]
    while stack:
        jaxpr = stack.pop()
        for eqn in jaxpr.eqns:
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    stack.append(sub)
            for v in eqn.params.values():
                if hasattr(v, "consts") and hasattr(v, "jaxpr") and v not in seen:
                    seen.append(v)
                    consts.extend(v.consts)
    return consts


def _check_consts(name: str, closed) -> List[AuditFinding]:
    out = []
    for c in _all_consts(closed):
        nbytes = getattr(c, "nbytes", None)
        if nbytes is None:
            size = getattr(c, "size", 0)
            itemsize = getattr(getattr(c, "dtype", None), "itemsize", 1)
            nbytes = int(size) * int(itemsize)
        if nbytes > CONST_BYTES_LIMIT:
            shape = tuple(getattr(c, "shape", ()))
            out.append(AuditFinding(
                "jaxpr-const", name,
                f"captured constant of {nbytes} bytes (shape {shape}) baked "
                "into the trace — device memory outside the residency "
                "ledger; pass it as an argument instead",
            ))
    return out


def _check_donation(spec: KernelSpec) -> List[AuditFinding]:
    if not spec.donate:
        return []
    try:
        text = spec.fn.lower(*spec.args).as_text()
    except Exception as e:  # pragma: no cover - lowering failure is a finding
        return [AuditFinding(
            "jaxpr-donation", spec.name, f"could not lower to check donation: {e}"
        )]
    # donation lowers to `tf.aliasing_output` (jax<=0.4.x CPU) or
    # `jax.buffer_donor` on newer versions
    if "aliasing_output" not in text and "buffer_donor" not in text:
        return [AuditFinding(
            "jaxpr-donation", spec.name,
            f"declared donation of argnums {spec.donate} is not honored in "
            "the lowering (no aliasing_output/buffer_donor attribute): the "
            "kernel copies instead of updating in place",
        )]
    return []


def audit_kernel(spec: KernelSpec) -> List[AuditFinding]:
    try:
        closed = jax.make_jaxpr(spec.fn)(*spec.args)
    except Exception as e:
        return [AuditFinding(
            "jaxpr-callback", spec.name, f"kernel failed to trace: {e}"
        )]
    findings = _check_callbacks(spec.name, closed)
    findings += _check_consts(spec.name, closed)
    findings += _check_donation(spec)
    return findings


# --------------------------------------------------------------------------
# compile-key enumeration for the grouped FFN
# --------------------------------------------------------------------------


def _pow2_values(cap: int) -> Set[int]:
    """The set ``{_bucket(n, cap) : 1 <= n <= cap}`` — all padded sizes the
    bucketing can produce.  |set| <= floor(log2 cap) + 2."""
    from repro.serving.engine import _bucket

    return {_bucket(n, cap) for n in range(1, cap + 1)}


def enumerate_grouped_keys(max_batch: int, E: int, k: int) -> Set[Tuple[int, int, int]]:
    """Every (B, U_pad, C) compile key the decode grouped FFN can see,
    derived from the engine's own bucketing helpers."""
    keys: Set[Tuple[int, int, int]] = set()
    for B in range(1, max_batch + 1):
        ucap = min(E, B * k)
        for u_pad in _pow2_values(ucap):
            for c in _pow2_values(B):
                keys.add((B, u_pad, c))
    return keys


def compile_key_bound(max_batch: int, E: int, k: int) -> int:
    """The O(log B)·O(log U) bound the paper-claim reduces to: per batch
    size, at most (log2 B + 2) group capacities x (log2 Ucap + 2) group
    counts."""
    total = 0
    for B in range(1, max_batch + 1):
        ucap = min(E, B * k)
        total += (int(math.log2(B)) + 2) * (int(math.log2(ucap)) + 2)
    return total


def _sample_selection_patterns(B: int, E: int, k: int):
    """A deterministic battery of [B, k] expert-selection matrices spanning
    the shape-relevant extremes: fully clustered (one group of size B),
    fully spread (max distinct experts), and cyclic mixes in between."""
    pats = []
    # fully clustered: every row picks the same k experts -> U = k, count = B
    pats.append(np.tile(np.arange(k, dtype=np.int32), (B, 1)))
    # fully spread: rows walk distinct experts -> U = min(E, B*k)
    spread = (np.arange(B * k, dtype=np.int32).reshape(B, k)) % E
    pats.append(spread)
    # cyclic strides in between
    for stride in (1, 2, 3):
        ids = np.zeros((B, k), np.int32)
        for t in range(B):
            base = (t * stride) % E
            ids[t] = [(base + j) % E for j in range(k)]
        pats.append(ids)
    return pats


def measure_grouped_keys(max_batch: int, E: int, k: int) -> Set[Tuple[int, int, int]]:
    """Push the pattern battery through the REAL ``group_by_expert`` with
    the decode call site's caps and collect the resulting compile keys."""
    from repro.serving.engine import group_by_expert

    seen: Set[Tuple[int, int, int]] = set()
    for B in range(1, max_batch + 1):
        for ids in _sample_selection_patterns(B, E, k):
            union = list(dict.fromkeys(int(e) for e in ids.ravel()))
            disp = group_by_expert(ids, union, bucket_cap=B,
                                   u_bucket_cap=min(E, B * k))
            seen.add((B,) + disp.row_idx.shape)
    return seen


def audit_compile_keys(eng) -> Tuple[List[AuditFinding], int, int]:
    """Statically verify the recompile claim for the grouped decode FFN:
    (1) the enumerated key set respects the per-B logarithmic bound, and
    (2) every key produced by real selection patterns is in the enumerated
    set, and `_grouped_raw` traces at each one (same jit cache keys)."""
    findings: List[AuditFinding] = []
    B_max, E, k = eng.max_batch, eng.E, eng.k
    keys = enumerate_grouped_keys(B_max, E, k)
    bound = compile_key_bound(B_max, E, k)
    if len(keys) > bound:
        findings.append(AuditFinding(
            "compile-keys", "_grouped_raw",
            f"enumerated {len(keys)} grouped-FFN compile keys across "
            f"B=1..{B_max}, exceeding the O(log B)·O(log U) bound {bound} — "
            "a shape dimension is crossing the jit boundary unbucketed",
        ))
    measured = measure_grouped_keys(B_max, E, k)
    stray = measured - keys
    if stray:
        findings.append(AuditFinding(
            "compile-keys", "_grouped_raw",
            f"real selection patterns produced compile keys {sorted(stray)} "
            "outside the enumerated bucket set: group_by_expert's padding "
            "no longer matches the declared bucketing",
        ))
    # trace the kernel at every measured key: these are exactly the jit
    # cache entries a serving sweep can create
    d = eng.cfg.d_model
    pools = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in eng.cache.pools]
    xdt = eng.dev["embed"].dtype
    for (B, U, C) in sorted(measured):
        spec = KernelSpec(
            name=f"_grouped_raw[B={B},U={U},C={C}]",
            fn=eng._grouped_raw,
            args=(
                jax.ShapeDtypeStruct((B, 1, d), xdt),
                jax.ShapeDtypeStruct((U, C), jnp.int32),
                *pools,
                jax.ShapeDtypeStruct((U,), jnp.int32),
            ),
        )
        findings += audit_kernel(spec)
    return findings, len(measured), bound


# --------------------------------------------------------------------------
# kernel registry
# --------------------------------------------------------------------------


def build_audit_engine():
    """A reduced-config batched engine purely for tracing: construction
    initializes params and the jitted kernels but compiles nothing."""
    from repro.configs.base import get_config, reduced
    from repro.models.model import build
    from repro.serving.batching import BatchedServingEngine

    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return BatchedServingEngine(cfg, params, policy="duo", max_batch=8,
                                max_seq=32, temperature=0.0)


def registered_kernels(eng) -> List[KernelSpec]:
    from repro.core.cache import _pool_write
    from repro.kernels.expert_ffn import expert_ffn, expert_ffn_from_pool

    cfg = eng.cfg
    d = cfg.d_model
    hkv, hd = cfg.n_kv_heads, cfg.hd
    W = eng.W
    B = 4
    lp = eng._layer(0)
    md = eng._moe_dev(0)
    xdt = eng.dev["embed"].dtype
    pools = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in eng.cache.pools]
    pdt = pools[0].dtype
    de = pools[0].shape[2]
    cap = pools[0].shape[0]
    S = jax.ShapeDtypeStruct
    i32 = jnp.int32

    kv = S((1, W, hkv, hd), xdt)
    kvB = S((B, W, hkv, hd), xdt)

    specs = [
        KernelSpec("attn_prefill", eng._attn_prefill,
                   (lp, S((1, 8, d), xdt))),
        KernelSpec("attn_prefill_chunk", eng._attn_prefill_chunk,
                   (lp, S((1, 4, d), xdt), kv, kv, S((1, W), i32),
                    S((), i32))),
        KernelSpec("attn_decode", eng._attn_decode,
                   (lp, S((1, 1, d), xdt), kv, kv, S((W,), i32),
                    S((), i32), S((), i32))),
        KernelSpec("attn_decode_batched", eng._attn_decode_batched,
                   (lp, S((B, 1, d), xdt), kvB, kvB, S((B, W), i32),
                    S((B,), i32), S((B,), i32))),
        KernelSpec("gate", eng._gate, (md, lp, S((B, 1, d), xdt))),
        KernelSpec("expert_raw", eng._expert_raw,
                   (S((B, 1, d), xdt), *pools, S((), i32))),
        KernelSpec("expert_apply", eng._expert,
                   (S((B, 1, d), xdt), *pools, S((), i32),
                    S((B,), jnp.float32))),
        KernelSpec("shared_apply", eng._shared, (md, S((B, 1, d), xdt))),
        KernelSpec("head", eng._head,
                   (eng.dev["ln_f"], eng.dev["embed"], S((B, d), xdt))),
        KernelSpec("expert_ffn[pallas]",
                   lambda x, w1, w3, w2: expert_ffn(
                       x, w1, w3, w2, block_f=de, interpret=True),
                   (S((2, 4, d), pdt), S((2, d, de), pdt),
                    S((2, d, de), pdt), S((2, de, d), pdt))),
        KernelSpec("expert_ffn_from_pool[pallas]",
                   lambda x, w1p, w3p, w2p, slots: expert_ffn_from_pool(
                       x, w1p, w3p, w2p, slots, interpret=True),
                   (S((2, 4, d), pdt), *pools, S((2,), i32))),
        KernelSpec("pool_write", _pool_write,
                   (S((cap, d, de), pdt), S((), i32), S((d, de), pdt)),
                   donate=(0,)),
        KernelSpec("snapshot_gather", _snapshot_gather,
                   (kvB, S((), i32))),
        KernelSpec("snapshot_scatter", _snapshot_scatter,
                   (kvB, S((6, hkv, hd), xdt), S((), i32))),
    ]
    return specs


# The snapshot/restore KV movement (serving/batching.py restore) expressed
# as traced kernels: per-prefix-length P they compile once per *restore*
# (a handoff boundary), never per token — the audit pins them callback- and
# const-free like every other kernel.
@jax.jit
def _snapshot_gather(K, slot):
    return jax.lax.dynamic_index_in_dim(K, slot, keepdims=False)


@jax.jit
def _snapshot_scatter(K, vals, slot):
    return K.at[slot, : vals.shape[0]].set(vals)


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------


def run_audit(extra: Optional[Sequence[KernelSpec]] = None,
              eng=None) -> AuditReport:
    if eng is None:
        eng = build_audit_engine()
    specs = registered_kernels(eng)
    if extra:
        specs = specs + list(extra)
    findings: List[AuditFinding] = []
    for spec in specs:
        findings += audit_kernel(spec)
    key_findings, n_keys, bound = audit_compile_keys(eng)
    findings += key_findings
    return AuditReport(
        findings=findings,
        kernels=[s.name for s in specs],
        compile_keys=n_keys,
        compile_key_bound=bound,
    )
