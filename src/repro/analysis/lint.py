"""AST lint engine for the repo's architectural invariants.

The serving stack's QoS claims rest on disciplines that are invisible to a
generic linter: host<->device transfers only at engine dispatch points, one
token-emission sink, one owner for the expert slot pools, and power-of-two
bucketing on every shape that crosses a jit boundary.  This module is the
engine: it parses every scanned source file once, annotates each AST node
with its enclosing scope (dotted qualname) and loop depth, and hands the
annotated module to each rule in :mod:`repro.analysis.rules`.

Findings are suppressed only through ``analysis/allowlist.toml`` — each entry
names the rule, file, scope and (optionally) the exact call/argument source
text it blesses, plus a human justification.  An entry that matches nothing
is reported as a warning so the allowlist cannot rot.
"""
from __future__ import annotations

import ast
import fnmatch
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


# --------------------------------------------------------------------------
# findings
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    """One rule violation at a specific source location."""

    rule: str          # rule id, e.g. "sync-point"
    path: str          # posix path relative to the scanned root, e.g. "serving/engine.py"
    line: int
    scope: str         # dotted qualname of the enclosing function ("" = module level)
    message: str
    call: str = ""     # dotted callee, e.g. "np.asarray" (rules may leave blank)
    arg: str = ""      # source text of the first argument, for allowlist matching

    def format(self) -> str:
        where = f"{self.path}:{self.line}"
        scope = f" [{self.scope}]" if self.scope else ""
        return f"{self.rule:<22} {where}{scope}  {self.message}"


# --------------------------------------------------------------------------
# allowlist
# --------------------------------------------------------------------------


@dataclass
class AllowEntry:
    rule: str
    reason: str
    path: str = "*"
    scope: str = "*"
    call: str = ""
    arg: str = ""
    hits: int = field(default=0, compare=False)

    def matches(self, f: Finding) -> bool:
        if self.rule != f.rule:
            return False
        if not fnmatch.fnmatch(f.path, self.path):
            return False
        if not fnmatch.fnmatch(f.scope or "", self.scope):
            return False
        if self.call and self.call != f.call:
            return False
        if self.arg and self.arg != f.arg:
            return False
        return True


def _parse_toml_minimal(text: str) -> dict:
    """Tiny TOML subset parser: ``[[allow]]`` array-of-tables with string
    values.  Python 3.10 has no ``tomllib``; the allowlist deliberately uses
    only this subset so the fallback stays trivial."""
    out: dict = {}
    current: Optional[dict] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[[") and line.endswith("]]"):
            name = line[2:-2].strip()
            current = {}
            out.setdefault(name, []).append(current)
            continue
        if line.startswith("[") and line.endswith("]"):
            name = line[1:-1].strip()
            current = {}
            out[name] = current
            continue
        if "=" in line:
            key, _, val = line.partition("=")
            key = key.strip()
            val = val.strip()
            if val.startswith('"'):
                # strip trailing comment outside the string
                end = val.find('"', 1)
                while end != -1 and val[end - 1] == "\\":
                    end = val.find('"', end + 1)
                sval = val[1:end] if end != -1 else val[1:]
                value: object = sval.replace('\\"', '"')
            elif val in ("true", "false"):
                value = val == "true"
            else:
                value = val.split("#", 1)[0].strip()
                try:
                    value = int(value)  # type: ignore[assignment]
                except ValueError:
                    pass
            if current is None:
                out[key] = value
            else:
                current[key] = value
    return out


def load_allowlist(path: Path) -> List[AllowEntry]:
    text = path.read_text()
    try:
        import tomllib  # py311+

        data = tomllib.loads(text)
    except ImportError:
        data = _parse_toml_minimal(text)
    entries: List[AllowEntry] = []
    for i, row in enumerate(data.get("allow", [])):
        if "rule" not in row or "reason" not in row:
            raise ValueError(
                f"allowlist entry #{i + 1} must set 'rule' and 'reason': {row!r}"
            )
        known = {"rule", "reason", "path", "scope", "call", "arg"}
        extra = set(row) - known
        if extra:
            raise ValueError(
                f"allowlist entry #{i + 1} has unknown keys {sorted(extra)}"
            )
        entries.append(AllowEntry(**{k: row[k] for k in known & set(row)}))
    return entries


# --------------------------------------------------------------------------
# module indexing
# --------------------------------------------------------------------------


class ModuleInfo:
    """A parsed source file with per-node scope and loop-depth annotations."""

    def __init__(self, relpath: str, source: str):
        self.relpath = relpath
        self.source = source
        self.tree = ast.parse(source, filename=relpath)
        # node -> dotted qualname of the *enclosing* function ("" at module level)
        self.scope_of: Dict[ast.AST, str] = {}
        # node -> number of enclosing for/while loops *within* its function
        self.loop_depth: Dict[ast.AST, int] = {}
        # qualname -> FunctionDef/AsyncFunctionDef
        self.functions: Dict[str, ast.AST] = {}
        for child in ast.iter_child_nodes(self.tree):
            self._visit(child, scope="", loops=0, qual=())

    def _visit(self, node: ast.AST, scope: str, loops: int, qual: Tuple[str, ...]):
        self.scope_of[node] = scope
        self.loop_depth[node] = loops
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # decorators and defaults evaluate in the *enclosing* scope
            for dec in node.decorator_list:
                self._visit(dec, scope, loops, qual)
            for d in node.args.defaults + [x for x in node.args.kw_defaults if x]:
                self._visit(d, scope, loops, qual)
            new_qual = qual + (node.name,)
            new_scope = ".".join(new_qual)
            self.functions[new_scope] = node
            for child in node.body:
                self._visit(child, new_scope, 0, new_qual)
            return
        if isinstance(node, ast.ClassDef):
            for dec in node.decorator_list:
                self._visit(dec, scope, loops, qual)
            for b in node.bases + node.keywords:
                self._visit(b, scope, loops, qual)
            for child in node.body:
                self._visit(child, scope, loops, qual + (node.name,))
            return
        if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            for fld in ("target", "iter", "test"):
                sub = getattr(node, fld, None)
                if sub is not None:
                    self._visit(sub, scope, loops, qual)
            for child in node.body + node.orelse:
                self._visit(child, scope, loops + 1, qual)
            return
        for child in ast.iter_child_nodes(node):
            self._visit(child, scope, loops, qual)

    # -- convenience -------------------------------------------------------

    def scope(self, node: ast.AST) -> str:
        return self.scope_of.get(node, "")

    def loops(self, node: ast.AST) -> int:
        return self.loop_depth.get(node, 0)


def dotted_name(node: ast.AST) -> str:
    """``np.asarray`` / ``self.cache.slot`` / ``jax.jit`` -> dotted string.

    Returns "" for anything that is not a plain Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif isinstance(node, ast.Call):
        # e.g. group_by_expert(...).row_idx — root is a call
        inner = dotted_name(node.func)
        parts.append(f"{inner}()" if inner else "()")
    else:
        return ""
    return ".".join(reversed(parts))


def first_arg_src(call: ast.Call) -> str:
    if call.args:
        try:
            return ast.unparse(call.args[0])
        except Exception:
            return ""
    return ""


# --------------------------------------------------------------------------
# rule base + runner
# --------------------------------------------------------------------------


class Rule:
    """Base class: subclasses set ``id``, ``paths`` (fnmatch globs relative
    to the scanned root) and implement ``check``."""

    id: str = ""
    paths: Sequence[str] = ("*",)

    def applies(self, relpath: str) -> bool:
        return any(fnmatch.fnmatch(relpath, g) for g in self.paths)

    def check(self, mod: ModuleInfo) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


@dataclass
class LintReport:
    findings: List[Finding]
    suppressed: List[Tuple[Finding, AllowEntry]]
    unused_allows: List[AllowEntry]
    scanned: List[str]

    @property
    def ok(self) -> bool:
        return not self.findings


def iter_sources(root: Path) -> Iterable[Tuple[str, Path]]:
    for p in sorted(root.rglob("*.py")):
        rel = p.relative_to(root).as_posix()
        yield rel, p


def run_lint(
    root: Path,
    rules: Sequence[Rule],
    allowlist: Sequence[AllowEntry] = (),
) -> LintReport:
    """Lint every ``*.py`` under ``root`` with ``rules``.

    ``root`` is the package root (the directory containing ``serving/``,
    ``core/``, ``kernels/``); rule path globs are matched against paths
    relative to it."""
    allow = list(allowlist)
    findings: List[Finding] = []
    suppressed: List[Tuple[Finding, AllowEntry]] = []
    scanned: List[str] = []
    for rel, path in iter_sources(root):
        active = [r for r in rules if r.applies(rel)]
        if not active:
            continue
        scanned.append(rel)
        mod = ModuleInfo(rel, path.read_text())
        for rule in active:
            for f in rule.check(mod):
                hit = next((a for a in allow if a.matches(f)), None)
                if hit is not None:
                    hit.hits += 1
                    suppressed.append((f, hit))
                else:
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    unused = [a for a in allow if a.hits == 0]
    return LintReport(findings, suppressed, unused, scanned)
