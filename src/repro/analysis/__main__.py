"""CLI: ``python -m repro.analysis`` — exits nonzero on any finding.

Layer 1 (always): AST lint of the repro package against the invariant
rules, suppressed only via ``analysis/allowlist.toml``.
Layer 2 (default, skip with ``--no-jaxpr``): trace every registered jitted
kernel with abstract shapes and audit callbacks / captured constants /
donation / compile-key counts.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .lint import load_allowlist, run_lint
from .rules import ALL_RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument(
        "--root", type=Path, default=None,
        help="package root to lint (default: the installed repro package)",
    )
    ap.add_argument(
        "--allowlist", type=Path, default=None,
        help="allowlist TOML (default: analysis/allowlist.toml in the root)",
    )
    ap.add_argument(
        "--no-jaxpr", action="store_true",
        help="skip the jaxpr audit layer (no jax import, pure AST lint)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print rule ids and exit",
    )
    ap.add_argument(
        "-v", "--verbose", action="store_true",
        help="also print suppressed findings with their allowlist reasons",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id:<22} paths: {', '.join(rule.paths)}")
        return 0

    root = args.root
    if root is None:
        import repro

        # repro may be a namespace package (__file__ is None)
        root = Path(next(iter(repro.__path__)))
    allow_path = args.allowlist or root / "analysis" / "allowlist.toml"
    allowlist = load_allowlist(allow_path) if allow_path.exists() else []

    report = run_lint(root, ALL_RULES, allowlist)
    status = 0

    for f in report.findings:
        print(f.format())
        status = 1
    if args.verbose:
        for f, entry in report.suppressed:
            print(f"allowed  {f.format()}")
            print(f"         reason: {entry.reason}")
    for entry in report.unused_allows:
        print(
            f"warning: unused allowlist entry rule={entry.rule!r} "
            f"path={entry.path!r} scope={entry.scope!r} call={entry.call!r} "
            f"arg={entry.arg!r} — delete it or fix the pattern"
        )

    n_sup = len(report.suppressed)
    print(
        f"lint: {len(report.scanned)} files, {len(report.findings)} finding(s), "
        f"{n_sup} allowlisted",
        file=sys.stderr,
    )

    if not args.no_jaxpr:
        from .jaxpr_audit import run_audit

        audit = run_audit()
        for f in audit.findings:
            print(f.format())
            status = 1
        print(
            f"jaxpr audit: {len(audit.kernels)} kernels, "
            f"{audit.compile_keys} grouped-FFN compile keys "
            f"(bound {audit.compile_key_bound}), "
            f"{len(audit.findings)} finding(s)",
            file=sys.stderr,
        )

    return status


if __name__ == "__main__":
    sys.exit(main())
