"""Blockwise online-softmax attention (prefill) — Pallas TPU kernel.

Causal + sliding-window + GQA. Grid (B, H, nq, nk) with the kv axis
innermost; running max/denominator live in VMEM scratch; the output tile is
written once on the last kv step. Fully-masked kv blocks (beyond the causal
frontier or outside the window) skip their MXU work via pl.when — unlike the
pure-jnp reference path, which computes-then-masks (that delta is the §Perf
compute-term win this kernel represents).

q: [B, H, S, D]; k/v: [B, Hkv, S, D]; window <= 0 = unbounded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, seq_len: int,
            window: int, causal: bool):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    valid = kpos < seq_len
    if causal:
        valid &= kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window

    # block liveness: any valid element? (causal frontier / window band)
    live = jnp.bool_(True)
    if causal:
        live &= (j * block_k) <= ((i + 1) * block_q - 1)
    if window > 0:
        live &= ((j + 1) * block_k - 1) > (i * block_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                    # [bq, D]
        k = k_ref[0, 0]                    # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        corr = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0, 0],
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _write():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = -1,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: [B,H,S,D]; k,v: [B,Hkv,S,D] -> [B,H,S,D]."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq, bk = min(block_q, S), min(block_k, S)
    nq, nk = -(-S // bq), -(-S // bk)
    pad_q, pad_k = nq * bq - S, nk * bk - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kern = functools.partial(
        _kernel, scale=D ** -0.5, block_q=bq, block_k=bk, seq_len=S,
        window=window, causal=causal)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
