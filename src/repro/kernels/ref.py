"""Pure-jnp oracles for every Pallas kernel (shape/dtype-sweep tests assert
allclose against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w1, w3, w2):
    """x: [E,C,d]; w1/w3: [E,d,f]; w2: [E,f,d] -> [E,C,d] (x.dtype)."""
    h1 = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                    w1.astype(jnp.float32))
    h3 = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                    w3.astype(jnp.float32))
    h = jax.nn.silu(h1) * h3
    y = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype).astype(jnp.float32),
                   w2.astype(jnp.float32))
    return y.astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=-1):
    """q: [B,H,S,D]; k,v: [B,Hkv,S,D] -> [B,H,S,D]."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * (D ** -0.5)
    ii = jnp.arange(S)[:, None]
    jj = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= jj <= ii
    if window > 0:
        ok &= jj > ii - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def ssd_scan_ref(x, b, c, da, dt):
    """Sequential recurrence oracle. x: [BH,S,P]; b,c: [BH,S,N];
    da,dt: [BH,S] -> y [BH,S,P] f32."""
    BH, S, P = x.shape
    N = b.shape[2]
    h = jnp.zeros((BH, N, P), jnp.float32)
    ys = []
    xf = x.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    for t in range(S):
        h = (jnp.exp(da[:, t])[:, None, None] * h
             + dt[:, t, None, None]
             * jnp.einsum("zn,zp->znp", bf[:, t], xf[:, t]))
        ys.append(jnp.einsum("zn,znp->zp", cf[:, t], h))
    return jnp.stack(ys, axis=1), h
