"""Single-token decode attention over a (ring-buffer) KV cache — Pallas TPU.

Completes the kernel set: prefill = flash_attention, MoE = expert_ffn,
SSM = ssd_scan, decode = this. Grid (B, Hkv, n_chunks): the kv cache streams
through VMEM in chunks while the running online-softmax state for the G
grouped query heads sits in scratch; Pallas double-buffers the next chunk's
cache tiles during the current chunk's dot products (decode is pure
HBM-bandwidth — the pipeline keeps the MXU fed at the cache-read rate).

q: [B, H, D] (one token); k,v: [B, Hkv, S, D]; slot_pos: [S] absolute
position per cache slot (-1 = empty); pos: scalar int32 position of the new
token (already written into the cache). window <= 0 = unbounded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale: float, window: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                       # [G, D]
    k = k_ref[0, 0]                       # [bk, D]
    sp = sp_ref[0]                        # [bk]
    pos = pos_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, bk]
    valid = (sp >= 0) & (sp <= pos)
    if window > 0:
        valid &= sp > pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    corr = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0, 0],
        preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _write():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 slot_pos: jax.Array, pos: jax.Array, *, window: int = -1,
                 block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q: [B,H,D]; k,v: [B,Hkv,S,D]; slot_pos: [S]; pos: scalar -> [B,H,D]."""
    B, H, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    bk = min(block_k, S)
    nk = -(-S // bk)
    pad = nk * bk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        slot_pos = jnp.pad(slot_pos, (0, pad), constant_values=-1)
    qg = q.reshape(B, Hkv, G, D)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=D ** -0.5, window=window),
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, k, v, slot_pos[None])
    return out.reshape(B, H, D)
