"""Double-buffered streaming expert FFN — the DuoServe prefill pipeline as a
TPU Pallas kernel.

The paper overlaps expert-weight fetches with expert computation using two
CUDA streams and a k-slot GPU cache. On TPU the same structure is expressed
with Pallas grid pipelining: the grid walks (expert e, hidden tile j); while
the MXU computes tile (e, j), Pallas's automatic double buffering DMAs tile
(e, j+1) — and across experts, expert e+1's first tiles stream from HBM while
expert e finishes. HBM here plays the role of the paper's host-side expert
cache; VMEM is the k=2-deep device-side cache (one tile computing, one
arriving).

Operands:
  x   [E, C, d]   capacity-grouped tokens (dispatch done upstream)
  w1  [E, d, f]   gate proj     w3 [E, d, f] up proj     w2 [E, f, d] down
  out [E, C, d]   f32 accumulated across hidden tiles

The stacked [E, ...] weight layout is exactly the ExpertResidency slot-pool
layout (core/cache.py: [pool_capacity, d, de] buffers) — `expert_ffn_from_pool`
runs the kernel straight off the serving engine's resident pools by slot
index, so the prefill pipeline and the Pallas kernel share one weight-access
convention.

Grid: (E, f // block_f); the hidden dim is tiled so each expert's working set
fits VMEM regardless of d_expert (SwiGLU is computed per f-tile and
down-projected immediately: out += (silu(x@w1_j) * (x@w3_j)) @ w2_j).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]                      # [C, d] bf16
    h1 = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    h3 = jnp.dot(x, w3_ref[0], preferred_element_type=jnp.float32)
    h = jax.nn.silu(h1) * h3          # [C, bf] f32
    o_ref[0] += jnp.dot(h.astype(x.dtype), w2_ref[0],
                        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_f", "interpret"))
def expert_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
               *, block_f: int = 512, interpret: bool = False) -> jax.Array:
    """x: [E, C, d]; w1/w3: [E, d, f]; w2: [E, f, d] -> [E, C, d] (x.dtype)."""
    E, C, d = x.shape
    f = w1.shape[2]
    # largest divisor of f that fits the requested tile: a non-dividing
    # block_f degrades to a smaller (still exact) tiling instead of failing
    bf = min(block_f, f)
    while f % bf:
        bf -= 1
    grid = (E, f // bf)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, d), lambda e, j: (e, 0, 0)),
            pl.BlockSpec((1, d, bf), lambda e, j: (e, 0, j)),
            pl.BlockSpec((1, d, bf), lambda e, j: (e, 0, j)),
            pl.BlockSpec((1, bf, d), lambda e, j: (e, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, d), lambda e, j: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), jnp.float32),
        interpret=interpret,
    )(x, w1, w3, w2)
    return out.astype(x.dtype)


def expert_ffn_from_pool(x: jax.Array, w1_pool: jax.Array,
                         w3_pool: jax.Array, w2_pool: jax.Array,
                         slots, **kw) -> jax.Array:
    """Run the streaming expert FFN straight off ExpertResidency slot pools.

    x: [E', C, d] capacity-grouped tokens for E' active experts; slots: [E']
    pool slot of each active expert (``residency.slot(key)``); w*_pool: the
    residency's fixed [pool_capacity, ...] buffers. The gather selects only
    the active experts' slabs, so the kernel's HBM reads stay bounded by the
    residency capacity — the device-side counterpart of the paper's k-slot
    cache feeding the two-stream prefill pipeline.
    """
    idx = jnp.asarray(slots, jnp.int32)
    return expert_ffn(x, w1_pool[idx], w3_pool[idx], w2_pool[idx], **kw)
