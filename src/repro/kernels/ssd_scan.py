"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid (B*H, n_chunks); the chunk axis is innermost and SEQUENTIAL: the
inter-chunk SSM state [N, P] persists in VMEM scratch across grid steps
(zeroed at chunk 0), while Pallas streams the next chunk's x/B/C/dt tiles
from HBM during the current chunk's MXU work — the recurrent analogue of the
expert-streaming pipeline.

Per chunk (decay-masked attention form, arXiv:2405.21060):
  cs       = cumsum(dt * A)                      [cl]
  y_intra  = ((C B^T) o L) (dt o x),  L_ij = exp(cs_i - cs_j) for i >= j
  y_inter  = exp(cs) o (C h_in)
  h_out    = exp(cs_last) h_in + sum_j exp(cs_last - cs_j) dt_j B_j x_j

Inputs (heads flattened into batch):
  x [BH, S, P], b [BH, S, N], c [BH, S, N], da [BH, S] (= dt*A), dt [BH, S]
Output y [BH, S, P] (f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, da_ref, dt_ref, y_ref, h_ref, *, cl: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)      # [cl, P]
    b = b_ref[0].astype(jnp.float32)      # [cl, N]
    c = c_ref[0].astype(jnp.float32)      # [cl, N]
    da = da_ref[0].astype(jnp.float32)    # [cl]
    dt = dt_ref[0].astype(jnp.float32)    # [cl]
    cs = jnp.cumsum(da)                   # [cl]

    # intra-chunk
    g = jnp.dot(c, b.T, preferred_element_type=jnp.float32)      # [cl, cl]
    ii = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (cl, cl), 1)
    ldec = jnp.where(ii >= jj, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
    m = g * ldec * dt[None, :]
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)        # [cl, P]

    # inter-chunk: contribution of incoming state
    h_in = h_ref[...]                                            # [N, P]
    y += jnp.exp(cs)[:, None] * jnp.dot(c, h_in,
                                        preferred_element_type=jnp.float32)

    # state update
    dec_end = jnp.exp(cs[-1] - cs) * dt                          # [cl]
    h_ref[...] = (jnp.exp(cs[-1]) * h_in
                  + jnp.dot((b * dec_end[:, None]).T, x,
                            preferred_element_type=jnp.float32))
    y_ref[0] = y


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, b: jax.Array, c: jax.Array, da: jax.Array,
             dt: jax.Array, *, chunk: int = 256,
             interpret: bool = False) -> jax.Array:
    """x: [BH,S,P]; b,c: [BH,S,N]; da,dt: [BH,S] -> y [BH,S,P] f32."""
    BH, S, P = x.shape
    N = b.shape[2]
    cl = min(chunk, S)
    nc = -(-S // cl)
    pad = nc * cl - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))

    out = pl.pallas_call(
        functools.partial(_kernel, cl=cl),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, cl, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cl, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cl, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, cl), lambda i, j: (i, j)),
            pl.BlockSpec((1, cl), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, cl, P), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc * cl, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, b, c, da, dt)
    return out[:, :S]
