"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels run with interpret=True; on a real TPU
set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to lower natively.
``moe_ffn_pallas`` is the drop-in hot path for the capacity-dispatched MoE
block (dispatch/combine stay in XLA; the grouped GEMMs run in the
double-buffered kernel).
"""
from __future__ import annotations

import os

from repro.kernels.expert_ffn import expert_ffn
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.ssd_scan import ssd_scan


def default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def expert_ffn_op(x, w1, w3, w2, *, block_f: int = 512,
                  interpret: bool | None = None):
    return expert_ffn(x, w1, w3, w2, block_f=block_f,
                      interpret=default_interpret() if interpret is None
                      else interpret)


def flash_attention_op(q, k, v, *, causal=True, window=-1, block_q=512,
                       block_k=512, interpret: bool | None = None):
    return flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k,
        interpret=default_interpret() if interpret is None else interpret)


def ssd_scan_op(x, b, c, da, dt, *, chunk=256, interpret: bool | None = None):
    return ssd_scan(x, b, c, da, dt, chunk=chunk,
                    interpret=default_interpret() if interpret is None
                    else interpret)


def flash_decode_op(q, k, v, slot_pos, pos, *, window=-1, block_k=512,
                    interpret: bool | None = None):
    return flash_decode(
        q, k, v, slot_pos, pos, window=window, block_k=block_k,
        interpret=default_interpret() if interpret is None else interpret)
