"""Cluster serving layer: a ReplicaPool of engines behind a pluggable
Router, with the QoS autopilot that closes the SLO loop.

The tier above ``BatchedServingEngine`` (cf. vLLM's production-stack
router): N independent engine replicas — each with its own KV slot pool,
scheduler, arrival queue, and ``ExpertResidency`` — behind a routing policy
that decides, per request, WHICH replica serves it. Because our replicas
carry phase-specialized expert caches, routing is richer than generic load
balancing: a replica whose residency already holds the request's likely
experts serves it with fewer fetches, so the router is an extension of the
paper's caching policy, not just a load spreader.

Routers (``make_router``):

  * ``round_robin``    — classic rotation; oblivious to load AND request
    size, so alternating long/short workloads systematically pile the long
    prompts onto the same replicas (the baseline the benches beat).
  * ``least_loaded``   — min outstanding work (``ReplicaLoad.total_tokens``:
    queued + prefill backlog + committed decode tokens), ties broken by
    replica index.
  * ``slo_headroom``   — route to the replica whose latency model leaves the
    MOST margin against the request's ttft/tbt SLOs
    (``AdmissionController.headroom``); reject only if NO replica is
    non-negative. SLO-less requests fall back to least-loaded ranking.
  * ``expert_affinity``— rank replicas by overlap between the request's
    likely-expert set (decode predictor with empty history when available,
    else trace popularity — fMoE's semantic-locality argument) and each
    replica's live residency ledger (``CacheState.residency_overlap``);
    load-overloaded replicas are excluded first (production-stack's
    overload-detector-then-affinity order), ties broken by load.

``ClusterFrontend`` keeps the exact PR-4 serving surface — ``submit(spec)
-> RequestHandle``, cooperative ``poll()`` (steps ALL replicas), handle
``.cancel()`` delegating to the owning replica — so every existing
example/bench runs on a cluster by swapping one constructor. A request the
router rejects gets a terminal handle with a ``RejectEvent("router_slo")``
and never touches an engine queue.

``QosAutopilot`` attaches to either front-end (cluster or plain
``ServingFrontend``) and runs after every poll: a request whose TTFT
deadline is unmeetable (predicted remaining prefill overruns it) or whose
next-token TBT deadline has already passed is shed via ``handle.cancel
(reason="slo_shed")`` — the KV slot, residency contributions, and TBT entry
reclaimed synchronously, surfaced as ``FinishEvent(reason="slo_shed")`` and
counted on both the autopilot and the owning engine (``n_slo_shed``).
Survivors are bit-unaffected (tests/test_cluster.py).

Determinism: at temperature 0 a 1-replica cluster is bit-identical to a
plain ``ServingFrontend`` under every router policy, and every request
served by ANY replica of an N-replica cluster reproduces the single-request
engine's tokens (the row-wise exactness invariant composes across
replicas).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Deque, Dict, FrozenSet, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.core.cache import ExpertKey
from repro.core.qos import AdmissionController, ReplicaLoad
from repro.serving.api import (GenerationRequest, RejectEvent, StepEvents,
                               as_request_spec)
from repro.serving.batching import BatchedServingEngine, Request, RequestQueue
from repro.serving.frontend import (CooperativeDriver, RequestHandle,
                                    ServingFrontend)


def likely_expert_keys(engine: BatchedServingEngine,
                       width: Optional[int] = None
                       ) -> FrozenSet[ExpertKey]:
    """The decode predictor's likely-expert set for an incoming request —
    per layer, the top-`width` (default top_k) experts the replica's
    scheduler expects a fresh request to activate.

    Before a request runs there is no activation path, so the per-layer
    prediction uses the empty-history feature vector (popularity + layer
    embedding dominate) when the scheduler carries the trained ExpertMLP;
    schedulers without a predictor fall back to the trace-popularity prior
    (MIF's request-level signal), and stat-less schedulers yield the empty
    set — expert_affinity then degrades to pure load ranking. The set is a
    property of the MODEL + workload, not of a replica, so the router
    computes it once per request (all replicas share params/stats)."""
    sched = engine.sched
    width = width or engine.k
    sc = getattr(sched, "state_constructor", None)
    predictor = getattr(sched, "predictor", None)
    stats = getattr(sched, "stats", None) or (sc.stats if sc else None)
    keys: List[ExpertKey] = []
    if predictor is not None and sc is not None:
        for l in range(engine.L):
            if l == 0:
                if stats is not None:
                    top = np.argsort(-stats.popularity[0])[:width]
                    keys += [(0, int(e)) for e in top]
                continue
            feat = sc.features([], l)
            top = predictor.predict_topk(feat[None], k=width)[0]
            keys += [(l, int(e)) for e in top[:width]]
    elif stats is not None:
        for l in range(engine.L):
            top = np.argsort(-stats.popularity[l])[:width]
            keys += [(l, int(e)) for e in top]
    return frozenset(keys)


class Router:
    """Routing policy: pick the replica index for a request, or None to
    reject it outright (only ``slo_headroom`` ever rejects). Stateless
    except for policy-owned cursors, so one router instance serves one
    ClusterFrontend."""

    name = "base"

    def choose(self, spec: GenerationRequest, pool: "ReplicaPool",
               now: float) -> Optional[int]:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._cursor = 0

    def choose(self, spec, pool, now):
        i = self._cursor % pool.n
        self._cursor += 1
        return i


class LeastLoadedRouter(Router):
    """Min outstanding tokens (queued + prefill backlog + committed decode);
    ties break toward the lower replica index for determinism."""
    name = "least_loaded"

    def choose(self, spec, pool, now):
        loads = pool.loads()
        return min(range(pool.n),
                   key=lambda i: (loads[i].total_tokens,
                                  loads[i].queue_depth, i))


class SloHeadroomRouter(Router):
    """Max SLO margin (AdmissionController.headroom) across replicas;
    reject (None) only when NO replica can meet the request's deadlines
    even from an IMMEDIATE start — the same REJECT boundary admission and
    the QosAutopilot use, so a backlog that merely has to drain first
    (admission's QUEUE band) routes to the best replica instead of being
    router-rejected. For SLO-less requests every headroom is +inf and the
    load tie-break makes this least-loaded."""
    name = "slo_headroom"

    def _scores(self, spec, pool, now, with_backlog: bool
                ) -> List[Tuple[float, int, int]]:
        arrival = spec.arrival if spec.arrival is not None else now
        plen = int(np.asarray(spec.prompt).reshape(-1).shape[0])
        loads = pool.loads()
        scored: List[Tuple[float, int, int]] = []
        for i, eng in enumerate(pool.engines):
            ld = loads[i]
            backlog = (ld.queued_tokens + ld.prefill_backlog
                       if with_backlog else 0)
            hr = eng.queue.admission.headroom(
                now, arrival, plen, backlog,
                ttft_slo=spec.ttft_slo, tbt_slo=spec.tbt_slo,
                running_batch=ld.running,
                chunk_budget=eng._current_budget(),
                chunk_adaptive=eng.prefill_budget == "auto")
            scored.append((hr, ld.total_tokens, i))
        return scored

    def choose(self, spec, pool, now):
        # rank by backlog-inclusive margin: the honest prediction of what
        # the request will actually experience on each replica
        best = max(self._scores(spec, pool, now, with_backlog=True),
                   key=lambda s: (s[0], -s[1], -s[2]))
        if best[0] >= 0:
            return best[2]
        # every replica breaches WITH its current backlog — reject only if
        # the deadline is hopeless even from an immediate start everywhere
        # (otherwise route to the best immediate-start replica and let its
        # admission QUEUE the request while the backlog drains)
        best0 = max(self._scores(spec, pool, now, with_backlog=False),
                    key=lambda s: (s[0], -s[1], -s[2]))
        if best0[0] < 0:
            return None   # no replica can meet the request's deadlines
        return best0[2]


class ExpertAffinityRouter(Router):
    """Max overlap between a fresh request's likely-expert set (shared
    model/workload signal, see ReplicaPool.likely_keys) and the replica's
    LIVE residency ledger, among non-overloaded replicas
    (overload first, affinity second — production-stack's ordering, which
    also breaks the warm-cache-wins-forever feedback loop); ties break by
    load then index. With no predictor/stats signal the overlap is 0
    everywhere and this degrades to least-loaded."""
    name = "expert_affinity"

    def __init__(self, overload_factor: float = 2.0):
        self.overload_factor = overload_factor

    def choose(self, spec, pool, now):
        plen = int(np.asarray(spec.prompt).reshape(-1).shape[0])
        loads = pool.loads()
        floor = min(ld.total_tokens for ld in loads)
        # a replica is overloaded when its backlog exceeds the least-loaded
        # replica's by more than `overload_factor` x this request's own
        # work — affinity may then not justify the queueing it would eat
        limit = floor + self.overload_factor * max(plen, 1)
        eligible = [i for i in range(pool.n)
                    if loads[i].total_tokens <= limit]
        keys = pool.likely_keys()
        return max(eligible,
                   key=lambda i: (pool.engines[i].cache.residency_overlap(
                       keys), -loads[i].total_tokens, -i))


ROUTERS = ("round_robin", "least_loaded", "slo_headroom", "expert_affinity")


def make_router(name: Union[str, Router]) -> Router:
    if isinstance(name, Router):
        return name
    name = name.lower()
    if name == "round_robin":
        return RoundRobinRouter()
    if name == "least_loaded":
        return LeastLoadedRouter()
    if name == "slo_headroom":
        return SloHeadroomRouter()
    if name == "expert_affinity":
        return ExpertAffinityRouter()
    raise KeyError(f"unknown router {name!r} (have {ROUTERS})")


class ReplicaPool:
    """N independent BatchedServingEngine replicas + their per-replica
    ServingFrontends. Replicas share NOTHING mutable: each has its own KV
    slots, arrival queue (own AdmissionController/LatencyModel — per-replica
    load signals stay honest), scheduler, and ExpertResidency; only the
    host-side params/stats/predictor objects are shared, read-only."""

    def __init__(self, engines: Sequence[BatchedServingEngine]):
        assert engines, "a pool needs at least one replica"
        for i, a in enumerate(engines):
            for b in engines[i + 1:]:
                assert a.queue is not b.queue, \
                    "replicas must not share an arrival queue"
                assert a.cache is not b.cache, \
                    "replicas must not share an ExpertResidency"
        self.engines = list(engines)
        self.frontends = [ServingFrontend(e) for e in self.engines]
        self._likely_cache: Optional[FrozenSet[ExpertKey]] = None

    @classmethod
    def build(cls, cfg, params, n_replicas: int, *,
              default_ttft_slo: Optional[float] = None,
              **engine_kwargs) -> "ReplicaPool":
        """Construct `n_replicas` identical engines over shared (read-only)
        params. `engine_kwargs` go to every BatchedServingEngine; a fresh
        RequestQueue/AdmissionController is built per replica (passing
        `queue=` here would alias one queue across replicas — rejected)."""
        assert n_replicas >= 1
        assert "queue" not in engine_kwargs, \
            "per-replica queues are built here; pass default_ttft_slo"
        engines = []
        for _ in range(n_replicas):
            q = (RequestQueue(AdmissionController(
                default_ttft_slo=default_ttft_slo))
                if default_ttft_slo is not None else None)
            engines.append(BatchedServingEngine(cfg, params, queue=q,
                                                **engine_kwargs))
        return cls(engines)

    @property
    def n(self) -> int:
        return len(self.engines)

    def loads(self) -> List[ReplicaLoad]:
        return [e.load() for e in self.engines]

    def likely_keys(self) -> FrozenSet[ExpertKey]:
        """The likely-expert set a FRESH request is expected to activate
        (see likely_expert_keys). With empty-history predictor features /
        popularity priors this is a property of the shared model + workload
        — the same for every request — so it is computed once and cached
        for the pool's lifetime; affinity routing therefore ranks replicas
        by how much of this hot set each one holds RESIDENT right now (the
        per-replica term is live, the per-request term is not — making the
        set prompt-conditioned is an open ROADMAP item)."""
        if self._likely_cache is None:
            self._likely_cache = likely_expert_keys(self.engines[0])
        return self._likely_cache


class ClusterFrontend(CooperativeDriver):
    """The PR-4 serving surface over a ReplicaPool: ``submit(spec) ->
    RequestHandle``, cooperative ``poll()`` stepping every replica once (in
    replica order — deterministic), ``cancel(handle)`` delegating to the
    owning replica. Handles submitted here drive the CLUSTER poll when
    iterated, so waiting on one request keeps all replicas advancing.

    Router rejections (slo_headroom finding no capable replica) produce a
    terminal handle carrying a ``RejectEvent("router_slo")`` — the request
    never occupies any replica's queue; ``n_router_rejected`` counts them
    for the pool's lifetime (``router_rejected`` retains a bounded window
    of the Request records) and their negative rids keep them disjoint
    from every replica-local rid space (replica rids start at 0 per
    engine, so cluster-level event streams disambiguate requests by
    HANDLE, not rid). Terminal handles are NOT retained here — the
    per-replica dispatch tables reap them, so a long-running cluster's
    memory stays bounded.
    """

    def __init__(self, pool: ReplicaPool,
                 router: Union[str, Router] = "least_loaded",
                 rejected_window: Optional[int] = 512):
        self.pool = pool
        self.router = make_router(router)
        self.router_rejected: Deque[Request] = collections.deque(
            maxlen=rejected_window)
        self.n_router_rejected = 0
        self.autopilot = None   # QosAutopilot registers itself here

    # -- submission ----------------------------------------------------------
    def submit(self, spec, **kw) -> RequestHandle:
        """Route a GenerationRequest (or raw prompt + fields, as with
        ServingFrontend.submit) to a replica and submit it there. The
        returned handle polls the CLUSTER; its ``.replica`` records the
        owning replica index (None for router rejections)."""
        spec = as_request_spec(spec, **kw)
        now = time.perf_counter()
        if spec.arrival is None:
            # stamp once so router scoring and the engine record agree
            spec = dataclasses.replace(spec, arrival=now)
        choice = self.router.choose(spec, self.pool, now)
        if choice is None:
            return self._reject(spec, now)
        handle = self.pool.frontends[choice].submit(spec)
        handle._fe = self              # iteration drives the cluster poll
        handle.replica = choice
        return handle

    def _reject(self, spec: GenerationRequest, now: float) -> RequestHandle:
        # negative rids keep router rejections disjoint from every
        # replica-local rid space
        self.n_router_rejected += 1
        req = Request(rid=-self.n_router_rejected,
                      prompt=np.asarray(spec.prompt, np.int32).reshape(-1),
                      params=spec.params, arrival=spec.arrival,
                      ttft_slo=spec.ttft_slo, tbt_slo=spec.tbt_slo,
                      priority=spec.priority, state="rejected")
        self.router_rejected.append(req)
        handle = RequestHandle(self, req)
        handle._on_event(RejectEvent(rid=req.rid, reason="router_slo",
                                     t=now))
        return handle

    # -- cooperative driving -------------------------------------------------
    @property
    def idle(self) -> bool:
        return all(fe.idle for fe in self.pool.frontends)

    def poll(self, now: Optional[float] = None) -> StepEvents:
        """One cluster iteration: step every replica once (replica order),
        merge their event streams, then run the autopilot's shed scan —
        shed FinishEvents("slo_shed") are appended to the returned stream.
        NOTE: merged events carry replica-LOCAL rids; consumers that track
        individual requests should hold their handles."""
        events: List = []
        did_work = False
        for fe in self.pool.frontends:
            ev = fe.poll(now)
            events.extend(ev)
            did_work |= ev.did_work
        if self.autopilot is not None:
            self.autopilot.scan_into(now, events)
        return StepEvents(events, did_work)

    # -- delegation ----------------------------------------------------------
    def cancel(self, handle: RequestHandle,
               reason: str = "cancelled") -> bool:
        if handle.done or handle.replica is None:
            return False
        return self.pool.frontends[handle.replica].cancel(handle,
                                                          reason=reason)

    def live_handles(self) -> List[RequestHandle]:
        out: List[RequestHandle] = []
        for fe in self.pool.frontends:
            out.extend(fe.live_handles())
        return out

    def engine_of(self, handle: RequestHandle) -> BatchedServingEngine:
        assert handle.replica is not None, "router-rejected handle"
        return self.pool.engines[handle.replica]


class QosAutopilot:
    """Per-poll SLO shed policy (ROADMAP "SLO-aware cancellation" item):
    sheds requests whose deadline is ALREADY unmeetable mid-flight, so a
    doomed request stops burning KV slots / prefill budget / expert
    residency that surviving requests could meet their SLOs with.

    Attaches to a ClusterFrontend or a plain ServingFrontend (it registers
    as ``frontend.autopilot``; both run ``scan`` after each poll's event
    dispatch and append shed FinishEvents to the poll's returned stream).
    Two triggers, both against the owning replica's live admission
    predictor (the SAME ``AdmissionController.predict_ttft`` that gated
    the request at admission):

      * TTFT — no first token yet, and even an IMMEDIATE start (zero
        backlog ahead: time already waited + own remaining work + decode
        interference) would overrun ``ttft_slo + grace`` — the admission
        REJECT boundary, so requests admission parked in its QUEUE band
        ("reachable once the backlog drains") are NOT shed early.
      * TBT — first token emitted, and the NEXT token's deadline
        (last token time + tbt_slo + grace) has already passed.

    Shedding goes through ``handle.cancel(reason="slo_shed")`` — the same
    synchronous reclamation as a caller cancel — surfaced as
    ``FinishEvent(reason="slo_shed")`` and counted here (``n_shed``,
    ``by_reason``; ``shed`` retains a bounded window of handles) and on
    the owning engine (``n_slo_shed``). Requests without SLOs are never
    touched; survivors stay bit-exact."""

    def __init__(self, frontend, *, grace: float = 0.0,
                 shed_window: Optional[int] = 512):
        self.fe = frontend
        self.grace = grace
        self.shed: Deque[RequestHandle] = collections.deque(
            maxlen=shed_window)
        self.n_shed = 0
        self.by_reason: Dict[str, int] = {"ttft": 0, "tbt": 0}
        frontend.autopilot = self

    def scan_into(self, now: Optional[float],
                  events: List) -> List[RequestHandle]:
        """scan(), then append each shed request's FinishEvent("slo_shed")
        to `events` — the one hook both front-ends' poll() call, so the
        returned event stream surfaces sheds identically everywhere."""
        shed_now = self.scan(now)
        for h in shed_now:
            events.append(h.events[-1])
        return shed_now

    def scan(self, now: Optional[float] = None) -> List[RequestHandle]:
        """One shed pass over the live handles; returns the handles shed by
        THIS pass. Called automatically after each poll once attached."""
        now = time.perf_counter() if now is None else now
        shed_now: List[RequestHandle] = []
        for h in self.fe.live_handles():
            if h.done:
                continue
            trigger = self._verdict(h, now)
            if trigger is None:
                continue
            if h.cancel(reason="slo_shed"):
                self.shed.append(h)
                self.n_shed += 1
                self.by_reason[trigger] += 1
                shed_now.append(h)
        return shed_now

    def _verdict(self, h: RequestHandle, now: float) -> Optional[str]:
        req = h.req
        if not h.tokens:
            if req.ttft_slo is None:
                return None
            # resolve the owning engine through the handle's OWN frontend:
            # cluster-submitted handles carry a replica index, handles
            # submitted directly through a per-replica frontend (warm-up
            # traffic) resolve via that frontend — and the engine is only
            # needed at all on this SLO-carrying branch
            eng = h._fe.engine_of(h)
            # mirror the admission REJECT boundary exactly: shed only when
            # even an IMMEDIATE start (zero backlog ahead) would breach the
            # deadline — time already waited + the request's own remaining
            # work + decode interference. Charging the live backlog here
            # would shed every request admission deliberately parked in its
            # QUEUE band ("deadline still reachable once the backlog
            # drains"), turning that band into dead behavior.
            own = (req.prefill_remaining if req.state == "prefilling"
                   else req.prompt_len)
            predicted = eng.queue.admission.predict_ttft(
                now, req.arrival, own, 0,
                running_batch=len(eng.running),
                chunk_budget=eng._current_budget())
            return ("ttft" if predicted > req.ttft_slo + self.grace
                    else None)
        if req.tbt_slo is not None and h.last_token_t is not None:
            # the next token's deadline has passed and it hasn't arrived
            if now - h.last_token_t > req.tbt_slo + self.grace:
                return "tbt"
        return None
