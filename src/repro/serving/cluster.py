"""Cluster serving layer: a ReplicaPool of engines behind a pluggable
Router, with the QoS autopilot that closes the SLO loop.

The tier above ``BatchedServingEngine`` (cf. vLLM's production-stack
router): N independent engine replicas — each with its own KV slot pool,
scheduler, arrival queue, and ``ExpertResidency`` — behind a routing policy
that decides, per request, WHICH replica serves it. Because our replicas
carry phase-specialized expert caches, routing is richer than generic load
balancing: a replica whose residency already holds the request's likely
experts serves it with fewer fetches, so the router is an extension of the
paper's caching policy, not just a load spreader.

Routers (``make_router``):

  * ``round_robin``    — classic rotation; oblivious to load AND request
    size, so alternating long/short workloads systematically pile the long
    prompts onto the same replicas (the baseline the benches beat).
  * ``least_loaded``   — min outstanding work (``ReplicaLoad.total_tokens``:
    queued + prefill backlog + committed decode tokens), ties broken by
    replica index.
  * ``slo_headroom``   — route to the replica whose latency model leaves the
    MOST margin against the request's ttft/tbt SLOs
    (``AdmissionController.headroom``); reject only if NO replica is
    non-negative. SLO-less requests fall back to least-loaded ranking.
  * ``expert_affinity``— rank replicas by overlap between the request's
    likely-expert set (decode predictor with empty history when available,
    else trace popularity — fMoE's semantic-locality argument) and each
    replica's live residency ledger (``CacheState.residency_overlap``);
    load-overloaded replicas are excluded first (production-stack's
    overload-detector-then-affinity order), ties broken by load.
  * ``prefix_affinity``— rank replicas by the length of the request's
    prompt prefix already cached in each replica's ``PrefixTree``
    (``peek`` — read-only), same overload-gate-then-affinity ordering as
    ``expert_affinity``: matching requests land on the warm replica, so N
    replicas become a sharded prefix cache instead of N cold copies
    (requires engines built with ``prefix_cache=True``; degrades to
    least-loaded otherwise).
  * ``disagg``         — disaggregated prefill/decode dispatch (the paper's
    dual-phase split at cluster scale, ROADMAP item 1): NEW requests go to
    prefill-role replicas only; when a prefill completes, the request sits
    ``held`` until the per-poll handoff (``ReplicaPool.handoff_held``)
    snapshots its KV prefix and restores it into the decode replica with
    the best per-request expert-affinity/headroom. Per-replica ``build``
    overrides size each role differently (big dense-traffic residency
    pools for prefill, small predictor-driven ones for decode).

Every policy routes over the pool's ROUTABLE replicas: ``ReplicaPool.
drain(i)`` removes a replica from routing and migrates its in-flight
requests to the survivors via the same snapshot/restore primitive
(retried each poll; ``undrain`` reverses). All of it rides on
``BatchedServingEngine.snapshot/restore`` — a paused, handed-off, or
migrated request resumes BIT-EXACTLY (carried rng state + dense KV prefix
+ token list), so phase placement never changes tokens.

``ClusterFrontend`` keeps the exact PR-4 serving surface — ``submit(spec)
-> RequestHandle``, cooperative ``poll()`` (steps ALL replicas), handle
``.cancel()`` delegating to the owning replica — so every existing
example/bench runs on a cluster by swapping one constructor; across
handoffs/migrations the SAME handle follows the request (rebound to each
restored incarnation, hops recorded on ``handle.handoffs``). A request the
router rejects gets a terminal handle with a ``RejectEvent("router_slo")``
and never touches an engine queue.

``QosAutopilot`` attaches to either front-end (cluster or plain
``ServingFrontend``) and runs after every poll: a request whose TTFT
deadline is unmeetable (predicted remaining prefill overruns it) or whose
next-token TBT deadline has already passed is shed via ``handle.cancel
(reason="slo_shed")`` — the KV slot, residency contributions, and TBT entry
reclaimed synchronously, surfaced as ``FinishEvent(reason="slo_shed")`` and
counted on both the autopilot and the owning engine (``n_slo_shed``).
With ``preempt=True`` it also gets a RECOVERABLE action: pause the
lowest-priority in-flight request host-side when a higher-priority one is
stuck queued, and resume it bit-exactly when headroom returns.
Survivors are bit-unaffected (tests/test_cluster.py).

Determinism: at temperature 0 a 1-replica cluster is bit-identical to a
plain ``ServingFrontend`` under every router policy, and every request
served by ANY replica of an N-replica cluster reproduces the single-request
engine's tokens (the row-wise exactness invariant composes across
replicas).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Deque, Dict, FrozenSet, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.core.cache import ExpertKey
from repro.core.qos import AdmissionController, ReplicaLoad
from repro.obs.metrics import METRICS_SCHEMA, MetricsRegistry
from repro.serving.api import (GenerationRequest, RejectEvent,
                               RequestSnapshot, StepEvents, as_request_spec)
from repro.serving.batching import (BatchedServingEngine, Request,
                                    RequestQueue, kv_row_bytes)
from repro.serving.frontend import (CooperativeDriver, RequestHandle,
                                    ServingFrontend)


def _valid_engine_kwargs() -> frozenset:
    """Keyword names BatchedServingEngine accepts, derived from its real
    signature (so this can never drift), plus the pool-level
    ``default_ttft_slo`` knob resolved in build()."""
    import inspect

    sig = inspect.signature(BatchedServingEngine.__init__)
    names = {p.name for p in sig.parameters.values()
             if p.name not in ("self", "cfg", "params", "queue")}
    return frozenset(names | {"default_ttft_slo"})


def _validate_engine_kwargs(kwargs, where: str) -> None:
    """Reject unknown engine kwargs/override keys up front with a clear
    error (a typo'd override otherwise surfaces as a TypeError only after
    earlier replicas were already built — or, worse, silently configures
    nothing if a **kwargs sink is ever introduced)."""
    valid = _valid_engine_kwargs()
    unknown = sorted(set(kwargs) - valid)
    if not unknown:
        return
    import difflib

    parts = []
    for u in unknown:
        close = difflib.get_close_matches(u, sorted(valid), n=1)
        parts.append(f"{u!r}" + (f" (did you mean {close[0]!r}?)"
                                 if close else ""))
    raise ValueError(
        f"{where}: unknown engine kwarg(s) {', '.join(parts)}; "
        f"valid keys: {sorted(valid)}"
    )


def likely_expert_keys(engine: BatchedServingEngine,
                       width: Optional[int] = None
                       ) -> FrozenSet[ExpertKey]:
    """The decode predictor's likely-expert set for an incoming request —
    per layer, the top-`width` (default top_k) experts the replica's
    scheduler expects a fresh request to activate.

    Before a request runs there is no activation path, so the per-layer
    prediction uses the empty-history feature vector (popularity + layer
    embedding dominate) when the scheduler carries the trained ExpertMLP;
    schedulers without a predictor fall back to the trace-popularity prior
    (MIF's request-level signal), and stat-less schedulers yield the empty
    set — expert_affinity then degrades to pure load ranking. The set is a
    property of the MODEL + workload, not of a replica, so the router
    computes it once per request (all replicas share params/stats)."""
    sched = engine.sched
    width = width or engine.k
    sc = getattr(sched, "state_constructor", None)
    predictor = getattr(sched, "predictor", None)
    stats = getattr(sched, "stats", None) or (sc.stats if sc else None)
    keys: List[ExpertKey] = []
    if predictor is not None and sc is not None:
        for l in range(engine.L):
            if l == 0:
                if stats is not None:
                    top = np.argsort(-stats.popularity[0])[:width]
                    keys += [(0, int(e)) for e in top]
                continue
            feat = sc.features([], l)
            top = predictor.predict_topk(feat[None], k=width)[0]
            keys += [(l, int(e)) for e in top[:width]]
    elif stats is not None:
        for l in range(engine.L):
            top = np.argsort(-stats.popularity[l])[:width]
            keys += [(l, int(e)) for e in top]
    return frozenset(keys)


class Router:
    """Routing policy: pick the replica index for a request, or None to
    reject it outright (only ``slo_headroom`` ever rejects). Stateless
    except for policy-owned cursors, so one router instance serves one
    ClusterFrontend. Every policy ranks over ``candidates(pool)`` —
    by default the pool's routable (non-draining) replicas, which the
    DisaggRouter narrows to prefill-capable ones."""

    name = "base"

    def candidates(self, pool: "ReplicaPool") -> List[int]:
        cands = pool.routable()
        assert cands, "every replica is draining — nowhere to route"
        return cands

    def choose(self, spec: GenerationRequest, pool: "ReplicaPool",
               now: float) -> Optional[int]:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._cursor = 0

    def choose(self, spec, pool, now):
        cands = self.candidates(pool)
        i = cands[self._cursor % len(cands)]
        self._cursor += 1
        return i


class LeastLoadedRouter(Router):
    """Min outstanding tokens (queued + prefill backlog + committed decode);
    ties break toward the lower replica index for determinism."""
    name = "least_loaded"

    def choose(self, spec, pool, now):
        loads = pool.loads()
        return min(self.candidates(pool),
                   key=lambda i: (loads[i].total_tokens,
                                  loads[i].queue_depth, i))


class SloHeadroomRouter(Router):
    """Max SLO margin (AdmissionController.headroom) across replicas;
    reject (None) only when NO replica can meet the request's deadlines
    even from an IMMEDIATE start — the same REJECT boundary admission and
    the QosAutopilot use, so a backlog that merely has to drain first
    (admission's QUEUE band) routes to the best replica instead of being
    router-rejected. For SLO-less requests every headroom is +inf and the
    load tie-break makes this least-loaded."""
    name = "slo_headroom"

    def _scores(self, spec, pool, now, with_backlog: bool
                ) -> List[Tuple[float, int, int]]:
        arrival = spec.arrival if spec.arrival is not None else now
        plen = int(np.asarray(spec.prompt).reshape(-1).shape[0])
        loads = pool.loads()
        scored: List[Tuple[float, int, int]] = []
        for i in self.candidates(pool):
            eng, ld = pool.engines[i], loads[i]
            backlog = (ld.queued_tokens + ld.prefill_backlog
                       if with_backlog else 0)
            hr = eng.queue.admission.headroom(
                now, arrival, plen, backlog,
                ttft_slo=spec.ttft_slo, tbt_slo=spec.tbt_slo,
                running_batch=ld.running,
                chunk_budget=eng._current_budget(),
                chunk_adaptive=eng.prefill_budget == "auto")
            scored.append((hr, ld.total_tokens, i))
        return scored

    def choose(self, spec, pool, now):
        # rank by backlog-inclusive margin: the honest prediction of what
        # the request will actually experience on each replica
        best = max(self._scores(spec, pool, now, with_backlog=True),
                   key=lambda s: (s[0], -s[1], -s[2]))
        if best[0] >= 0:
            return best[2]
        # every replica breaches WITH its current backlog — reject only if
        # the deadline is hopeless even from an immediate start everywhere
        # (otherwise route to the best immediate-start replica and let its
        # admission QUEUE the request while the backlog drains)
        best0 = max(self._scores(spec, pool, now, with_backlog=False),
                    key=lambda s: (s[0], -s[1], -s[2]))
        if best0[0] < 0:
            return None   # no replica can meet the request's deadlines
        return best0[2]


class ExpertAffinityRouter(Router):
    """Max overlap between a fresh request's likely-expert set (shared
    model/workload signal, see ReplicaPool.likely_keys) and the replica's
    LIVE residency ledger, among non-overloaded replicas
    (overload first, affinity second — production-stack's ordering, which
    also breaks the warm-cache-wins-forever feedback loop); ties break by
    load then index. With no predictor/stats signal the overlap is 0
    everywhere and this degrades to least-loaded."""
    name = "expert_affinity"

    def __init__(self, overload_factor: float = 2.0):
        self.overload_factor = overload_factor

    def choose(self, spec, pool, now):
        plen = int(np.asarray(spec.prompt).reshape(-1).shape[0])
        cands = self.candidates(pool)
        loads = pool.loads()
        floor = min(loads[i].total_tokens for i in cands)
        # a replica is overloaded when its backlog exceeds the least-loaded
        # replica's by more than `overload_factor` x this request's own
        # work — affinity may then not justify the queueing it would eat
        limit = floor + self.overload_factor * max(plen, 1)
        eligible = [i for i in cands if loads[i].total_tokens <= limit]
        keys = pool.likely_keys()
        return max(eligible,
                   key=lambda i: (pool.engines[i].cache.residency_overlap(
                       keys), -loads[i].total_tokens, -i))


class PrefixAffinityRouter(Router):
    """Max cached-prefix overlap between the request's prompt and each
    replica (``BatchedServingEngine.prefix_score`` — the ``PrefixTree``'s
    current contents plus every live request's prompt, so a BURST of
    same-template arrivals co-locates even before the first one has
    prefilled; KV-side affinity, the sibling of ``expert_affinity``'s
    residency overlap), among non-overloaded replicas. The overload gate
    comes FIRST with the same factor/ordering as expert_affinity: prefix
    hits shorten prefill, which attracts more matching requests, so
    without the gate the warm-replica feedback loop would pile unbounded
    load onto one replica. Ties break by load then index; replicas
    without a prefix tree score 0, so on a cold or tree-less pool this
    degrades to least-loaded."""
    name = "prefix_affinity"

    def __init__(self, overload_factor: float = 2.0):
        self.overload_factor = overload_factor

    def choose(self, spec, pool, now):
        prompt = np.asarray(spec.prompt, np.int32).reshape(-1)
        plen = int(prompt.shape[0])
        cands = self.candidates(pool)
        loads = pool.loads()
        floor = min(loads[i].total_tokens for i in cands)
        limit = floor + self.overload_factor * max(plen, 1)
        eligible = [i for i in cands if loads[i].total_tokens <= limit]

        # cap at plen-1 — the engine never reuses the final prompt
        # position (its logits produce the first token), so score exactly
        # the rows a hit could actually save
        cap = max(plen - 1, 0)
        return max(eligible,
                   key=lambda i: (pool.engines[i].prefix_score(prompt, cap),
                                  -loads[i].total_tokens, -i))


class DisaggRouter(Router):
    """Disaggregated prefill/decode dispatch: NEW requests go to
    prefill-capable replicas only (least-loaded among them — for a
    prefill-role replica ``total_tokens`` is pure prefill work, held
    requests' decode budgets are excluded); decode-role replicas receive
    work exclusively through the KV-snapshot handoff the ClusterFrontend
    runs each poll (``ReplicaPool.handoff_held``), which picks the decode
    replica by THIS request's own expert-affinity (overlap between its
    observed prefill activations and the replica's live residency), then
    load."""
    name = "disagg"

    def candidates(self, pool):
        cands = [i for i in pool.routable()
                 if pool.roles[i] in ("prefill", "both")]
        # dedicated prefill replicas take precedence over generalists
        pref = [i for i in cands if pool.roles[i] == "prefill"]
        cands = pref or cands
        assert cands, "no routable prefill-capable replica"
        return cands

    def choose(self, spec, pool, now):
        loads = pool.loads()
        return min(self.candidates(pool),
                   key=lambda i: (loads[i].total_tokens,
                                  loads[i].queue_depth, i))


ROUTERS = ("round_robin", "least_loaded", "slo_headroom", "expert_affinity",
           "prefix_affinity", "disagg")


def make_router(name: Union[str, Router]) -> Router:
    if isinstance(name, Router):
        return name
    name = name.lower()
    if name == "round_robin":
        return RoundRobinRouter()
    if name == "least_loaded":
        return LeastLoadedRouter()
    if name == "slo_headroom":
        return SloHeadroomRouter()
    if name == "expert_affinity":
        return ExpertAffinityRouter()
    if name == "prefix_affinity":
        return PrefixAffinityRouter()
    if name == "disagg":
        return DisaggRouter()
    raise KeyError(f"unknown router {name!r} (have {ROUTERS})")


class ReplicaPool:
    """N independent BatchedServingEngine replicas + their per-replica
    ServingFrontends. Replicas share NOTHING mutable: each has its own KV
    slots, arrival queue (own AdmissionController/LatencyModel — per-replica
    load signals stay honest), scheduler, and ExpertResidency; only the
    host-side params/stats/predictor objects are shared, read-only."""

    def __init__(self, engines: Sequence[BatchedServingEngine]):
        assert engines, "a pool needs at least one replica"
        for i, a in enumerate(engines):
            for b in engines[i + 1:]:
                assert a.queue is not b.queue, \
                    "replicas must not share an arrival queue"
                assert a.cache is not b.cache, \
                    "replicas must not share an ExpertResidency"
        self.engines = list(engines)
        self.frontends = [ServingFrontend(e) for e in self.engines]
        self.roles: List[str] = [getattr(e, "role", "both")
                                 for e in self.engines]
        self.draining: set = set()   # replica indices being drained
        # pool-level accounting lives on this registry (per-replica numbers
        # live on each engine's own ``metrics``); the n_handoffs /
        # handoff_bytes / ... attributes below are read-only views
        self.metrics = MetricsRegistry()
        self._c_handoffs = self.metrics.counter(
            "cluster_handoffs_total",
            "prefill->decode KV handoffs completed")
        self._c_migrated = self.metrics.counter(
            "cluster_migrations_total", "drain migrations completed")
        self._c_handoff_bytes = self.metrics.counter(
            "cluster_handoff_bytes_total",
            "host-side KV bytes moved by migrate()")
        self._c_handoff_saved = self.metrics.counter(
            "cluster_handoff_bytes_saved_total",
            "head bytes NOT shipped thanks to destination prefix reuse")
        self._c_tail_handoffs = self.metrics.counter(
            "cluster_tail_handoffs_total",
            "migrations that shipped only a partial KV tail")
        # stamp each engine's span recorder with its replica index so a
        # merged Perfetto export gets one process-track per replica
        for i, e in enumerate(self.engines):
            e.obs.replica = i
        self._flow_seq = 0   # Perfetto flow-arrow ids for handoff hops
        self._likely_cache: Optional[FrozenSet[ExpertKey]] = None

    # legacy counter attributes — thin read-only registry views (the
    # obs-discipline lint rejects direct writes; mutate via the counters)
    @property
    def n_handoffs(self) -> int:
        """Prefill->decode KV handoffs completed (registry view)."""
        return int(self._c_handoffs.value)

    @property
    def n_migrated(self) -> int:
        """Drain migrations completed (registry view)."""
        return int(self._c_migrated.value)

    @property
    def handoff_bytes(self) -> int:
        """Host-side KV bytes moved by migrate() (registry view)."""
        return int(self._c_handoff_bytes.value)

    @property
    def handoff_bytes_saved(self) -> int:
        """Head bytes NOT shipped thanks to prefix reuse (registry view)."""
        return int(self._c_handoff_saved.value)

    @property
    def n_tail_handoffs(self) -> int:
        """Migrations that shipped a partial tail (registry view)."""
        return int(self._c_tail_handoffs.value)

    @classmethod
    def build(cls, cfg, params, n_replicas: Optional[int] = None, *,
              default_ttft_slo: Optional[float] = None,
              overrides: Optional[Sequence[Optional[dict]]] = None,
              **engine_kwargs) -> "ReplicaPool":
        """Construct `n_replicas` engines over shared (read-only) params.
        `engine_kwargs` go to every BatchedServingEngine; `overrides` is an
        optional per-replica dict of engine kwargs layered on top — the
        disaggregation knobs (``role="prefill"|"decode"``, ``max_batch``,
        ``cache_capacity``, ``policy``, ``prefill_budget``, and a
        per-replica ``default_ttft_slo``) so prefill replicas can carry big
        residency pools / dense-traffic policies while decode replicas run
        small predictor-driven ones. With `overrides` given, `n_replicas`
        may be omitted (one replica per entry). A fresh RequestQueue/
        AdmissionController is built per replica (passing `queue=` here
        would alias one queue across replicas — rejected)."""
        if overrides is not None:
            n_replicas = len(overrides) if n_replicas is None else n_replicas
            assert len(overrides) == n_replicas, \
                "overrides must have one entry (or None) per replica"
        assert n_replicas is not None and n_replicas >= 1
        assert "queue" not in engine_kwargs, \
            "per-replica queues are built here; pass default_ttft_slo"
        _validate_engine_kwargs(engine_kwargs, "ReplicaPool.build(**engine_kwargs)")
        engines = []
        for r in range(n_replicas):
            kw = dict(engine_kwargs)
            if overrides is not None and overrides[r]:
                assert "queue" not in overrides[r], \
                    "per-replica queues are built here"
                _validate_engine_kwargs(overrides[r],
                                        f"ReplicaPool.build overrides[{r}]")
                kw.update(overrides[r])
            slo = kw.pop("default_ttft_slo", default_ttft_slo)
            q = (RequestQueue(AdmissionController(default_ttft_slo=slo))
                 if slo is not None else None)
            engines.append(BatchedServingEngine(cfg, params, queue=q, **kw))
        return cls(engines)

    @property
    def n(self) -> int:
        return len(self.engines)

    @property
    def disagg(self) -> bool:
        """True when any replica is phase-specialized (role != 'both') —
        the ClusterFrontend then runs the prefill->decode handoff loop."""
        return any(r != "both" for r in self.roles)

    def routable(self) -> List[int]:
        """Replica indices routers may send NEW requests to (everything
        not draining)."""
        return [i for i in range(self.n) if i not in self.draining]

    def role_indices(self, *roles: str) -> List[int]:
        return [i for i, r in enumerate(self.roles) if r in roles]

    def loads(self) -> List[ReplicaLoad]:
        return [e.load() for e in self.engines]

    def likely_keys(self) -> FrozenSet[ExpertKey]:
        """The likely-expert set a FRESH request is expected to activate
        (see likely_expert_keys). With empty-history predictor features /
        popularity priors this is a property of the shared model + workload
        — the same for every request — so it is computed once and cached
        for the pool's lifetime; affinity routing therefore ranks replicas
        by how much of this hot set each one holds RESIDENT right now (the
        per-replica term is live, the per-request term is not — making the
        set prompt-conditioned is an open ROADMAP item)."""
        if self._likely_cache is None:
            self._likely_cache = likely_expert_keys(self.engines[0])
        return self._likely_cache

    # -- snapshot migration (handoff + draining) -----------------------------
    def migrate(self, req: Request, src: int, dst: int) -> RequestHandle:
        """Move one live request from replica `src` to `dst` via the
        snapshot/restore primitive. The request's handle (if it was
        submitted through a frontend) is rebound to the restored request so
        the caller's event stream continues seamlessly — `.replica` and
        `.handoffs` record the hop. Raw engine submissions (no handle) get
        a fresh handle on the destination frontend.

        When the destination's prefix tree already holds the request's
        shared head (``prefix_head_for``), the snapshot is TAIL-ONLY: only
        the unique KV tail crosses host-side (``handoff_bytes`` grows by
        the tail alone; the head rows avoided are accounted in
        ``handoff_bytes_saved``) and restore rebuilds the head from the
        destination's own tree — bit-identical rows, deterministic
        prefill."""
        assert src != dst
        h = self.frontends[src]._handles.pop(req.rid, None)
        head = self.engines[dst].prefix_head_for(req)
        # flow-linked hop endpoints: the exporter pairs these two instants
        # (same flow id) into a Perfetto arrow from src track to dst track.
        # rid=None — both ends must record or neither (per-rid sampling
        # could otherwise keep one end and orphan the flow, since the
        # restored request gets a NEW engine-local rid)
        self._flow_seq += 1
        fid = self._flow_seq
        self.engines[src].obs.instant(
            "handoff.snapshot", lane="lifecycle", flow=fid,
            src=src, dst=dst, src_rid=req.rid)
        snap = self.engines[src].snapshot(req, kv_start=head)
        self._c_handoff_bytes.inc(snap.kv_bytes)
        if head:
            self._c_handoff_saved.inc(head * kv_row_bytes(
                self.engines[src]))
            self._c_tail_handoffs.inc()
        h = self.frontends[dst].resume(snap, handle=h, src=src, dst=dst)
        self.engines[dst].obs.instant(
            "handoff.restore", lane="lifecycle", flow=fid,
            src=src, dst=dst, dst_rid=h.rid, kv_bytes=snap.kv_bytes)
        h.replica = dst
        return h

    def _request_keys(self, req: Request) -> FrozenSet[ExpertKey]:
        """The (layer, expert) set THIS request's prefill actually
        activated — a per-request affinity signal (unlike the pool-wide
        ``likely_keys`` prior) for picking its decode replica."""
        return frozenset((l, int(e))
                         for l, acts in enumerate(req.prefill_active)
                         for e in acts)

    def _target_for(self, req: Request, state: str,
                    exclude: int) -> Optional[int]:
        """Best replica to move `req` (in lifecycle `state`) to, or None if
        no viable one exists right now: role-compatible (prefill work needs
        a prefill-capable replica, decode work a decode-capable one), not
        draining, KV capacity sufficient, and — except for still-queued
        requests — a free KV slot. Ranked by overlap between the request's
        own observed expert activations and the replica's live residency
        (fewest handoff refetches), then load, then index."""
        need = req.prompt_len + req.max_new + 1
        roles_ok = {"queued": ("prefill", "both"),
                    "prefilling": ("prefill", "both"),
                    "running": ("decode", "both"),
                    "held": ("decode", "both")}[state]
        cands = []
        for j in range(self.n):
            if j == exclude or j in self.draining:
                continue
            eng = self.engines[j]
            if self.roles[j] not in roles_ok or need > eng.W:
                continue
            if state == "prefilling" and not eng.chunked:
                continue
            if state != "queued" and not eng.slot_available:
                continue
            cands.append(j)
        if not cands:
            return None
        keys = self._request_keys(req)
        loads = self.loads()
        return max(cands,
                   key=lambda j: (self.engines[j].cache.residency_overlap(
                       keys), -loads[j].total_tokens, -j))

    def handoff_held(self) -> int:
        """One prefill->decode handoff pass (the ClusterFrontend runs this
        every poll on a disaggregated pool): every held request on a
        prefill-role replica whose KV fits a decode replica with a free
        slot migrates there and joins its decode batch; the rest stay held
        and retry next pass. Returns handoffs completed."""
        moved = 0
        for i in self.role_indices("prefill"):
            for req in list(self.engines[i].held):
                j = self._target_for(req, "held", exclude=i)
                if j is None:
                    continue
                self.migrate(req, i, j)
                self._c_handoffs.inc()
                moved += 1
        return moved

    # -- draining (elasticity primitive) -------------------------------------
    def drain(self, i: int) -> int:
        """Begin draining replica `i`: routers stop sending it NEW work
        (``routable()`` excludes it) and its in-flight requests migrate to
        other replicas via snapshot/restore — whatever fits a target NOW
        moves immediately (returned count); the rest keep stepping locally
        while the ClusterFrontend retries every poll, so a request that
        never finds a target simply completes where it is. Reversible via
        ``undrain``."""
        assert 0 <= i < self.n
        self.draining.add(i)
        return self.migrate_draining()

    def undrain(self, i: int) -> None:
        """Return a draining replica to routable service (requests already
        migrated away stay where they landed)."""
        self.draining.discard(i)

    def migrate_draining(self) -> int:
        """One migration pass over every draining replica's live requests
        (queued first — they need no target slot — then held, prefilling,
        running). Returns migrations completed."""
        moved = 0
        for i in sorted(self.draining):
            eng = self.engines[i]
            groups = (("queued", list(eng.queue.pending)),
                      ("held", list(eng.held)),
                      ("prefilling", list(eng.prefilling)),
                      ("running", list(eng.running)))
            for state, reqs in groups:
                for req in reqs:
                    j = self._target_for(req, state, exclude=i)
                    if j is None:
                        continue
                    self.migrate(req, i, j)
                    self._c_migrated.inc()
                    moved += 1
        return moved

    # -- observability -------------------------------------------------------
    def recorders(self) -> List:
        """Per-replica span recorders in replica order — the input
        ``repro.obs.chrome_trace`` exporters take."""
        return [e.obs for e in self.engines]

    def metrics_snapshot(self) -> dict:
        """JSON-ready nested snapshot: pool-level handoff/migration
        counters plus one registry snapshot per replica engine. Valid
        under ``validate_metrics_snapshot`` (schema repro.obs.metrics/1)."""
        return {"schema": METRICS_SCHEMA,
                "cluster": self.metrics.snapshot(),
                "replicas": [e.metrics.snapshot() for e in self.engines]}


class ClusterFrontend(CooperativeDriver):
    """The PR-4 serving surface over a ReplicaPool: ``submit(spec) ->
    RequestHandle``, cooperative ``poll()`` stepping every replica once (in
    replica order — deterministic), ``cancel(handle)`` delegating to the
    owning replica. Handles submitted here drive the CLUSTER poll when
    iterated, so waiting on one request keeps all replicas advancing.

    Router rejections (slo_headroom finding no capable replica) produce a
    terminal handle carrying a ``RejectEvent("router_slo")`` — the request
    never occupies any replica's queue; ``n_router_rejected`` counts them
    for the pool's lifetime (``router_rejected`` retains a bounded window
    of the Request records) and their negative rids keep them disjoint
    from every replica-local rid space (replica rids start at 0 per
    engine, so cluster-level event streams disambiguate requests by
    HANDLE, not rid). Terminal handles are NOT retained here — the
    per-replica dispatch tables reap them, so a long-running cluster's
    memory stays bounded.
    """

    def __init__(self, pool: ReplicaPool,
                 router: Union[str, Router] = "least_loaded",
                 rejected_window: Optional[int] = 512):
        self.pool = pool
        self.router = make_router(router)
        self.router_rejected: Deque[Request] = collections.deque(
            maxlen=rejected_window)
        self.n_router_rejected = 0
        self.autopilot = None   # QosAutopilot registers itself here

    # -- submission ----------------------------------------------------------
    def submit(self, spec, **kw) -> RequestHandle:
        """Route a GenerationRequest (or raw prompt + fields, as with
        ServingFrontend.submit) to a replica and submit it there. The
        returned handle polls the CLUSTER; its ``.replica`` records the
        owning replica index (None for router rejections)."""
        spec = as_request_spec(spec, **kw)
        now = time.perf_counter()
        if spec.arrival is None:
            # stamp once so router scoring and the engine record agree
            spec = dataclasses.replace(spec, arrival=now)
        choice = self.router.choose(spec, self.pool, now)
        if choice is None:
            return self._reject(spec, now)
        handle = self.pool.frontends[choice].submit(spec)
        handle._fe = self              # iteration drives the cluster poll
        handle.replica = choice
        return handle

    def _reject(self, spec: GenerationRequest, now: float) -> RequestHandle:
        # negative rids keep router rejections disjoint from every
        # replica-local rid space
        self.n_router_rejected += 1
        req = Request(rid=-self.n_router_rejected,
                      prompt=np.asarray(spec.prompt, np.int32).reshape(-1),
                      params=spec.params, arrival=spec.arrival,
                      ttft_slo=spec.ttft_slo, tbt_slo=spec.tbt_slo,
                      priority=spec.priority, state="rejected")
        self.router_rejected.append(req)
        handle = RequestHandle(self, req)
        handle._on_event(RejectEvent(rid=req.rid, reason="router_slo",
                                     t=now))
        return handle

    # -- cooperative driving -------------------------------------------------
    @property
    def idle(self) -> bool:
        # autopilot-paused requests keep the cluster non-idle: a later
        # poll's scan resumes them once headroom returns
        return all(fe.idle for fe in self.pool.frontends) and not (
            self.autopilot is not None and self.autopilot.paused)

    def poll(self, now: Optional[float] = None) -> StepEvents:
        """One cluster iteration: step every replica once (replica order),
        run the pool's KV-migration passes — the prefill->decode handoff
        on a disaggregated pool, and retry migration off draining replicas
        — then the autopilot's shed/preempt scan (shed
        FinishEvents("slo_shed") are appended to the returned stream).
        NOTE: merged events carry replica-LOCAL rids; consumers that track
        individual requests should hold their handles."""
        events: List = []
        did_work = False
        for fe in self.pool.frontends:
            ev = fe.poll(now)
            events.extend(ev)
            did_work |= ev.did_work
        if self.pool.disagg:
            did_work |= bool(self.pool.handoff_held())
        if self.pool.draining:
            did_work |= bool(self.pool.migrate_draining())
        if self.autopilot is not None:
            self.autopilot.scan_into(now, events)
        return StepEvents(events, did_work)

    # -- delegation ----------------------------------------------------------
    def cancel(self, handle: RequestHandle,
               reason: str = "cancelled") -> bool:
        if handle.done:
            return False
        if handle.req.state == "paused":
            return self._cancel_paused(handle, reason)
        if handle.replica is None:
            return False
        return self.pool.frontends[handle.replica].cancel(handle,
                                                          reason=reason)

    def live_handles(self) -> List[RequestHandle]:
        out: List[RequestHandle] = []
        for fe in self.pool.frontends:
            out.extend(fe.live_handles())
        return out

    def engine_of(self, handle: RequestHandle) -> BatchedServingEngine:
        assert handle.replica is not None, "router-rejected handle"
        return self.pool.engines[handle.replica]


class QosAutopilot:
    """Per-poll SLO shed policy (ROADMAP "SLO-aware cancellation" item):
    sheds requests whose deadline is ALREADY unmeetable mid-flight, so a
    doomed request stops burning KV slots / prefill budget / expert
    residency that surviving requests could meet their SLOs with.

    Attaches to a ClusterFrontend or a plain ServingFrontend (it registers
    as ``frontend.autopilot``; both run ``scan`` after each poll's event
    dispatch and append shed FinishEvents to the poll's returned stream).
    Two triggers, both against the owning replica's live admission
    predictor (the SAME ``AdmissionController.predict_ttft`` that gated
    the request at admission):

      * TTFT — no first token yet, and even an IMMEDIATE start (zero
        backlog ahead: time already waited + own remaining work + decode
        interference) would overrun ``ttft_slo + grace`` — the admission
        REJECT boundary, so requests admission parked in its QUEUE band
        ("reachable once the backlog drains") are NOT shed early.
      * TBT — first token emitted, and the NEXT token's deadline
        (last token time + tbt_slo + grace) has already passed.

    Shedding goes through ``handle.cancel(reason="slo_shed")`` — the same
    synchronous reclamation as a caller cancel — surfaced as
    ``FinishEvent(reason="slo_shed")`` and counted here (``n_shed``,
    ``by_reason``; ``shed`` retains a bounded window of handles) and on
    the owning engine (``n_slo_shed``). Requests without SLOs are never
    touched; survivors stay bit-exact.

    Preemption (``preempt=True``) adds a second, RECOVERABLE action on top
    of shedding: when a strictly-higher-priority request is stuck queued
    behind a full slot pool, the lowest-priority (youngest-first) running
    or prefilling request is PAUSED host-side via the snapshot primitive
    (``ServingFrontend.pause`` — KV slot, residency contributions, and TBT
    entry released exactly like a cancel, but no FinishEvent: the request
    is parked, not killed) and resumed — bit-exactly, possibly on a
    different replica — once headroom returns (a free slot and no
    higher-priority work still waiting there). Paused requests are
    excluded from every load/headroom signal (they hold no engine
    resources); their KV lives host-side in the parked snapshots
    (``paused_kv_bytes`` — what memory accounting should charge) and the
    pause interval is never billed as an inter-token gap
    (``TBTLedger.reopen``)."""

    def __init__(self, frontend, *, grace: float = 0.0,
                 shed_window: Optional[int] = 512,
                 preempt: bool = False):
        self.fe = frontend
        self.grace = grace
        self.preempt = preempt
        self.shed: Deque[RequestHandle] = collections.deque(
            maxlen=shed_window)
        # counters live on the pool registry (cluster front-end) or the
        # engine's own (plain ServingFrontend); n_shed / by_reason /
        # n_preempted / n_resumed below are read-only registry views
        pool = getattr(frontend, "pool", None)
        reg = pool.metrics if pool is not None else frontend.engine.metrics
        self._c_shed = {r: reg.counter(
            "autopilot_shed_total", "requests shed mid-flight, by trigger",
            reason=r) for r in ("ttft", "tbt")}
        self._c_preempted = reg.counter(
            "autopilot_preempted_total",
            "requests paused host-side by priority preemption")
        self._c_resumed = reg.counter(
            "autopilot_resumed_total",
            "preempted requests resumed after headroom returned")
        reg.gauge("autopilot_paused_kv_bytes",
                  "host KV bytes held by currently-paused requests",
                  fn=lambda: self.paused_kv_bytes)
        # (handle, snapshot) pairs parked by preemption, resumed by scan
        self.paused: List[Tuple[RequestHandle, "RequestSnapshot"]] = []
        frontend.autopilot = self

    @property
    def paused_kv_bytes(self) -> int:
        """Host bytes of KV held by currently-paused requests."""
        return sum(s.kv_bytes for _, s in self.paused)

    # legacy counter attributes — thin read-only registry views
    @property
    def n_shed(self) -> int:
        """Total requests shed (registry view over both triggers)."""
        return int(sum(c.value for c in self._c_shed.values()))

    @property
    def by_reason(self) -> Dict[str, int]:
        """Shed counts by trigger (fresh dict; registry view)."""
        return {r: int(c.value) for r, c in self._c_shed.items()}

    @property
    def n_preempted(self) -> int:
        return int(self._c_preempted.value)

    @property
    def n_resumed(self) -> int:
        return int(self._c_resumed.value)

    def scan_into(self, now: Optional[float],
                  events: List) -> List[RequestHandle]:
        """scan(), then append each shed request's FinishEvent("slo_shed")
        to `events` — the one hook both front-ends' poll() call, so the
        returned event stream surfaces sheds identically everywhere."""
        shed_now = self.scan(now)
        for h in shed_now:
            events.append(h.events[-1])
        return shed_now

    def scan(self, now: Optional[float] = None) -> List[RequestHandle]:
        """One shed pass over the live handles (then, with ``preempt=True``,
        one resume-or-preempt pass); returns the handles shed by THIS pass.
        Called automatically after each poll once attached."""
        now = time.perf_counter() if now is None else now
        shed_now: List[RequestHandle] = []
        for h in self.fe.live_handles():
            if h.done:
                continue
            trigger = self._verdict(h, now)
            if trigger is None:
                continue
            if h.cancel(reason="slo_shed"):
                self.shed.append(h)
                self._c_shed[trigger].inc()
                # annotate the shed on the owning engine's timeline with
                # WHICH SLO trigger fired (the terminal span itself is
                # recorded by engine.cancel)
                h._fe.engine_of(h).obs.instant(
                    "autopilot.shed", lane="lifecycle", rid=h.rid,
                    trigger=trigger)
                shed_now.append(h)
        if self.preempt:
            self._scan_preempt()
        return shed_now

    # -- preemption (snapshot/restore consumer #2) ---------------------------
    def _frontends(self) -> List[ServingFrontend]:
        pool = getattr(self.fe, "pool", None)
        return list(pool.frontends) if pool is not None else [self.fe]

    def _scan_preempt(self) -> None:
        """Resume parked requests whose headroom returned, then pause a
        low-priority victim wherever a strictly-higher-priority request is
        stuck queued behind a FULL slot pool. Victim order: lowest
        priority first, youngest (largest rid) among equals — the least
        sunk work is parked. Only requests submitted through a frontend
        (i.e. with a handle) are preempted."""
        for item in list(self.paused):
            h, snap = item
            target = self._resume_target(snap)
            if target is None:
                continue
            fe, j = target
            fe.resume(snap, handle=h, dst=j)
            if j is not None:
                h.replica = j
            self.paused.remove(item)
            self._c_resumed.inc()
        for fe in self._frontends():
            eng = fe.engine
            if eng.slot_available or not len(eng.queue):
                continue   # a free slot exists / nothing is waiting
            top = max(r.priority for r in eng.queue.pending)
            viable = [r for r in eng.running + eng.prefilling
                      if r.priority < top and r.rid in fe._handles]
            if not viable:
                continue
            victim = min(viable, key=lambda r: (r.priority, -r.rid))
            h = fe._handles[victim.rid]
            # annotate WHY the pause happened (the request.paused instant
            # itself comes from engine.snapshot inside fe.pause)
            eng.obs.instant("autopilot.preempt", lane="lifecycle",
                            rid=victim.rid, priority=victim.priority,
                            top_priority=top)
            snap = fe.pause(h)
            self.paused.append((h, snap))
            self._c_preempted.inc()

    def _resume_target(self, snap: RequestSnapshot
                       ) -> Optional[Tuple[ServingFrontend, Optional[int]]]:
        """Where `snap` can resume NOW, or None: the engine must be able to
        restore it (free slot, KV capacity, chunked if mid-prefill) and
        must have no strictly-higher-priority request still queued (resume
        must not steal the slot the preemption freed). On a disaggregated
        pool the resume respects roles; ranked by the request's own
        expert-affinity, then load."""
        def ok(eng) -> bool:
            return eng.can_restore(snap) and not any(
                r.priority > snap.spec.priority for r in eng.queue.pending)

        pool = getattr(self.fe, "pool", None)
        if pool is None:
            return (self.fe, None) if ok(self.fe.engine) else None
        roles_ok = (("prefill", "both")
                    if snap.state in ("queued", "prefilling")
                    else ("decode", "both"))
        keys = frozenset((l, int(e))
                         for l, acts in enumerate(snap.prefill_active)
                         for e in acts)
        loads = pool.loads()
        best = None
        for j in pool.routable():
            if pool.roles[j] not in roles_ok or not ok(pool.engines[j]):
                continue
            score = (pool.engines[j].cache.residency_overlap(keys),
                     -loads[j].total_tokens, -j)
            if best is None or score > best[0]:
                best = (score, j)
        return (pool.frontends[best[1]], best[1]) if best else None

    def _verdict(self, h: RequestHandle, now: float) -> Optional[str]:
        req = h.req
        if not h.tokens:
            if req.ttft_slo is None:
                return None
            # resolve the owning engine through the handle's OWN frontend:
            # cluster-submitted handles carry a replica index, handles
            # submitted directly through a per-replica frontend (warm-up
            # traffic) resolve via that frontend — and the engine is only
            # needed at all on this SLO-carrying branch
            eng = h._fe.engine_of(h)
            # mirror the admission REJECT boundary exactly: shed only when
            # even an IMMEDIATE start (zero backlog ahead) would breach the
            # deadline — time already waited + the request's own remaining
            # work + decode interference. Charging the live backlog here
            # would shed every request admission deliberately parked in its
            # QUEUE band ("deadline still reachable once the backlog
            # drains"), turning that band into dead behavior.
            own = (req.prefill_remaining if req.state == "prefilling"
                   else req.prompt_len)
            predicted = eng.queue.admission.predict_ttft(
                now, req.arrival, own, 0,
                running_batch=len(eng.running),
                chunk_budget=eng._current_budget())
            return ("ttft" if predicted > req.ttft_slo + self.grace
                    else None)
        if req.tbt_slo is not None and h.last_token_t is not None:
            # the next token's deadline has passed and it hasn't arrived
            if now - h.last_token_t > req.tbt_slo + self.grace:
                return "tbt"
        return None
