"""Continuous-batching serving engine (ROADMAP: multi-request QoS).

Generalizes the paper's single-request dual-phase runtime to concurrent
load, the regime its TTFT/E2E SLO claims actually target. The public
surface is typed and event-driven (``serving/api.py`` +
``serving/frontend.py``): callers describe a request as a
``GenerationRequest`` (prompt + frozen ``SamplingParams`` + QoS targets +
priority), ``step()`` emits a ``StepEvents`` list of
``TokenEvent``/``FinishEvent``/``RejectEvent`` records, and requests can be
cancelled mid-prefill or mid-decode with their KV slot, expert-residency
contributions, and TBT-ledger entry reclaimed within the same call.

  * ``RequestQueue`` — arrival queue with SLO-aware admission: predicted
    TTFT (EWMA cost model, ``core/qos.py``) is checked against each
    request's deadline, folding in the remaining prefill backlog AND the
    running batch's decode interference; requests whose deadline is already
    unmeetable are shed instead of poisoning the batch. Per-request
    ``tbt_slo`` targets that are structurally unmeetable are shed too.
    Candidates are considered in (priority desc, arrival) order — stable,
    so equal priorities keep FIFO.
  * ``BatchedServingEngine`` — continuous batching over the layer-by-layer
    engine core: requests are admitted mid-flight; each scheduler iteration
    spends at most ``prefill_budget`` prompt tokens of (chunked) prefill
    work, then runs ONE batched decode step for every in-flight request.
    KV lives in a slot pool (one slot per in-flight request, per-request
    write positions, ring invariant slot == pos % W), so sequences at
    different positions decode together via ``self_attn_decode_batched``.
  * Chunked, stall-free prefill (paper §III phase disparity): a long prompt
    no longer freezes in-flight decoders for its whole prefill. Admitted
    requests sit in state ``prefilling``; each iteration spends the step's
    token budget on chunks through ``EngineCore.prefill_chunk``, so
    inter-token gaps for decoders stay bounded by one chunk + one decode
    step instead of a full prefill. The budget is shared FAIRLY:
    ``prefill_fairness="rr"`` (default) rotates the per-step budget across
    ALL prefilling requests so one long prompt cannot starve later
    arrivals' TTFT; ``"srf"`` serves shortest-remaining-first (short
    prompts overtake long backlogs — best straggler TTFT, long prompts pay);
    ``"fifo"`` restores the head-of-line discipline.
    ``prefill_budget="auto"`` derives the budget each step from the live
    ``LatencyModel`` so one chunk + one batched decode step fits the
    TIGHTEST inter-token-gap target in flight (the engine ``tbt_slo`` and
    every in-flight request's own ``tbt_slo``; core/qos.py
    ``suggest_chunk``). ``prefill_budget=None`` preserves the monolithic
    behaviour. The ``TBTLedger`` (core/qos.py) records per-request
    inter-token gaps; ``benchmarks/bench_stall.py`` measures the bound.
  * Decode-phase expert scheduling is shared: per-step, per-layer expert
    selections of all B requests are unioned (first-appearance order) and
    handed to ONE scheduler/ExpertResidency ledger (paper §V generalized to
    B>1) — each distinct expert is fetched at most once per step.
  * Cancellation (``cancel``): a queued request is dequeued; a prefilling or
    running request is removed from its phase list, its KV slot returns to
    the free pool, its expert-residency contributions are dropped from the
    shared ledger (only entries no OTHER in-flight request also touched —
    surviving rows keep their working set), and its ``TBTLedger`` entry is
    closed. The request emits one final ``FinishEvent("cancelled")`` and
    never emits again. Survivors are bit-unaffected: every decode kernel is
    row-wise deterministic, so shrinking the batch never changes their
    tokens (tests/test_frontend.py).

Exactness invariant: every decode-side kernel is row-wise deterministic,
per-row accumulation follows each request's own top-k order, and chunked
prefill's valid-key sets/per-token expert order match monolithic prefill
row-wise — so at temperature 0 a batched step reproduces the
single-request engine's tokens bit-exactly for EVERY chunk size, fairness
mode, and poll() schedule (tests/test_serving_batch.py,
tests/test_frontend.py).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Set, Union

import jax.numpy as jnp
import numpy as np

from repro.core.cache import ExpertKey
from repro.core.prefix import PrefixTree
from repro.core.qos import (Admission, AdmissionController, ReplicaLoad,
                            TBTLedger)
from repro.core.scheduler import DuoServeScheduler
from repro.models.layers import PDT
from repro.obs.spans import SpanRecorder, monotonic
from repro.serving.api import (FinishEvent, GenerationRequest, RejectEvent,
                               RequestSnapshot, SamplingParams, StepEvents,
                               TokenEvent)
from repro.serving.engine import EngineCore, RequestResult, group_by_expert


@dataclasses.dataclass
class Request:
    """One request's RUNTIME state inside the engine (the engine-internal
    counterpart of the immutable ``GenerationRequest`` spec)."""
    rid: int
    prompt: np.ndarray               # [S] int32
    params: SamplingParams
    arrival: float
    ttft_slo: Optional[float] = None
    tbt_slo: Optional[float] = None
    priority: int = 0
    # runtime state ---------------------------------------------------------
    # queued|prefilling|running|held|done|rejected|cancelled|paused:
    # 'held' = prefill complete on a role='prefill' replica, awaiting KV
    # handoff; 'paused' = snapshot taken, the request lives HOST-side in a
    # RequestSnapshot (this engine holds nothing for it any more)
    state: str = "queued"
    finish_reason: Optional[str] = None  # length|stop_token|cancelled|slo_shed
    slot: int = -1
    prefill_pos: int = 0             # prompt tokens already prefilled
    prefix_len: int = 0              # leading tokens seeded from PrefixTree
    tokens: List[int] = dataclasses.field(default_factory=list)
    prefill_active: List[List[int]] = dataclasses.field(default_factory=list)
    active_sets: Optional[List[set]] = None   # accumulating, chunked prefill
    # per-layer [1, W] KV carried across prefill chunks; scattered into the
    # engine's slot pool ONCE when the final chunk completes (so a chunk
    # never round-trips the whole [max_batch, W] pool)
    pf_k: Optional[List] = None
    pf_v: Optional[List] = None
    pf_sp: Optional[object] = None
    trace: List[np.ndarray] = dataclasses.field(default_factory=list)
    pred: List[np.ndarray] = dataclasses.field(default_factory=list)
    hits: int = 0
    misses: int = 0
    t_start: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    rng: Optional[np.random.Generator] = None

    @property
    def max_new(self) -> int:
        return self.params.max_new_tokens

    @property
    def temperature(self) -> Optional[float]:
        return self.params.temperature   # None = engine default

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefill_remaining(self) -> int:
        return self.prompt_len - self.prefill_pos

    @property
    def pos(self) -> int:
        """Absolute position of the NEXT token to decode."""
        return self.prompt_len + len(self.tokens) - 1

    @property
    def done(self) -> bool:
        # first token + max_new decode steps, or an early finish
        # (stop token / cancellation) recorded in finish_reason
        return (self.finish_reason is not None
                or len(self.tokens) >= self.max_new + 1)

    def result(self) -> RequestResult:
        T = len(self.trace)
        L_k = self.trace[0].shape if T else (0, 0)
        return RequestResult(
            tokens=np.asarray(self.tokens, np.int64),
            prefill_active=self.prefill_active,
            decode_trace=(np.stack(self.trace) if T
                          else np.zeros((0,) + L_k, np.int32)),
            pred_trace=(np.stack(self.pred) if T
                        else np.zeros((0,) + L_k, np.int32)),
            # cancelled before the first token: no TTFT exists (t_first
            # still holds its 0.0 sentinel — not a real timestamp)
            ttft_wall=(self.t_first - self.arrival if self.t_first
                       else float("nan")),
            e2e_wall=self.t_done - self.arrival,
            hits=self.hits, misses=self.misses,
            finish_reason=self.finish_reason or "length")


def kv_row_bytes(engine: "BatchedServingEngine") -> int:
    """Host bytes one KV row (one position: K+V across all layers)
    occupies — the unit ``ReplicaPool.handoff_bytes_saved`` counts when a
    tail-only snapshot skips shipping the shared head."""
    return int(2 * engine.L * engine.cfg.n_kv_heads * engine.cfg.hd
               * np.dtype(PDT).itemsize)


def _nan_to_zero(fn):
    """Wrap a pull-gauge callback so an empty sketch's NaN reads as 0.0
    (a JSON metrics snapshot must stay finite)."""
    def g() -> float:
        v = float(fn())
        return v if v == v else 0.0
    return g


def parse_prefill_budget(v: Union[int, str, None]) -> Union[int, str, None]:
    """CLI-string form of `prefill_budget`: int tokens, "auto"
    (LatencyModel-tuned, needs tbt_slo), or None/"none" for monolithic.
    Shared by the benchmark/example drivers so the syntax stays in one
    place."""
    if v is None or v == "none":
        return None
    if v == "auto":
        return "auto"
    return int(v)


class RequestQueue:
    """Arrival queue with SLO-aware admission (core/qos.py).

    `pop_admissible` hands back up to `limit` requests whose predicted TTFT
    fits their deadline; breached requests are shed (state='rejected') so a
    doomed prompt never occupies a KV slot another request could meet its
    SLO with, and requests whose per-request `tbt_slo` is structurally
    unmeetable (steady per-step gap over target, core/qos.py
    `predict_tbt`) are shed too. Candidates are considered in
    (priority desc, arrival) order — the sort is stable over the FIFO
    deque, so equal priorities preserve arrival order and the historical
    all-priority-0 behaviour is unchanged. The TTFT prediction folds in the
    prefill backlog already admitted (`backlog_tokens`) and the running
    batch's decode interference (`running_batch`).
    """

    def __init__(self, admission: Optional[AdmissionController] = None):
        self.admission = admission or AdmissionController()
        self.pending: Deque[Request] = collections.deque()
        self.rejected: List[Request] = []

    def __len__(self) -> int:
        return len(self.pending)

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def remove(self, req: Request) -> bool:
        """Withdraw a still-queued request (cancellation before admission)."""
        try:
            self.pending.remove(req)
            return True
        except ValueError:
            return False

    def queued_tokens(self) -> int:
        return sum(r.prompt_len for r in self.pending)

    def pop_admissible(self, now: float, limit: int, *,
                       backlog_tokens: int = 0, running_batch: int = 0,
                       chunk_budget: Optional[int] = None,
                       chunk_adaptive: bool = False,
                       hit_fn=None) -> List[Request]:
        out: List[Request] = []
        ahead = backlog_tokens
        taken: List[Request] = []
        # stable priority-then-arrival order: GenerationRequest.priority is
        # load-bearing — a high-priority late arrival is considered first
        for req in sorted(self.pending, key=lambda r: -r.priority):
            if len(out) >= limit:
                break
            # prefix-cache-aware charging: `hit_fn` (the engine's read-only
            # PrefixTree peek) reports how many leading prompt tokens are
            # already cached, so the TTFT prediction and the backlog each
            # admitted request contributes charge only the un-hit suffix
            hit = hit_fn(req) if hit_fn is not None else 0
            verdict = self.admission.decide(
                now, req.arrival, req.prompt_len - hit, ahead, req.ttft_slo,
                running_batch=running_batch, chunk_budget=chunk_budget,
                tbt_slo=req.tbt_slo, chunk_adaptive=chunk_adaptive)
            if verdict is Admission.QUEUE:
                # deadline still reachable once the backlog drains: keep the
                # request where it is and stop admitting this round (a
                # lower-priority request must not jump past a queued one)
                break
            taken.append(req)
            if verdict is Admission.REJECT:
                req.state = "rejected"
                self.rejected.append(req)
                continue
            ahead += req.prompt_len - hit
            out.append(req)
        for req in taken:
            self.pending.remove(req)
        return out


class BatchedServingEngine(EngineCore):
    """Continuous-batching engine: slot-pool KV + shared expert scheduling.

    max_batch: concurrent in-flight requests (= KV slots).
    max_seq:   per-slot KV capacity W (prompt + generated tokens must fit).
    prefill_budget: max prompt tokens of prefill work per step(); admitted
        requests prefill in chunks under this budget (state 'prefilling'),
        interleaved with the batched decode step so decoder inter-token
        gaps stay bounded. None = monolithic (each admitted request
        prefills fully inside the step that admits it). "auto" = derive
        the budget each step from the live LatencyModel so one chunk + one
        batched decode step fits the tightest in-flight TBT target
        (requires tbt_slo as the fallback when no request carries one).
    prefill_fairness: "rr" (default) rotates the per-step budget across
        all prefilling requests (one chunk shape, fair progress over
        steps); "srf" serves shortest-remaining-first; "fifo" always
        spends the budget head-of-line.
    prefix_cache: enable cross-request prefix/KV reuse (core/prefix.py):
        on admit the engine matches the prompt against a radix tree over
        the slot pool, copies the longest cached prefix's KV rows into the
        new request's buffers, and prefills only the un-hit suffix
        (admission charges only that suffix too). Completed prompts are
        offered back to the tree; a retiring request's slot is RETAINED as
        tree-owned cache while nodes reference it and reclaimed LRU when a
        free slot is needed. Bit-exact vs cold prefill at temperature 0
        (the copied rows are exactly what prefill would recompute —
        tests/test_prefix.py).
    tbt_slo: engine-default inter-token-gap bound (seconds) for the auto
        budget; per-request `tbt_slo` values tighten it.
    finished_window: retain only the most recent N finished/cancelled
        requests (None = unbounded; set for long-running servers so full
        per-request traces don't accumulate forever).

    ``step()`` returns a ``StepEvents`` list (serving/api.py) — the event
    stream is the primary output; ``run_until_drained()`` is a thin compat
    wrapper that drives it and returns the finished-request records.
    """

    def __init__(self, cfg, params, policy: str = "duo", *,
                 max_batch: int = 4, max_seq: int = 128,
                 prefill_budget: Union[int, str, None] = None,
                 prefill_fairness: str = "rr",
                 tbt_slo: Optional[float] = None,
                 finished_window: Optional[int] = None,
                 tbt_window: Optional[int] = 8192,
                 queue: Optional[RequestQueue] = None,
                 role: str = "both",
                 prefix_cache: bool = False,
                 grouped_decode: bool = True,
                 fused_prefill: Optional[bool] = None,
                 stats=None, predictor=None, cache_capacity=None,
                 temperature: float = 0.0, sample_seed: int = 0,
                 spans: Union[bool, SpanRecorder] = False):
        super().__init__(cfg, params, policy, stats=stats,
                         predictor=predictor, cache_capacity=cache_capacity,
                         temperature=temperature, sample_seed=sample_seed,
                         sched_batch=max_batch,
                         prefill_chunk=(prefill_budget
                                        if isinstance(prefill_budget, int)
                                        else None),
                         fused_prefill=fused_prefill, spans=spans)
        # grouped_decode=True (default): the batched decode expert sweep is
        # segment-gathered — each distinct expert computes only its
        # selecting rows, one FFN launch per layer (bit-exact vs the dense
        # full-batch path, which False retains as the A/B baseline)
        self.grouped_decode = grouped_decode
        self.decode_step_wall: List[float] = []
        self.max_batch = max_batch
        self.W = max_seq
        if prefill_budget == "auto":
            assert tbt_slo is not None and tbt_slo > 0, \
                'prefill_budget="auto" needs a tbt_slo target'
        else:
            assert prefill_budget is None or prefill_budget >= 1, \
                "prefill_budget must be None, 'auto', or >= 1 token"
        assert prefill_fairness in ("rr", "fifo", "srf")
        self.prefill_budget = prefill_budget
        self.prefill_fairness = prefill_fairness
        self.tbt_slo = tbt_slo
        self.queue = RequestQueue() if queue is None else queue
        self.sample_seed = sample_seed
        hkv, hd = cfg.n_kv_heads, cfg.hd
        self._K = [jnp.zeros((max_batch, max_seq, hkv, hd), PDT)
                   for _ in range(self.L)]
        self._V = [jnp.zeros_like(self._K[l]) for l in range(self.L)]
        self._slot_pos = np.full((max_batch, max_seq), -1, np.int32)
        self._free: List[int] = list(range(max_batch))[::-1]
        # disaggregated-cluster role (serving/cluster.py): "both" serves
        # the full lifecycle; "prefill" HOLDS requests once their prefill
        # completes (state 'held', first token emitted, excluded from the
        # decode batch) until their KV snapshot is handed to a decode
        # replica; "decode" is a handoff TARGET — it can run the full
        # lifecycle if submitted to directly (warm-up), routers just never
        # send it fresh work.
        assert role in ("both", "prefill", "decode"), f"bad role {role!r}"
        self.role = role
        self.prefilling: List[Request] = []   # state='prefilling'
        self.running: List[Request] = []
        self.held: List[Request] = []         # state='held' (role=prefill)
        self.finished: Deque[Request] = collections.deque(
            maxlen=finished_window)
        self.cancelled: Deque[Request] = collections.deque(
            maxlen=finished_window)
        self.tbt = TBTLedger(window=tbt_window)
        # TBT aggregates as PULL gauges off the one ledger (NaN-safe: the
        # sketches report nan until their first gap, which a JSON snapshot
        # must not carry)
        for q, sk in self.tbt.sketches.items():
            self.metrics.gauge(f"tbt_gap_seconds_p{int(q)}_stream",
                               "streaming P2 inter-token-gap percentile",
                               fn=_nan_to_zero(sk.value))
        self.metrics.gauge("tbt_gap_seconds_max",
                           "lifetime maximum inter-token gap",
                           fn=self.tbt.max_gap)
        self.metrics.gauge("tbt_gaps_total",
                           "inter-token gaps observed (lifetime)",
                           fn=lambda: self.tbt.total_gaps)
        self._h_step = self.metrics.histogram(
            "decode_step_seconds", "batched decode step wall time")
        # cross-request prefix/KV reuse (core/prefix.py); prefilled_tokens
        # counts prompt tokens that actually ran through prefill kernels —
        # with hits it is strictly less than the sum of prompt lengths
        self.prefix = PrefixTree() if prefix_cache else None
        self._c_prefilled = self.metrics.counter(
            "engine_prefilled_tokens_total",
            "prompt tokens run through prefill kernels")
        self._next_rid = 0
        self._pf_rr = 0   # round-robin rotation cursor across steps
        self.step_count = 0
        self.decode_batch_hist: List[int] = []

    @property
    def chunked(self) -> bool:
        return self.prefill_budget is not None

    @property
    def idle(self) -> bool:
        """No queued, prefilling, running, or held requests — nothing a
        step() could advance (event consumers use this, not event
        emptiness: prefill-chunk work emits no token). Held requests count:
        they are waiting on an EXTERNAL actor (the cluster handoff loop),
        so a driver must keep polling until they move."""
        return not (self.running or self.prefilling or self.held
                    or len(self.queue))

    @property
    def prefilled_tokens(self) -> int:
        """Thin view over the registry counter (obs-discipline: mutation
        happens only through ``self._c_prefilled.inc``)."""
        return int(self._c_prefilled.value)

    def _current_budget(self) -> Optional[int]:
        """Resolve this step's prefill token budget. Auto mode consults the
        live EWMA cost model (core/qos.py LatencyModel.suggest_chunk)
        against the TIGHTEST in-flight TBT target: the engine default and
        every prefilling/running request's own tbt_slo."""
        if self.prefill_budget is None:
            return None
        if self.prefill_budget == "auto":
            slos = [r.tbt_slo for r in self.running + self.prefilling
                    if r.tbt_slo is not None]
            slos.append(self.tbt_slo)
            return self.queue.admission.model.suggest_chunk(min(slos))
        return self.prefill_budget

    # -- event sink (buffer + _emit/drain_events live in EngineCore) --------
    def _emit_token(self, req: Request, tok: int, t: float,
                    first: bool = False) -> None:
        """THE token sink: every generated token — monolithic prefill,
        final prefill chunk, batched decode — funnels through here, so the
        event stream and the request's token list can never diverge. Also
        the stop-token early-termination point."""
        req.tokens.append(tok)
        if first:
            req.t_first = t
        self.tbt.observe(req.rid, t)
        if req.finish_reason is None and req.params.stop_token_ids \
                and tok in req.params.stop_token_ids:
            req.finish_reason = "stop_token"
        self._emit(TokenEvent(rid=req.rid, token=tok,
                              index=len(req.tokens) - 1, t=t, first=first))

    # -- submission ---------------------------------------------------------
    def submit_request(self, spec: GenerationRequest) -> Request:
        """Submit a typed GenerationRequest; returns the engine's runtime
        Request record (wrap it in a ServingFrontend RequestHandle for the
        streaming/cancellation interface)."""
        prompt = np.asarray(spec.prompt, np.int32).reshape(-1)
        req = Request(rid=self._next_rid, prompt=prompt, params=spec.params,
                      arrival=(time.perf_counter() if spec.arrival is None
                               else spec.arrival),
                      ttft_slo=spec.ttft_slo, tbt_slo=spec.tbt_slo,
                      priority=spec.priority)
        seed = (spec.params.seed if spec.params.seed is not None
                else self.sample_seed + req.rid)
        req.rng = np.random.default_rng(seed)
        need = req.prompt_len + spec.params.max_new_tokens + 1
        assert need <= self.W, f"request needs {need} slots > W={self.W}"
        self._next_rid += 1
        self.queue.submit(req)
        self.obs.instant("request.queued", rid=req.rid,
                         prompt_len=req.prompt_len)
        return req

    def submit(self, prompt: np.ndarray,
               params: Optional[SamplingParams] = None, *,
               max_new: Optional[int] = None,
               arrival: Optional[float] = None,
               ttft_slo: Optional[float] = None,
               tbt_slo: Optional[float] = None,
               priority: int = 0,
               temperature: Optional[float] = None) -> Request:
        """Compat sugar over `submit_request`: legacy `max_new=` /
        `temperature=` kwargs are folded into a SamplingParams."""
        if params is None:
            params = SamplingParams(
                temperature=temperature,
                max_new_tokens=16 if max_new is None else max_new)
        else:
            assert max_new is None and temperature is None, \
                "pass sampling via params OR legacy kwargs, not both"
        return self.submit_request(GenerationRequest(
            prompt=np.asarray(prompt, np.int32).reshape(-1), params=params,
            ttft_slo=ttft_slo, tbt_slo=tbt_slo, priority=priority,
            arrival=arrival))

    # -- cancellation -------------------------------------------------------
    def cancel(self, req: Request, reason: str = "cancelled") -> bool:
        """Cancel a request mid-flight. Synchronous and idempotent: on the
        first call a queued request is dequeued; a prefilling/running one is
        removed from its phase list, its KV slot returns to the free pool,
        its expert-residency contributions are dropped from the shared
        ledger (entries no other in-flight request also touched), and its
        TBT-ledger entry closes. One final ``FinishEvent(reason)`` is
        emitted; the request NEVER emits again. Returns False if already
        terminal.

        reason: recorded as the request's finish_reason — "cancelled"
        (caller-initiated, the default) or "slo_shed" (the QosAutopilot
        shedding a request whose TTFT/TBT deadline is already unmeetable,
        serving/cluster.py). Reclamation is identical for both."""
        if req.state in ("done", "rejected", "cancelled"):
            return False
        if req.state == "paused":
            # the engine holds NOTHING for a paused request — its life is
            # in a host-side RequestSnapshot; the snapshot's owner (the
            # frontend/autopilot) terminates it
            return False
        if req.state == "queued":
            if not self.queue.remove(req):
                return False
        elif req.state in ("prefilling", "running", "held"):
            {"prefilling": self.prefilling, "running": self.running,
             "held": self.held}[req.state].remove(req)
            self._release_expert_contributions(req)
            self._release_slot(req)
        else:  # pragma: no cover - unknown state is a bug
            raise AssertionError(f"cancel from state {req.state!r}")
        req.state = "cancelled"
        req.finish_reason = reason
        req.t_done = time.perf_counter()
        req.pf_k = req.pf_v = req.pf_sp = None
        req.active_sets = None
        self.tbt.close(req.rid)
        self.cancelled.append(req)
        self.obs.terminal(req.rid, reason, n_tokens=len(req.tokens))
        self._emit(FinishEvent(rid=req.rid, reason=reason,
                               n_tokens=len(req.tokens), t=req.t_done))
        return True

    @property
    def n_slo_shed(self) -> int:
        """Requests the autopilot shed mid-flight (within the retained
        `cancelled` window) — the engine-side ledger count of
        FinishEvent(reason="slo_shed") terminations."""
        return sum(1 for r in self.cancelled
                   if r.finish_reason == "slo_shed")

    # -- load introspection (cluster routing, serving/cluster.py) -----------
    def load(self) -> ReplicaLoad:
        """Snapshot this engine's outstanding work as a ReplicaLoad
        (core/qos.py): what the routers rank replicas by. Decode backlog
        counts every token THIS engine is still committed to produce —
        running requests' remaining budget plus prefilling requests' full
        budget (their decode work hasn't started). On a role='prefill'
        replica the decode work happens elsewhere after handoff, so held
        requests and prefilling requests' decode budgets are excluded.
        Host-PAUSED requests released every resource here and appear in no
        field — load (and every headroom computed from it) never charges
        them."""
        dec = sum(r.max_new + 1 - len(r.tokens) for r in self.running)
        if self.role != "prefill":
            dec += sum(r.max_new + 1 for r in self.prefilling)
        return ReplicaLoad(
            queue_depth=len(self.queue),
            queued_tokens=self.queue.queued_tokens(),
            prefill_backlog=sum(r.prefill_remaining
                                for r in self.prefilling),
            running=len(self.running),
            decode_backlog=dec,
            free_slots=len(self._free) + (self.prefix.n_reclaimable()
                                          if self.prefix is not None else 0),
            held=len(self.held))

    @property
    def slot_available(self) -> bool:
        """A KV slot could be handed out right now — free, or reclaimable
        from tree-owned prefix cache by LRU eviction."""
        return bool(self._free) or (self.prefix is not None
                                    and self.prefix.n_reclaimable() > 0)

    def _acquire_slot(self) -> int:
        """Pop a free KV slot, evicting tree-owned cached prefixes (LRU)
        to reclaim one when the free list is empty."""
        if not self._free and self.prefix is not None:
            self._free.extend(self.prefix.evict_for(1))
        return self._free.pop()

    def _release_slot(self, req: Request) -> None:
        self._slot_pos[req.slot, :] = -1
        if self.prefix is not None:
            if self.prefix.slot_released(req.slot):
                # the tree still references this slot's rows: the slot
                # becomes tree-owned prefix cache instead of returning to
                # the free list (reclaimed by _acquire_slot's LRU eviction)
                return
        self._free.append(req.slot)

    # -- cross-request prefix/KV reuse (core/prefix.py) ----------------------
    def _prefix_peek(self, req: Request) -> int:
        """Read-only longest cached prefix usable by `req`, capped at
        prompt_len - 1 so the final prompt position always prefills (its
        logits produce the first token). Admission charges only the
        remainder."""
        if self.prefix is None or req.prompt_len < 2:
            return 0
        return self.prefix.peek(req.prompt, limit=req.prompt_len - 1)

    def _prefix_match(self, req: Request):
        """Acquire the longest cached prefix for an admitted request: pins
        the tree path (the caller releases it once the rows are copied
        out) and returns (n_hit, the (slot, lo, hi) row blocks to copy).
        ``req.prefix_len`` records the hit as a per-request stat."""
        if self.prefix is None or req.prompt_len < 2:
            return 0, []
        n_hit, blocks = self.prefix.match(req.prompt,
                                          limit=req.prompt_len - 1)
        req.prefix_len = n_hit
        return n_hit, blocks

    def _prefix_insert(self, req: Request) -> None:
        """Offer the request's full prompt KV (now resident in its slot,
        rows 0..S-1) to the tree — called at every point a prompt's KV
        lands in the slot pool: monolithic admit, final prefill chunk,
        and 'running' restore."""
        if self.prefix is not None and req.prompt_len:
            self.prefix.insert(req.prompt, req.slot)

    def _seeded_pf(self, n_hit: int, blocks):
        """Fresh per-request prefill carry buffers with rows [0, n_hit)
        seeded from the tree's slot-pool blocks. Slot row == absolute
        position (the ring never wraps), so the copy is row-for-row and
        bit-identical to what cold prefill would have written."""
        hkv, hd = self.cfg.n_kv_heads, self.cfg.hd
        pf_k = [jnp.zeros((1, self.W, hkv, hd), PDT) for _ in range(self.L)]
        pf_v = [jnp.zeros_like(pf_k[l]) for l in range(self.L)]
        for l in range(self.L):
            for s, a, b in blocks:
                pf_k[l] = pf_k[l].at[0, a:b].set(self._K[l][s, a:b])
                pf_v[l] = pf_v[l].at[0, a:b].set(self._V[l][s, a:b])
        sp = np.full((1, self.W), -1, np.int32)
        if n_hit:
            sp[0, :n_hit] = np.arange(n_hit, dtype=np.int32)
        return pf_k, pf_v, jnp.asarray(sp)

    def _release_expert_contributions(self, req: Request) -> None:
        """Drop the cancelled request's expert-residency contributions: the
        (layer, expert) entries ITS prefill chunks / last decode step
        touched, minus anything another in-flight request also touched (a
        survivor's working set must not be yanked — dropping it would only
        cost refetches, never correctness, but the point of cancelling is
        to FREE budget, not to churn it). Pins are step-scoped (every plan
        path end_layer()s before the step returns), so between steps —
        where cancellation runs — all of the request's entries are
        unpinned; the pinned check is defensive."""
        def touched(r: Request) -> Set[ExpertKey]:
            keys: Set[ExpertKey] = set()
            if r.active_sets is not None:          # mid-prefill
                for l, s in enumerate(r.active_sets):
                    keys |= {(l, int(e)) for e in s}
            for l, acts in enumerate(r.prefill_active):
                keys |= {(l, int(e)) for e in acts}
            if r.trace:                            # last decode step
                for l in range(self.L):
                    keys |= {(l, int(e)) for e in r.trace[-1][l]}
            return keys

        mine = touched(req)
        for other in self.prefilling + self.running + self.held:
            if other is not req:
                mine -= touched(other)
        for key in mine:
            if self.cache.contains(key) and not self.cache.resident[key]:
                self.cache.drop(key)

    # -- snapshot / restore (pause, handoff, migration primitive) -----------
    def find_request(self, rid: int) -> Optional[Request]:
        """The live (queued/prefilling/running/held) request with id `rid`,
        or None — terminal and paused requests are not live here."""
        for r in (list(self.queue.pending) + self.prefilling
                  + self.running + self.held):
            if r.rid == rid:
                return r
        return None

    def prefix_score(self, prompt, limit: Optional[int] = None) -> int:
        """Router scoring signal (cluster prefix_affinity): the longest
        leading run of `prompt` this engine could serve from cache BY THE
        TIME the request would prefill — the tree's current contents PLUS
        the prompts of every live request (queued/prefilling/running/held
        work is KV the tree will hold before a new arrival is admitted
        behind it). Read-only; 0 without a prefix tree."""
        if self.prefix is None:
            return 0
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        cap = (int(prompt.shape[0]) if limit is None
               else min(int(limit), int(prompt.shape[0])))
        if cap <= 0:
            return 0
        best = self.prefix.peek(prompt, limit=cap)
        for r in (list(self.queue.pending) + self.prefilling
                  + self.running + self.held):
            n = min(cap, r.prompt_len)
            if n <= best:
                continue
            neq = np.nonzero(r.prompt[:n] != prompt[:n])[0]
            best = max(best, int(neq[0]) if neq.size else n)
        return best

    def prefix_head_for(self, req: Request) -> int:
        """How many leading KV rows of `req` THIS engine could rebuild
        from its own prefix tree — the shared head a tail-only handoff
        (``other.snapshot(req, kv_start=head)`` -> ``self.restore``) need
        not ship host-side. Capped at the prompt region actually captured
        (the tree only caches prompt rows)."""
        if self.prefix is None or req.state == "queued":
            return 0
        have = (req.prefill_pos if req.state == "prefilling" else req.pos)
        cap = min(req.prompt_len, have)
        if cap <= 0:
            return 0
        return self.prefix.peek(req.prompt, limit=cap)

    def snapshot(self, req: Union[Request, int], *,
                 kv_start: int = 0) -> RequestSnapshot:
        """Pause a live request and capture it as a host-side, engine-
        portable ``RequestSnapshot`` (serving/api.py).

        The KV prefix is gathered host-side from the request's slot (or,
        mid-prefill, from its chunk-carry buffers) as a DENSE array — the
        ring never wraps (``need <= W`` is asserted at submission), so ring
        slot == absolute position and row p is position p. Resource
        reclamation is exactly ``cancel()``'s: the KV slot returns to the
        free pool, expert-residency contributions no other in-flight
        request touched are dropped, and the TBT-ledger entry closes (so
        paused wall time is never charged as an inter-token gap — see
        ``TBTLedger.reopen``). Unlike cancel, NO FinishEvent is emitted:
        the request is not terminal, it is host-side; its state becomes
        'paused' and this engine never references it again. A ``held``
        request snapshots with state='running' — prefill is complete, any
        decode-capable engine resumes it straight into its batch.

        ``kv_start`` > 0 makes the snapshot TAIL-ONLY: the dense KV arrays
        cover positions ``[kv_start, P)`` and the destination rebuilds the
        shared head ``[0, kv_start)`` from its OWN prefix tree at restore
        (``ReplicaPool.migrate`` picks kv_start via the destination's
        ``prefix_head_for``). The head must lie inside the prompt region —
        only prompt rows are reconstructible from a prefix tree."""
        if isinstance(req, int):
            found = self.find_request(req)
            assert found is not None, f"no live request with rid {req}"
            req = found
        assert req.state in ("queued", "prefilling", "running", "held"), \
            f"snapshot from state {req.state!r}"
        assert not req.done, "snapshot of a finished request"
        spec = GenerationRequest(
            prompt=req.prompt, params=req.params, ttft_slo=req.ttft_slo,
            tbt_slo=req.tbt_slo, priority=req.priority, arrival=req.arrival)
        kv_k: List[np.ndarray] = []
        kv_v: List[np.ndarray] = []
        if req.state == "queued":
            assert kv_start == 0, "a queued snapshot carries no KV"
            ok = self.queue.remove(req)
            assert ok, "queued request not in its queue"
            state = "queued"
        elif req.state == "prefilling":
            P = req.prefill_pos
            assert 0 <= kv_start <= min(P, req.prompt_len), \
                f"kv_start {kv_start} outside captured prompt region"
            for l in range(self.L):
                kv_k.append(np.asarray(req.pf_k[l][0, kv_start:P]))
                kv_v.append(np.asarray(req.pf_v[l][0, kv_start:P]))
            self.prefilling.remove(req)
            self._release_expert_contributions(req)
            self._release_slot(req)
            state = "prefilling"
        else:
            # running/held: positions 0..pos-1 are written (the latest
            # token's KV lands when IT is decoded, not when sampled)
            P = req.pos
            assert 0 <= kv_start <= min(P, req.prompt_len), \
                f"kv_start {kv_start} outside captured prompt region"
            for l in range(self.L):
                kv_k.append(np.asarray(self._K[l][req.slot, kv_start:P]))
                kv_v.append(np.asarray(self._V[l][req.slot, kv_start:P]))
            (self.running if req.state == "running"
             else self.held).remove(req)
            self._release_expert_contributions(req)
            self._release_slot(req)
            state = "running"
        snap = RequestSnapshot(
            spec=spec, state=state, tokens=list(req.tokens),
            kv_k=kv_k, kv_v=kv_v, prefill_pos=req.prefill_pos,
            active_sets=([sorted(int(e) for e in s)
                          for s in req.active_sets]
                         if req.active_sets is not None else None),
            prefill_active=[list(map(int, a)) for a in req.prefill_active],
            trace=list(req.trace), pred=list(req.pred),
            hits=req.hits, misses=req.misses,
            t_start=req.t_start, t_first=req.t_first,
            tbt_gaps=list(self.tbt.by_rid.get(req.rid, ())),
            rng_state=(req.rng.bit_generator.state
                       if req.rng is not None else None),
            # the obs monotonic clock — the SAME source the destination
            # stamps t_restore with (serving/frontend.py), so handoff
            # latency can never go negative under wall-clock adjustment
            source_rid=req.rid, t_snapshot=monotonic(),
            kv_start=kv_start)
        self.tbt.close(req.rid)
        self.obs.instant("request.paused", rid=req.rid,
                         kv_bytes=snap.kv_bytes, state=state)
        req.state = "paused"
        req.slot = -1
        req.pf_k = req.pf_v = req.pf_sp = None
        req.active_sets = None
        return snap

    def can_restore(self, snap: RequestSnapshot) -> bool:
        """Whether ``restore(snap)`` would succeed right now: the request
        fits a KV slot (always true for a still-queued snapshot), mid-
        prefill this engine can run chunked prefill, and a tail-only
        snapshot's shared head is present in this engine's prefix tree."""
        prompt = np.asarray(snap.spec.prompt).reshape(-1)
        need = int(prompt.shape[0]) + snap.spec.params.max_new_tokens + 1
        if need > self.W:
            return False
        if snap.kv_start and (
                self.prefix is None
                or self.prefix.peek(prompt,
                                    limit=snap.kv_start) < snap.kv_start):
            return False
        if snap.state == "queued":
            return True
        return self.slot_available and \
            (snap.state != "prefilling" or self.chunked)

    def restore(self, snap: RequestSnapshot) -> Request:
        """Resume a snapshot on THIS engine as a fresh request (new rid —
        rids stay engine-local and monotonic; cluster consumers track the
        HANDLE, which the frontend rebinds). The carried rng state, token
        list, and KV prefix make the continuation bit-exact: the dense KV
        rows scatter into a free slot at positions ``0..P-1`` and every
        later ring position stays -1, which the attention mask weights to
        exactly zero — stale slot contents cannot leak in. A 'running'
        snapshot joins the decode batch (or this replica's held list if it
        is itself role='prefill'); a 'prefilling' one resumes chunking from
        ``prefill_pos``; a 'queued' one simply re-enqueues. The TBT ledger
        reopens WITHOUT a baseline, so the pause is never charged as a
        gap."""
        spec = snap.spec
        req = Request(rid=self._next_rid,
                      prompt=np.asarray(spec.prompt, np.int32).reshape(-1),
                      params=spec.params, arrival=spec.arrival,
                      ttft_slo=spec.ttft_slo, tbt_slo=spec.tbt_slo,
                      priority=spec.priority)
        need = req.prompt_len + req.max_new + 1
        assert need <= self.W, \
            f"restored request needs {need} slots > W={self.W}"
        self._next_rid += 1
        req.rng = np.random.default_rng()
        if snap.rng_state is not None:
            req.rng.bit_generator.state = snap.rng_state
        req.tokens = list(snap.tokens)
        req.trace = list(snap.trace)
        req.pred = list(snap.pred)
        req.hits, req.misses = snap.hits, snap.misses
        req.t_start, req.t_first = snap.t_start, snap.t_first
        req.prefill_active = [list(a) for a in snap.prefill_active]
        if snap.state == "queued":
            req.state = "queued"
            self.queue.submit(req)
            self.obs.instant("request.restored", rid=req.rid,
                             source_rid=snap.source_rid, state="queued")
            return req
        # tail-only snapshot: rebuild the shared head [0, kv_start) from
        # THIS engine's prefix tree. Match (and pin) the head path BEFORE
        # acquiring a slot — _acquire_slot may evict tree-owned cache, and
        # a pinned path is never evicted from under us; the pin drops as
        # soon as the head rows are copied below.
        head = snap.kv_start
        blocks: List = []
        if head:
            assert self.prefix is not None, \
                "tail-only snapshot needs a prefix tree on the target"
            n_hit, blocks = self.prefix.match(req.prompt, limit=head)
            assert n_hit == head, \
                f"target lost the shared head: have {n_hit} of {head} rows"
            req.prefix_len = head
        assert self.slot_available, "no free KV slot to restore into"
        slot = self._acquire_slot()
        req.slot = slot
        self._slot_pos[slot, :] = -1
        if snap.state == "prefilling":
            assert self.chunked, "mid-prefill restore needs a chunked engine"
            P = snap.prefill_pos
            req.state = "prefilling"
            req.prefill_pos = P
            req.active_sets = [set(s) for s in snap.active_sets]
            req.pf_k, req.pf_v, _ = self._seeded_pf(head, blocks)
            for l in range(self.L):
                if P > head:
                    req.pf_k[l] = req.pf_k[l].at[0, head:P].set(
                        jnp.asarray(snap.kv_k[l], PDT))
                    req.pf_v[l] = req.pf_v[l].at[0, head:P].set(
                        jnp.asarray(snap.kv_v[l], PDT))
            sp = np.full((1, self.W), -1, np.int32)
            sp[0, :P] = np.arange(P, dtype=np.int32)
            req.pf_sp = jnp.asarray(sp)
            self.prefilling.append(req)
        else:
            assert snap.state == "running", f"bad state {snap.state!r}"
            P = req.pos
            for l in range(self.L):
                K, V = self._K[l], self._V[l]
                for s, a, b in blocks:
                    K = K.at[slot, a:b].set(self._K[l][s, a:b])
                    V = V.at[slot, a:b].set(self._V[l][s, a:b])
                if P > head:
                    K = K.at[slot, head:P].set(jnp.asarray(snap.kv_k[l],
                                                           PDT))
                    V = V.at[slot, head:P].set(jnp.asarray(snap.kv_v[l],
                                                           PDT))
                self._K[l], self._V[l] = K, V
            self._slot_pos[slot, :P] = np.arange(P, dtype=np.int32)
            req.prefill_pos = req.prompt_len
            self._prefix_insert(req)
            self._finish_prefill(req)   # running, or held on role='prefill'
        if head:
            self.prefix.release(req.prompt, head)   # head rows are copied
        self.tbt.reopen(req.rid, snap.tbt_gaps)
        self.obs.instant("request.restored", rid=req.rid,
                         source_rid=snap.source_rid, state=req.state)
        return req

    # -- prefill phase ------------------------------------------------------
    def _admit_and_prefill(self, now: float) -> List[Request]:
        """Admit queue arrivals into free KV slots.

        Monolithic mode (prefill_budget=None): each admitted request
        prefills fully, right here, exactly as before chunking existed.
        Chunked mode: the request only transitions to 'prefilling'; chunk
        execution happens in `_prefill_work` under the step's token budget.
        """
        n_rej = len(self.queue.rejected)
        backlog = sum(r.prefill_remaining for r in self.prefilling)
        free_now = len(self._free) + (self.prefix.n_reclaimable()
                                      if self.prefix is not None else 0)
        newly = self.queue.pop_admissible(
            now, limit=free_now, backlog_tokens=backlog,
            running_batch=len(self.running),
            chunk_budget=self._current_budget(),
            chunk_adaptive=self.prefill_budget == "auto",
            hit_fn=(self._prefix_peek if self.prefix is not None else None))
        for r in self.queue.rejected[n_rej:]:
            self.obs.terminal(r.rid, "rejected", reason_detail="slo")
            self._emit(RejectEvent(rid=r.rid, reason="slo",
                                   t=time.perf_counter()))
        for req in newly:
            req.t_start = now
            self.obs.instant("request.admitted", rid=req.rid)
            # longest cached prefix (capped at S-1): match pins the path
            # only while its rows are copied into fresh carry buffers —
            # once seeded, the pin drops so _acquire_slot below may evict
            # ANY tree-owned slot, including the donor's (at max_batch=1
            # the hit's own donor slot is exactly the one reclaimed)
            n_hit, blocks = self._prefix_match(req)
            pf_k = pf_v = pf_sp = None
            if n_hit:
                pf_k, pf_v, pf_sp = self._seeded_pf(n_hit, blocks)
                self.prefix.release(req.prompt, n_hit)
            slot = self._acquire_slot()
            req.slot = slot
            self._slot_pos[slot, :] = -1
            if self.chunked:
                req.state = "prefilling"
                req.prefill_pos = n_hit
                req.active_sets = [set() for _ in range(self.L)]
                if pf_k is None:
                    pf_k, pf_v, pf_sp = self._seeded_pf(0, [])
                req.pf_k, req.pf_v, req.pf_sp = pf_k, pf_v, pf_sp
                self.prefilling.append(req)
                continue
            req.state = "running"
            t0 = time.perf_counter()
            S = req.prompt_len
            pt = self.obs.begin("prefill", lane="prefill", rid=req.rid,
                                tokens=S - n_hit)
            if n_hit:
                # monolithic engine with a hit: run the un-hit suffix as
                # ONE whole chunk over the seeded carry buffers — the
                # chunked==monolithic exactness invariant makes the tokens
                # bit-identical to a cold whole-prompt prefill
                logits, pf_k, pf_v, pf_sp, active, _ = self.prefill_chunk(
                    req.prompt[None, n_hit:], n_hit, pf_k, pf_v, pf_sp,
                    need_logits=True)
                for l in range(self.L):
                    self._K[l] = self._K[l].at[slot, :S].set(pf_k[l][0, :S])
                    self._V[l] = self._V[l].at[slot, :S].set(pf_v[l][0, :S])
                active = [sorted(set(a)) for a in active]
            else:
                logits, (kc, vc), active, _ = self.prefill_layers(
                    req.prompt.reshape(1, -1))
                for l in range(self.L):
                    self._K[l] = self._K[l].at[slot, :S].set(kc[l][0])
                    self._V[l] = self._V[l].at[slot, :S].set(vc[l][0])
            self._slot_pos[slot, :S] = np.arange(S, dtype=np.int32)
            req.prefill_pos = S
            req.prefill_active = active
            self._c_prefilled.inc(S - n_hit)
            self._prefix_insert(req)
            tok = self._sample_req(req, logits[0])
            self._emit_token(req, tok, time.perf_counter(), first=True)
            self.queue.admission.model.observe_prefill(S - n_hit,
                                                       req.t_first - t0)
            self.obs.end(pt)
            self._finish_prefill(req)
        return newly

    def _finish_prefill(self, req: Request) -> None:
        """Prefill done, first token emitted: a role='prefill' replica
        HOLDS the request (state 'held', out of the decode batch) until the
        cluster hands its KV snapshot to a decode replica; every other role
        joins this step's decode batch."""
        if self.role == "prefill":
            req.state = "held"
            self.held.append(req)
        else:
            req.state = "running"
            self.running.append(req)

    def _run_prefill_chunk(self, req: Request, C: int) -> None:
        """Advance one 'prefilling' request by a C-token chunk.

        The chunk runs through `EngineCore.prefill_chunk` directly against
        the request's KV slot: it attends over the slot's already-written
        prefix and appends its own K/V, and the scheduler sees it through
        the ordinary per-layer `prefill_plan` path. When the request's
        final chunk completes, its first token is sampled — exactly the
        token monolithic prefill would have produced — and it joins this
        same step's decode batch (like a monolithically prefilled arrival).
        """
        t0 = time.perf_counter()
        slot, start = req.slot, req.prefill_pos
        stop = start + C
        final = stop == req.prompt_len
        pt = self.obs.begin("prefill.chunk", lane="prefill", rid=req.rid,
                            start=start, tokens=C)
        logits, req.pf_k, req.pf_v, req.pf_sp, act, _ = \
            self.prefill_chunk(req.prompt[None, start:stop], start,
                               req.pf_k, req.pf_v, req.pf_sp,
                               need_logits=final)
        for l in range(self.L):
            req.active_sets[l].update(act[l])
        req.prefill_pos = stop
        self._c_prefilled.inc(C)
        self.queue.admission.model.observe_prefill(
            C, time.perf_counter() - t0)
        if final:
            # one scatter into the slot pool for the whole prompt
            self.obs.instant("kv.scatter", lane="prefill", rid=req.rid,
                             rows=req.prompt_len)
            for l in range(self.L):
                self._K[l] = self._K[l].at[slot].set(req.pf_k[l][0])
                self._V[l] = self._V[l].at[slot].set(req.pf_v[l][0])
            self._slot_pos[slot] = np.asarray(req.pf_sp[0])
            req.pf_k = req.pf_v = req.pf_sp = None
            req.prefill_active = [sorted(s) for s in req.active_sets]
            req.active_sets = None
            self._prefix_insert(req)
            tok = self._sample_req(req, logits[0])
            self._emit_token(req, tok, time.perf_counter(), first=True)
            self.prefilling.remove(req)
            self._finish_prefill(req)
        self.obs.end(pt, final=final)

    def _prefill_work(self) -> int:
        """Spend up to this step's prefill budget advancing 'prefilling'
        requests (stall-free interleaving). Returns tokens of work done.

        Fairness: "fifo" always serves the head request (a long prompt
        monopolizes prefill until done — a short prompt behind it waits for
        EVERY earlier prefill to complete). "rr" rotates which prefilling
        request receives the step's budget, so overlapping prompts make
        interleaved progress and a short arrival's TTFT is bounded by
        ~n_prefilling * (len/budget) steps instead of the whole backlog.
        "srf" orders by `prefill_remaining` (shortest first, rid tiebreak):
        a short straggler overtakes every long backlog immediately — the
        best straggler TTFT of the three — while the longest prompt pays
        for everyone that overtook it (bench_stall --fairness compares all
        modes). In every mode the budget goes to one request at a time
        (spilling to the next in order when it finishes early) rather than
        being split — chunk shapes stay constant, so the chunked-prefill
        kernels compile once per budget, not once per (budget/n) share."""
        if not self.chunked:
            return 0  # monolithic mode: prefill happened at admission
        budget = self._current_budget()
        spent = 0
        if self.prefilling and self.prefill_fairness == "rr":
            rot = self._pf_rr % len(self.prefilling)
            self._pf_rr += 1
            order = self.prefilling[rot:] + self.prefilling[:rot]
        elif self.prefilling and self.prefill_fairness == "srf":
            # shortest-remaining-first: deterministic (rid tiebreak), and
            # re-sorted every step so progress keeps the order current
            order = sorted(self.prefilling,
                           key=lambda r: (r.prefill_remaining, r.rid))
        else:
            order = list(self.prefilling)  # fifo: head-of-line
        for req in order:
            if budget <= 0:
                break
            C = min(budget, req.prefill_remaining)
            self._run_prefill_chunk(req, C)
            spent += C
            budget -= C
        return spent

    def _sample_req(self, req: Request, logits_row) -> int:
        temp = (self.temperature if req.temperature is None
                else req.temperature)
        return self.sample_row(np.asarray(logits_row, np.float64), temp,
                               req.rng)

    # -- decode phase -------------------------------------------------------
    def _decode_step(self, batch: List[Request]) -> None:
        """One batched decode step: every request advances by one token.

        Per-row accumulation follows each request's own top-k order, so the
        result is bit-identical to B independent single-request steps —
        on BOTH expert-execution disciplines (grouped_decode segment-gather
        default, dense full-batch baseline).
        Output goes through the `_emit_token` event sink.
        """
        B = len(batch)
        t0 = time.perf_counter()
        dt = self.obs.begin("decode.step", lane="decode", batch=B)
        idx = np.asarray([r.slot for r in batch], np.int32)
        toks = np.asarray([[r.tokens[-1]] for r in batch], np.int32)
        pos_np = np.asarray([r.pos for r in batch], np.int32)
        slot_np = pos_np % self.W
        rows = np.arange(B)
        for b in range(B):
            self._slot_pos[idx[b], slot_np[b]] = pos_np[b]
        sp = jnp.asarray(self._slot_pos[idx])
        pos = jnp.asarray(pos_np)
        slot = jnp.asarray(slot_np)
        jidx = jnp.asarray(idx)

        x = self.dev["embed"].at[jnp.asarray(toks)].get(mode="clip")
        if isinstance(self.sched, DuoServeScheduler):
            self.sched.begin_decode_step()
        step_trace = np.zeros((B, self.L, self.k), np.int32)
        step_pred = np.full((B, self.L, self.k), -1, np.int32)
        for l in range(self.L):
            lp = self._layer(l)
            ck = self._K[l][jidx]
            cv = self._V[l][jidx]
            x, ck, cv = self._attn_decode_batched(lp, x, ck, cv, sp, slot,
                                                  pos)
            self._K[l] = self._K[l].at[jidx].set(ck)
            self._V[l] = self._V[l].at[jidx].set(cv)
            self.obs.instant("kv.scatter", lane="decode", layer=l, rows=B)
            xn, w, ids = self._gate(self._moe_dev(l), lp, x)
            ids_np = np.asarray(ids).reshape(B, self.k)
            step_trace[:, l] = ids_np
            selections = [list(map(int, ids_np[b])) for b in range(B)]
            plan = self.sched.decode_plan(l, selections)
            # hits + misses together cover exactly the distinct selections
            union = plan.hits + plan.misses
            np_pred = plan.predicted[: self.k]
            step_pred[:, l, : len(np_pred)] = np_pred
            # correction fetches for misses (sync point #1), once per expert
            if plan.misses:
                ct = self.obs.begin("prefetch.correction", lane="prefetch",
                                    layer=l, n=len(plan.misses))
                for e in plan.misses:
                    self.cache.prefetch((l, e))
                    self.cache.wait((l, e))
                self.obs.end(ct)
            hit_set, miss_set = set(plan.hits), set(plan.misses)
            for b, r in enumerate(batch):
                r.hits += len(set(selections[b]) & hit_set)
                r.misses += len(set(selections[b]) & miss_set)
            self.perf.inc("decode_layers")
            self.perf.inc("decode_rows_dense", len(union) * B)
            acc = self._shared(self._moe_dev(l), xn)
            if union and self.grouped_decode:
                # segment-gathered sweep: ONE launch computes only each
                # expert's selecting rows ([U, C, d] instead of U x [B, d]),
                # slots resolved in one vectorized pass; the scatter-back
                # walks j = 0..k-1 so every row still accumulates in its
                # OWN top-k order — bit-identical to the dense path below
                disp = group_by_expert(ids_np, union, bucket_cap=B,
                                       u_bucket_cap=min(self.E, B * self.k))
                raw_g = self._grouped_ffn_raw(l, union, xn, disp.row_idx)
                self.obs.instant("ffn.launch", lane="decode", layer=l,
                                 rows=disp.n_launched)
                self.perf.inc("decode_ffn_launches")
                self.perf.inc("decode_rows_grouped", disp.n_rows)
                self.perf.inc("decode_rows_launched", disp.n_launched)
                for j in range(self.k):
                    y = raw_g[jnp.asarray(disp.u_of[:, j]),
                              jnp.asarray(disp.c_of[:, j])]  # f32 [B, d]
                    acc = acc + (y * w[:, j, None]).astype(acc.dtype)
            elif union:
                # dense full-batch baseline: one pre-gate output per
                # DISTINCT expert, each over all B rows, read by slot index
                # out of the shared residency pools (pools re-read after
                # every slot(): a pending transfer swaps in a fresh pool
                # array object)
                raw: Dict[int, jnp.ndarray] = {}
                for e in union:
                    eslot = jnp.int32(self.cache.slot((l, e)))
                    raw[e] = self._expert_raw(xn, *self.cache.pools,
                                              eslot)  # f32 [B, d]
                self.obs.instant("ffn.launch", lane="decode", layer=l,
                                 rows=len(union) * B, launches=len(union))
                self.perf.inc("decode_ffn_launches", len(union))
                self.perf.inc("decode_rows_launched", len(union) * B)
                stacked = jnp.stack([raw[e] for e in union])  # [U, B, d]
                inv = np.zeros(self.E, np.int32)
                for u, e in enumerate(union):
                    inv[e] = u
                for j in range(self.k):
                    # j-th choice of every row, in that row's own top-k order
                    y = stacked[jnp.asarray(inv[ids_np[:, j]]), rows]
                    acc = acc + (y * w[:, j, None]).astype(acc.dtype)
            x = x + acc.reshape(x.shape)
            # prediction stream: prefetch layer l+1's experts for the batch
            if plan.prefetch_next:
                self.obs.instant("prefetch.dispatch", lane="prefetch",
                                 layer=l, n=len(plan.prefetch_next))
            for e in plan.prefetch_next:
                self.cache.prefetch((l + 1, e))
        # unpin the successor-less last layer (see MoEServingEngine.decode):
        # without this, a continuously batching engine (which never calls
        # begin_request) accumulates pinned (L-1, e) entries forever
        self.sched.end_layer(self.L - 1)
        logits = self._head(self.dev["ln_f"], self.dev["embed"], x[:, -1])
        lg_np = np.asarray(logits, np.float64)
        t_tok = time.perf_counter()
        for b, r in enumerate(batch):
            self._emit_token(r, self._sample_req(r, lg_np[b]), t_tok)
            r.trace.append(step_trace[b])
            r.pred.append(step_pred[b])
        self.queue.admission.model.observe_decode_step(t_tok - t0)
        self.decode_step_wall.append(t_tok - t0)
        self.decode_batch_hist.append(B)
        self._h_step.observe(t_tok - t0)
        self.obs.end(dt, batch=B)

    # -- scheduler loop -----------------------------------------------------
    def step(self, now: Optional[float] = None) -> StepEvents:
        """One engine iteration: admit new arrivals, spend the prefill token
        budget on chunked prefill work (monolithic when prefill_budget is
        None), then one batched decode step for all in-flight requests.

        Returns the step's event stream (StepEvents): TokenEvents for every
        token generated this step, FinishEvents for requests retired this
        step (plus any cancellations since the last step), RejectEvents for
        admission sheds. `events.did_work` is True if any work was done —
        use it (not event-list truthiness) for idle detection."""
        now = time.perf_counter() if now is None else now
        admitted = self._admit_and_prefill(now)
        prefilled = self._prefill_work()
        batch = [r for r in self.running if not r.done]
        if batch:
            self._decode_step(batch)
        did_work = bool(admitted or prefilled or batch)
        self.step_count += 1
        # retire finished requests, free their slots (held requests can
        # finish at their FIRST token — stop token or max_new_tokens=0 —
        # without ever reaching a decode replica)
        self.running = [r for r in self.running if not self._retire(r)]
        self.held = [r for r in self.held if not self._retire(r)]
        return StepEvents(self.drain_events(), did_work)

    def _retire(self, r: Request) -> bool:
        if not r.done:
            return False
        r.state = "done"
        if r.finish_reason is None:
            r.finish_reason = "length"
        r.t_done = time.perf_counter()
        self._release_slot(r)
        self.finished.append(r)
        self.tbt.close(r.rid)
        self.obs.terminal(r.rid, r.finish_reason, n_tokens=len(r.tokens))
        self._emit(FinishEvent(rid=r.rid, reason=r.finish_reason,
                               n_tokens=len(r.tokens), t=r.t_done))
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> Deque[Request]:
        """Thin compat wrapper over the event stream: drive step() until
        queue + prefilling + running are all empty, discarding the events
        (every token is still recorded on its Request), and return the
        finished-request records."""
        for _ in range(max_steps):
            self.step()
            if self.idle:
                break
        return self.finished
