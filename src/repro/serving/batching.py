"""Continuous-batching serving front-end (ROADMAP: multi-request QoS).

Generalizes the paper's single-request dual-phase runtime to concurrent
load, the regime its TTFT/E2E SLO claims actually target:

  * ``RequestQueue`` — arrival queue with SLO-aware admission: predicted
    TTFT (EWMA cost model, ``core/qos.py``) is checked against each
    request's deadline; requests whose deadline is already unmeetable are
    shed instead of poisoning the batch.
  * ``BatchedServingEngine`` — continuous batching over the layer-by-layer
    engine core: requests are admitted mid-flight; each scheduler iteration
    runs prefill for newly admitted arrivals, then ONE batched decode step
    for every in-flight request. KV lives in a slot pool (one slot per
    in-flight request, per-request write positions, ring invariant
    slot == pos % W), so sequences at different positions decode together
    via ``self_attn_decode_batched``.
  * Decode-phase expert scheduling is shared: per-step, per-layer expert
    selections of all B requests are unioned (first-appearance order) and
    handed to ONE scheduler/DeviceExpertCache pair (paper §V generalized to
    B>1) — each distinct expert is fetched at most once per step, and the
    ExpertMLP prediction stream prefetches layer l+1 for the whole batch.

Exactness invariant: every decode-side kernel is row-wise deterministic and
per-row accumulation follows each request's own top-k order, so at
temperature 0 a batched step reproduces the single-request engine's tokens
bit-exactly (tests/test_serving_batch.py).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.qos import Admission, AdmissionController
from repro.core.scheduler import DuoServeScheduler
from repro.models.layers import PDT
from repro.serving.engine import EngineCore, RequestResult


@dataclasses.dataclass
class Request:
    """One serving request moving through the continuous-batching engine."""
    rid: int
    prompt: np.ndarray               # [S] int32
    max_new: int
    arrival: float
    ttft_slo: Optional[float] = None
    temperature: Optional[float] = None   # None = engine default
    # runtime state ---------------------------------------------------------
    state: str = "queued"            # queued|running|done|rejected
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    prefill_active: List[List[int]] = dataclasses.field(default_factory=list)
    trace: List[np.ndarray] = dataclasses.field(default_factory=list)
    pred: List[np.ndarray] = dataclasses.field(default_factory=list)
    hits: int = 0
    misses: int = 0
    t_start: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    rng: Optional[np.random.Generator] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def pos(self) -> int:
        """Absolute position of the NEXT token to decode."""
        return self.prompt_len + len(self.tokens) - 1

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new + 1  # first token + max_new

    def result(self) -> RequestResult:
        T = len(self.trace)
        L_k = self.trace[0].shape if T else (0, 0)
        return RequestResult(
            tokens=np.asarray(self.tokens, np.int64),
            prefill_active=self.prefill_active,
            decode_trace=(np.stack(self.trace) if T
                          else np.zeros((0,) + L_k, np.int32)),
            pred_trace=(np.stack(self.pred) if T
                        else np.zeros((0,) + L_k, np.int32)),
            ttft_wall=self.t_first - self.arrival,
            e2e_wall=self.t_done - self.arrival,
            hits=self.hits, misses=self.misses)


class RequestQueue:
    """FIFO arrival queue with SLO-aware admission (core/qos.py).

    `pop_admissible` hands back up to `limit` requests whose predicted TTFT
    fits their deadline; breached requests are shed (state='rejected') so a
    doomed prompt never occupies a KV slot another request could meet its
    SLO with.
    """

    def __init__(self, admission: Optional[AdmissionController] = None):
        self.admission = admission or AdmissionController()
        self.pending: Deque[Request] = collections.deque()
        self.rejected: List[Request] = []

    def __len__(self) -> int:
        return len(self.pending)

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def queued_tokens(self) -> int:
        return sum(r.prompt_len for r in self.pending)

    def pop_admissible(self, now: float, limit: int) -> List[Request]:
        out: List[Request] = []
        ahead = 0
        while self.pending and len(out) < limit:
            req = self.pending[0]
            verdict = self.admission.decide(
                now, req.arrival, req.prompt_len, ahead, req.ttft_slo)
            if verdict is Admission.QUEUE:
                # deadline still reachable once the backlog drains: keep the
                # request at the head (FIFO) and stop admitting this round
                break
            self.pending.popleft()
            if verdict is Admission.REJECT:
                req.state = "rejected"
                self.rejected.append(req)
                continue
            ahead += req.prompt_len
            out.append(req)
        return out


class BatchedServingEngine(EngineCore):
    """Continuous-batching engine: slot-pool KV + shared expert scheduling.

    max_batch: concurrent in-flight requests (= KV slots).
    max_seq:   per-slot KV capacity W (prompt + generated tokens must fit).
    """

    def __init__(self, cfg, params, policy: str = "duo", *,
                 max_batch: int = 4, max_seq: int = 128,
                 queue: Optional[RequestQueue] = None,
                 stats=None, predictor=None, cache_capacity=None,
                 temperature: float = 0.0, sample_seed: int = 0):
        super().__init__(cfg, params, policy, stats=stats,
                         predictor=predictor, cache_capacity=cache_capacity,
                         temperature=temperature, sample_seed=sample_seed,
                         sched_batch=max_batch)
        self.max_batch = max_batch
        self.W = max_seq
        self.queue = RequestQueue() if queue is None else queue
        self.sample_seed = sample_seed
        hkv, hd = cfg.n_kv_heads, cfg.hd
        self._K = [jnp.zeros((max_batch, max_seq, hkv, hd), PDT)
                   for _ in range(self.L)]
        self._V = [jnp.zeros_like(self._K[l]) for l in range(self.L)]
        self._slot_pos = np.full((max_batch, max_seq), -1, np.int32)
        self._free: List[int] = list(range(max_batch))[::-1]
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self._next_rid = 0
        self.step_count = 0
        self.decode_batch_hist: List[int] = []

    # -- submission ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int = 16, *,
               arrival: Optional[float] = None,
               ttft_slo: Optional[float] = None,
               temperature: Optional[float] = None) -> Request:
        req = Request(rid=self._next_rid,
                      prompt=np.asarray(prompt, np.int32).reshape(-1),
                      max_new=max_new,
                      arrival=(time.perf_counter() if arrival is None
                               else arrival),
                      ttft_slo=ttft_slo, temperature=temperature)
        req.rng = np.random.default_rng(self.sample_seed + req.rid)
        assert req.prompt_len + max_new + 1 <= self.W, \
            f"request needs {req.prompt_len + max_new + 1} slots > W={self.W}"
        self._next_rid += 1
        self.queue.submit(req)
        return req

    # -- prefill phase ------------------------------------------------------
    def _admit_and_prefill(self, now: float) -> List[Request]:
        newly = self.queue.pop_admissible(now, limit=len(self._free))
        for req in newly:
            slot = self._free.pop()
            req.slot = slot
            req.state = "running"
            req.t_start = now
            t0 = time.perf_counter()
            logits, (kc, vc), active, _ = self.prefill_layers(
                req.prompt.reshape(1, -1))
            S = req.prompt_len
            for l in range(self.L):
                self._K[l] = self._K[l].at[slot, :S].set(kc[l][0])
                self._V[l] = self._V[l].at[slot, :S].set(vc[l][0])
            self._slot_pos[slot, :] = -1
            self._slot_pos[slot, :S] = np.arange(S, dtype=np.int32)
            req.prefill_active = active
            req.tokens.append(self._sample_req(req, logits[0]))
            req.t_first = time.perf_counter()
            self.queue.admission.model.observe_prefill(S, req.t_first - t0)
            self.running.append(req)
        return newly

    def _sample_req(self, req: Request, logits_row) -> int:
        temp = (self.temperature if req.temperature is None
                else req.temperature)
        return self.sample_row(np.asarray(logits_row, np.float64), temp,
                               req.rng)

    # -- decode phase -------------------------------------------------------
    def _decode_step(self, batch: List[Request]) -> None:
        """One batched decode step: every request advances by one token.

        Per-row accumulation follows each request's own top-k order, so the
        result is bit-identical to B independent single-request steps.
        """
        B = len(batch)
        t0 = time.perf_counter()
        idx = np.asarray([r.slot for r in batch], np.int32)
        toks = np.asarray([[r.tokens[-1]] for r in batch], np.int32)
        pos_np = np.asarray([r.pos for r in batch], np.int32)
        slot_np = pos_np % self.W
        rows = np.arange(B)
        for b in range(B):
            self._slot_pos[idx[b], slot_np[b]] = pos_np[b]
        sp = jnp.asarray(self._slot_pos[idx])
        pos = jnp.asarray(pos_np)
        slot = jnp.asarray(slot_np)
        jidx = jnp.asarray(idx)

        x = self.dev["embed"].at[jnp.asarray(toks)].get(mode="clip")
        if isinstance(self.sched, DuoServeScheduler):
            self.sched.begin_decode_step()
        step_trace = np.zeros((B, self.L, self.k), np.int32)
        step_pred = np.full((B, self.L, self.k), -1, np.int32)
        for l in range(self.L):
            lp = self._layer(l)
            ck = self._K[l][jidx]
            cv = self._V[l][jidx]
            x, ck, cv = self._attn_decode_batched(lp, x, ck, cv, sp, slot,
                                                  pos)
            self._K[l] = self._K[l].at[jidx].set(ck)
            self._V[l] = self._V[l].at[jidx].set(cv)
            xn, w, ids = self._gate(self._moe_dev(l), lp, x)
            ids_np = np.asarray(ids).reshape(B, self.k)
            step_trace[:, l] = ids_np
            selections = [list(map(int, ids_np[b])) for b in range(B)]
            plan = self.sched.decode_plan(l, selections)
            # hits + misses together cover exactly the distinct selections
            union = plan.hits + plan.misses
            np_pred = plan.predicted[: self.k]
            step_pred[:, l, : len(np_pred)] = np_pred
            # correction fetches for misses (sync point #1), once per expert
            for e in plan.misses:
                self.cache.prefetch((l, e))
                self.cache.wait((l, e))
            hit_set, miss_set = set(plan.hits), set(plan.misses)
            for b, r in enumerate(batch):
                r.hits += len(set(selections[b]) & hit_set)
                r.misses += len(set(selections[b]) & miss_set)
            # one pre-gate output per DISTINCT expert across the batch
            raw: Dict[int, jnp.ndarray] = {}
            for e in union:
                w1, w3, w2 = self.cache.get((l, e))
                raw[e] = self._expert_raw(xn, w1, w3, w2)  # f32 [B, d]
            acc = self._shared(self._moe_dev(l), xn)
            if union:
                stacked = jnp.stack([raw[e] for e in union])  # [U, B, d]
                inv = np.zeros(self.E, np.int32)
                for u, e in enumerate(union):
                    inv[e] = u
                for j in range(self.k):
                    # j-th choice of every row, in that row's own top-k order
                    y = stacked[jnp.asarray(inv[ids_np[:, j]]), rows]
                    acc = acc + (y * w[:, j, None]).astype(acc.dtype)
            x = x + acc.reshape(x.shape)
            # prediction stream: prefetch layer l+1's experts for the batch
            for e in plan.prefetch_next:
                self.cache.prefetch((l + 1, e))
        logits = self._head(self.dev["ln_f"], self.dev["embed"], x[:, -1])
        lg_np = np.asarray(logits, np.float64)
        t_tok = time.perf_counter()
        for b, r in enumerate(batch):
            r.tokens.append(self._sample_req(r, lg_np[b]))
            r.trace.append(step_trace[b])
            r.pred.append(step_pred[b])
        self.queue.admission.model.observe_decode_step(t_tok - t0)
        self.decode_batch_hist.append(B)

    # -- scheduler loop -----------------------------------------------------
    def step(self, now: Optional[float] = None) -> bool:
        """One engine iteration: admit + prefill new arrivals, then one
        batched decode step for all in-flight requests. Returns True if any
        work was done."""
        now = time.perf_counter() if now is None else now
        admitted = self._admit_and_prefill(now)
        batch = [r for r in self.running if not r.done]
        if batch:
            self._decode_step(batch)
        did_work = bool(admitted or batch)
        self.step_count += 1
        # retire finished requests, free their slots
        still = []
        for r in self.running:
            if r.done:
                r.state = "done"
                r.t_done = time.perf_counter()
                self._slot_pos[r.slot, :] = -1
                self._free.append(r.slot)
                self.finished.append(r)
            else:
                still.append(r)
        self.running = still
        return did_work

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Drive step() until queue + running set are empty."""
        for _ in range(max_steps):
            self.step()
            if not self.running and not len(self.queue):
                break
        return self.finished
