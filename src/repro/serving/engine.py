"""DuoServe-MoE serving engine — the live runtime (paper §V).

Executes a MoE decoder layer-by-layer so the Python-level Expert Dispatcher
can interleave host->device expert transfers with dispatched computation:

  * prefill: per layer — attention dispatched, gate read back, tokens grouped
    by expert, then the policy's PrefillPlan drives the fetch/compute
    pipeline. With JAX async dispatch, issuing `device_put(expert e+1)` after
    dispatching `compute(expert e)` overlaps them (two-stream analogue).
    Prefill is also available incrementally (``prefill_chunk``): a
    token-budget chunk attends over the request's already-written KV prefix
    and appends its own K/V, carrying per-layer KV state across chunks —
    the unit of work the stall-free continuous-batching front-end
    (``serving/batching.py``) interleaves with batched decode. Chunked and
    monolithic prefill are bit-identical at any chunk size.
  * decode: per layer — gate result compared against prefetched experts
    (sync point #1); misses corrected with a blocking fetch; the ExpertMLP is
    dispatched on the "prediction stream" (async) to choose layer l+1's
    prefetch while layer l's experts compute.

Routed-expert weights live ONLY in the HostExpertStore (host RAM); the device
holds non-MoE weights + ONE ``ExpertResidency`` (core/cache.py) — a single
CacheState ledger fused with fixed slot-pool expert buffers, shared by
reference with the scheduling policy. Exactly one ledger exists per engine:
the scheduler's plan-time admits/evicts/unpins ARE the device slot
allocations/frees, so expert HBM is bounded by ``capacity *
bytes_per_expert`` at every step (no silently growing device dict), and the
jitted expert kernels read weights by slot index straight out of the pools.
The engine records routing traces + cache events; the simulator replays them
with hardware constants to produce the paper's latency/memory tables.

Sparse grouped expert execution (ROADMAP item 3): both phases can run each
layer's whole expert sweep as ONE launch instead of one launch per expert.
``group_by_expert`` builds the dispatch host-side from the already-synced
gate result: each distinct expert's selecting rows are gathered into a
``[U, C, d]`` block (C bucketed to powers of two so the jitted kernel sees
O(log B) shapes; padding rows repeat row 0 and are never read back), the
per-expert ``cache.slot`` host syncs collapse into one vectorized slot pass,
and the sweep runs as a single grouped einsum with numerics IDENTICAL per
row to the dense ``expert_raw`` (same dtypes, same contraction) — or, under
``REPRO_OPT_GROUPED_FFN``, as the Pallas ``expert_ffn_from_pool`` streaming
kernel straight off the residency pools. Accumulation-order contract: the
decode scatter-back walks j = 0..k-1 gathering every row's j-th choice from
its group, so each row accumulates in its OWN top-k order; the fused prefill
scatter-back adds per-expert contributions in PLAN order with gate weights
folded in (non-selecting tokens contribute exact zeros, as in the dense
path). Both disciplines are therefore bit-exact vs the per-expert loops at
temperature 0 (tests/test_serving_batch.py, tests/test_perf_opts.py).

The module is split into:

  * ``EngineCore`` — the shared execution substrate (host store, device
    residency split, jitted per-layer kernels, one scheduler + residency
    pair) plus the EVENT SINK every front-end shares: generated tokens are
    emitted as ``TokenEvent`` records (serving/api.py) and drained by the
    caller — ``MoEServingEngine.serve()`` assembles its RequestResult from
    the stream, ``BatchedServingEngine.step()`` returns it as StepEvents,
    and the ``ServingFrontend`` routes it to live RequestHandles. Kernels
    are written batch-agnostic: every decode-side op is row-wise
    deterministic, so a [B,1,d] batched step reproduces B independent
    [1,1,d] steps bit-exactly (the invariant the continuous-batching
    front-end in ``serving/batching.py`` is built on).
  * ``MoEServingEngine`` — the paper-scope single-request engine. Its
    ``serve()`` takes a ``SamplingParams`` (temperature, max_new_tokens,
    stop-token early termination, seed); the legacy ``max_new=`` kwarg is
    compat sugar.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cache import ExpertResidency, HostExpertStore
from repro.core.scheduler import (DuoServeScheduler,
                                  default_capacity, make_scheduler)
from repro.core.state import StateConstructor
from repro.core.tracer import ExpertsTracer, TraceStats
from repro.kernels.expert_ffn import expert_ffn_from_pool
from repro.kernels.ops import default_interpret
from repro.models import layers as L
from repro.models import moe_layer as M
from repro.models import opt_flags
from repro.models.layers import PDT
from repro.models.model import attn_dims
from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import SpanRecorder
from repro.serving.api import Event, SamplingParams, TokenEvent

_PERF_FIELDS = ("decode_rows_dense", "decode_rows_grouped",
                "decode_rows_launched", "decode_ffn_launches",
                "decode_layers", "prefill_ffn_launches",
                "prefill_moe_layers")
_PERF_MAX_FIELD = "max_prefill_launches_per_layer"


class PerfCounters:
    """Measured expert-execution work, filled by the serving engines.

    ``rows`` are (token, expert) FFN row evaluations — the unit expert-FLOP
    cost scales with (6 * d_model * d_expert FLOPs per row, see
    benchmarks/roofline.expert_flops_per_row). ``decode_rows_dense`` is what
    the dense full-batch discipline costs (U distinct experts x all B rows
    per layer — counted on BOTH paths, so a grouped engine reports the
    redundancy it removed); ``decode_rows_grouped`` counts only each
    expert's selecting rows (sum of per-expert group sizes);
    ``decode_rows_launched`` is what the engine's FFN launches actually
    computed (grouped: after Cmax bucketing, padding included; dense:
    U * B). ``*_ffn_launches`` count expert-FFN kernel dispatches — the
    fused prefill path must keep prefill_ffn_launches == prefill_moe_layers
    (exactly one launch per layer visit).

    Since the repro.obs migration this is a thin VIEW over the engine's
    :class:`MetricsRegistry`: every field reads a registry instrument
    (``engine_<field>_total`` counters; the max is a max-tracking gauge)
    and mutation goes through ``inc``/``max_update`` only — direct field
    writes raise here and are rejected statically by the
    ``obs-discipline`` lint (repro.analysis)."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        reg = registry if registry is not None else MetricsRegistry()
        object.__setattr__(self, "_c", {
            f: reg.counter(f"engine_{f}_total",
                           "expert-execution work (PerfCounters view)")
            for f in _PERF_FIELDS})
        object.__setattr__(self, "_gmax", reg.gauge(
            f"engine_{_PERF_MAX_FIELD}",
            "largest per-layer prefill FFN launch count seen"))

    def inc(self, field: str, n: int = 1) -> None:
        self._c[field].inc(n)

    def max_update(self, field: str, v: int) -> None:
        assert field == _PERF_MAX_FIELD, f"not a max-tracking field: {field}"
        self._gmax.max_update(v)

    def __getattr__(self, name: str):
        c = self.__dict__.get("_c", {})
        if name in c:
            return int(c[name].value)
        if name == _PERF_MAX_FIELD:
            return int(self.__dict__["_gmax"].value)
        raise AttributeError(name)

    def __setattr__(self, name: str, value) -> None:
        raise AttributeError(
            f"PerfCounters.{name} is a registry view — mutate via "
            f"inc()/max_update()")


def _bucket(n: int, cap: int) -> int:
    """Next power of two >= n, clamped to cap — the padded group capacity.
    Bucketing keeps the jitted grouped FFN at O(log cap) compiled shapes
    instead of one compilation per distinct max-group-size."""
    return min(1 << max(0, n - 1).bit_length(), cap)


@dataclasses.dataclass
class GroupedDispatch:
    """Host-side segment-gather plan for one layer's expert sweep."""
    row_idx: np.ndarray   # [U, C] int32 token index per expert (0-padded)
    counts: List[int]     # per-expert selecting-row counts (<= C each)
    u_of: np.ndarray      # [T, k] int32: group of each row's j-th choice
    c_of: np.ndarray      # [T, k] int32: row's position inside that group
    n_rows: int           # sum(counts) — real rows the sweep computes
    n_launched: int       # U * C — rows launched after bucketing


def group_by_expert(ids_np: np.ndarray, union: Sequence[int],
                    bucket_cap: int,
                    u_bucket_cap: Optional[int] = None) -> GroupedDispatch:
    """Build the capacity-grouped dispatch for a [T, k] selection matrix.

    ``union`` must cover every expert id appearing in ``ids_np`` (decode:
    plan.hits + plan.misses; prefill: plan.order) and fixes the group
    order. Rows are gathered per distinct expert in first-appearance order;
    ``u_of``/``c_of`` invert the gather so scatter-back can walk each row's
    own top-k choices (a row selecting the same expert under two choices
    maps both to the one gathered copy).

    ``u_bucket_cap`` additionally pads the GROUP dimension (distinct-expert
    count U) to a power of two clamped to the cap, the same discipline as
    the per-group capacity C: without it the jitted grouped sweep recompiles
    once per distinct U value. Padding groups are all-zero rows (they gather
    token 0, are computed, and are never scattered back — ``counts``,
    ``u_of`` and ``c_of`` only cover real groups, so bit-exactness is
    untouched). None keeps the exact U (callers that index groups
    positionally, e.g. the raw-kernel tests, rely on that)."""
    T, k = ids_np.shape
    einv = {int(e): u for u, e in enumerate(union)}
    groups: List[List[int]] = [[] for _ in union]
    u_of = np.zeros((T, k), np.int32)
    c_of = np.zeros((T, k), np.int32)
    pos: Dict[Tuple[int, int], int] = {}
    for t in range(T):
        for j in range(k):
            u = einv[int(ids_np[t, j])]
            c = pos.get((u, t))
            if c is None:
                g = groups[u]
                c = len(g)
                g.append(t)
                pos[(u, t)] = c
            u_of[t, j] = u
            c_of[t, j] = c
    counts = [len(g) for g in groups]
    C = _bucket(max(counts), bucket_cap) if counts else 1
    U_rows = max(len(union), 1)
    if u_bucket_cap is not None:
        U_rows = max(U_rows, _bucket(U_rows, u_bucket_cap))
    row_idx = np.zeros((U_rows, C), np.int32)
    for u, g in enumerate(groups):
        row_idx[u, : len(g)] = g
    return GroupedDispatch(row_idx=row_idx, counts=counts, u_of=u_of,
                           c_of=c_of, n_rows=sum(counts),
                           n_launched=int(row_idx.size))


@dataclasses.dataclass
class RequestResult:
    tokens: np.ndarray              # generated token ids [T]
    prefill_active: List[List[int]]  # union of experts per layer
    decode_trace: np.ndarray        # [T, L, k]
    pred_trace: np.ndarray          # [T, L, k] DuoServe predictions (-1 pad)
    ttft_wall: float
    e2e_wall: float
    hits: int
    misses: int
    finish_reason: str = "length"   # length | stop_token | cancelled


class EngineCore:
    """Shared serving substrate for dense-family MoE configs.

    Owns the host expert store, the device-resident non-expert weights, the
    jitted per-layer kernels, and one scheduler + device expert cache pair.
    Subclasses add a request-execution discipline on top (single-request
    serve() here; continuous batching in serving/batching.py).
    """

    def __init__(self, cfg: ArchConfig, params, policy: str = "duo", *,
                 stats: Optional[TraceStats] = None, predictor=None,
                 cache_capacity: Optional[int] = None,
                 temperature: float = 0.8, sample_seed: int = 0,
                 sched_batch: int = 1, prefill_chunk: Optional[int] = None,
                 fused_prefill: Optional[bool] = None,
                 spans: Union[bool, SpanRecorder] = False):
        assert cfg.is_moe and cfg.family in ("moe", "dense"), \
            "engine schedules experts; use bundle.decode for non-MoE archs"
        assert cfg.n_dense_layers == 0, "engine assumes uniform MoE stack"
        self.cfg = cfg
        self.L = cfg.n_layers
        self.E = cfg.n_experts
        self.k = cfg.top_k
        self.vp = L.vocab_pad_of(cfg.vocab)

        lp = params["layers"]
        self.store = HostExpertStore.from_params(lp["moe"], self.L, self.E)
        # device-resident: everything except routed expert weights
        moe_dev = {k: v for k, v in lp["moe"].items()
                   if k not in ("w1", "w3", "w2")}
        self.dev = {
            "embed": params["embed"], "ln_f": params["ln_f"],
            "layers": {k: v for k, v in lp.items() if k != "moe"},
            "moe": moe_dev,
        }
        self.temperature = temperature
        self.prefill_chunk_size = prefill_chunk
        # sparse grouped execution: fused_prefill=None defers to the
        # REPRO_OPT_GROUPED_FFN opt flag, which also selects the Pallas
        # pool-kernel backend for every grouped sweep (resolved once here)
        self.fused_prefill = (opt_flags.grouped_ffn() if fused_prefill
                              is None else bool(fused_prefill))
        self._grouped_pallas = opt_flags.grouped_ffn()
        # observability spine (repro.obs): ONE registry per engine is the
        # home of every number this engine tracks; the span recorder is off
        # by default (spans=True — or a pre-built SpanRecorder, e.g. with a
        # sampling rate — turns the lifecycle/phase timeline on)
        self.metrics = MetricsRegistry()
        self.obs = (spans if isinstance(spans, SpanRecorder)
                    else SpanRecorder(enabled=bool(spans)))
        self.perf = PerfCounters(self.metrics)
        self._rng = np.random.default_rng(sample_seed)
        # event sink: every generated token is emitted as a TokenEvent; the
        # front-ends (serve(), BatchedServingEngine.step()) assemble their
        # outputs from this stream rather than from side-channel state
        self._events: List[Event] = []
        sc = StateConstructor(stats) if stats is not None else None
        # ONE ledger per engine: the residency is built first, then the
        # scheduler shares it by reference (sched.cache IS self.cache).
        # Capacity covers the policy default AND the largest must-have
        # (pinned) set a single prefill plan can create — every expert the
        # chunk's tokens activate stays pinned until end_layer — so the
        # all-pinned growth branch (and a pool regrow) never fires and
        # expert HBM is a hard capacity*bytes_per_expert bound.
        pin_bound = self.E if prefill_chunk is None \
            else min(self.E, prefill_chunk * self.k)
        cap = cache_capacity or max(
            default_capacity(policy, self.L, self.E, self.k,
                             batch=sched_batch), pin_bound)
        self.cache = ExpertResidency(self.store, capacity=cap)
        # residency counts surface as PULL gauges — evaluated at snapshot
        # time off the one ledger, so the cache hot path stays untouched
        self.metrics.gauge("residency_hits", "expert-cache hits (lifetime)",
                           fn=lambda: self.cache.hits)
        self.metrics.gauge("residency_misses",
                           "expert-cache misses (lifetime)",
                           fn=lambda: self.cache.misses)
        self.metrics.gauge("residency_evictions",
                           "expert slots evicted (lifetime)",
                           fn=lambda: sum(1 for e in self.cache.events
                                          if e.kind == "evict"))
        self.metrics.gauge("residency_device_bytes",
                           "expert weight bytes resident in HBM",
                           fn=lambda: self.cache.device_bytes)
        self.sched = make_scheduler(
            policy, self.L, self.E, self.k, self.store.bytes_per_expert,
            stats=stats, predictor=predictor, state_constructor=sc,
            capacity=cap, batch=sched_batch, state=self.cache)
        assert self.sched.cache is self.cache, "ledger must be shared"
        self._jit_fns()

    # -- jitted per-layer kernels (compiled once; reused for every layer) ----
    def _jit_fns(self):
        cfg = self.cfg
        dims = attn_dims(cfg)
        eps = cfg.rms_eps

        @jax.jit
        def attn_prefill(lp, x):
            h, (k, v) = L.self_attn_full(L.rms_norm(x, lp["ln1"], eps),
                                         lp["attn"], dims)
            return x + h, k, v

        @jax.jit
        def attn_prefill_chunk(lp, x, ck, cv, sp, start):
            h, ck, cv, sp = L.self_attn_prefill_chunk(
                L.rms_norm(x, lp["ln1"], eps), lp["attn"], dims,
                ck, cv, sp, start)
            return x + h, ck, cv, sp

        @jax.jit
        def attn_decode(lp, x, ck, cv, sp, slot, pos):
            h, ck, cv = L.self_attn_decode(
                L.rms_norm(x, lp["ln1"], eps), lp["attn"], dims,
                ck, cv, sp, slot, pos)
            return x + h, ck, cv

        @jax.jit
        def attn_decode_batched(lp, x, ck, cv, sp, slot, pos):
            h, ck, cv = L.self_attn_decode_batched(
                L.rms_norm(x, lp["ln1"], eps), lp["attn"], dims,
                ck, cv, sp, slot, pos)
            return x + h, ck, cv

        @jax.jit
        def gate(moe_dev, lp, x):
            xn = L.rms_norm(x, lp["ln2"], eps)
            x2 = xn.reshape(-1, xn.shape[-1])
            w, ids, probs = M.route(x2, moe_dev["router"], self.E, self.k)
            return xn, w, ids

        @jax.jit
        def expert_raw(xn, w1p, w3p, w2p, slot):
            """Pre-gate expert output in f32: [T, d]. Weights are read by
            slot index out of the residency's fixed [capacity, ...] pools
            (the slot arrives as a traced jnp scalar, so one compilation
            serves every slot)."""
            x2 = xn.reshape(-1, xn.shape[-1])
            w1 = jax.lax.dynamic_index_in_dim(w1p, slot, keepdims=False)
            w3 = jax.lax.dynamic_index_in_dim(w3p, slot, keepdims=False)
            w2 = jax.lax.dynamic_index_in_dim(w2p, slot, keepdims=False)
            h = jax.nn.silu(x2 @ w1) * (x2 @ w3)
            return (h @ w2).astype(jnp.float32)

        @jax.jit
        def grouped_raw(xn, row_idx, w1p, w3p, w2p, slots):
            """Segment-gathered expert sweep in ONE launch: row_idx [U, C]
            indexes each expert's selecting rows into the flattened tokens
            (padding rows repeat row 0 — computed and never read back) and
            slots [U] reads each expert's slab out of the residency pools.
            Per-row numerics are IDENTICAL to expert_raw — same dtypes,
            same contraction order, f32 cast after the down-projection —
            so every gathered row is bit-equal to the dense full-batch
            output for that (row, expert)."""
            x2 = xn.reshape(-1, xn.shape[-1])
            xg = x2[row_idx]                        # [U, C, d]
            w1 = w1p[slots]
            w3 = w3p[slots]
            w2 = w2p[slots]
            h = jax.nn.silu(jnp.einsum("ucd,udf->ucf", xg, w1)) \
                * jnp.einsum("ucd,udf->ucf", xg, w3)
            return jnp.einsum("ucf,ufd->ucd", h, w2).astype(jnp.float32)

        @jax.jit
        def expert_apply(xn, w1p, w3p, w2p, slot, gate_w):
            return (expert_raw(xn, w1p, w3p, w2p, slot)
                    * gate_w[:, None]).astype(xn.dtype)

        @jax.jit
        def shared_apply(moe_dev, xn):
            if "sw1" not in moe_dev:
                return jnp.zeros_like(xn.reshape(-1, xn.shape[-1]))
            x2 = xn.reshape(-1, xn.shape[-1])
            h = jax.nn.silu(x2 @ moe_dev["sw1"]) * (x2 @ moe_dev["sw3"])
            return h @ moe_dev["sw2"]

        @jax.jit
        def head(p_lnf, embed, x_last):
            x = L.rms_norm(x_last, p_lnf, self.cfg.rms_eps)
            lg = x @ embed.T.astype(x.dtype)
            mask = jnp.arange(self.vp) < self.cfg.vocab
            return jnp.where(mask, lg.astype(jnp.float32), -1e9)

        self._attn_prefill = attn_prefill
        self._attn_prefill_chunk = attn_prefill_chunk
        self._attn_decode = attn_decode
        self._attn_decode_batched = attn_decode_batched
        self._gate = gate
        self._expert_raw = expert_raw
        self._grouped_raw = grouped_raw
        self._expert = expert_apply
        self._shared = shared_apply
        self._head = head

    def _layer(self, l: int):
        return jax.tree.map(lambda a: a[l], self.dev["layers"])

    def _moe_dev(self, l: int):
        return jax.tree.map(lambda a: a[l], self.dev["moe"])

    def _grouped_ffn_raw(self, l: int, union: Sequence[int], xn,
                         row_idx: np.ndarray):
        """ONE FFN launch for a whole layer's expert sweep, reading weights
        by slot out of the residency pools. The per-expert host syncs of
        the dense path collapse into one vectorized slot pass (single
        host walk over the union, single int32 transfer); pools are read
        AFTER the pass, so pending transfers' fresh array objects are
        picked up. Backend: the engine grouped einsum (bit-exact vs
        expert_raw) or, under REPRO_OPT_GROUPED_FFN, the Pallas
        ``expert_ffn_from_pool`` streaming kernel. Returns f32 [U, C, d]."""
        slots = np.fromiter((self.cache.slot((l, e)) for e in union),
                            np.int32, count=len(union))
        if row_idx.shape[0] > slots.size:
            # U-bucketed dispatch: padding groups read slab 0 (always a
            # valid slot) and their output is never scattered back
            slots = np.pad(slots, (0, row_idx.shape[0] - slots.size))
        jslots = jnp.asarray(slots)
        jrows = jnp.asarray(row_idx)
        if self._grouped_pallas:
            x2 = xn.reshape(-1, xn.shape[-1])
            out = expert_ffn_from_pool(x2[jrows], *self.cache.pools, jslots,
                                       interpret=default_interpret())
            return out.astype(jnp.float32)
        return self._grouped_raw(xn, jrows, *self.cache.pools, jslots)

    def _run_experts_prefill(self, l, xn, w, ids, plan, ids_np=None):
        """Execute the PrefillPlan: grouped per-expert compute with the
        policy's fetch schedule. The plan already admitted its fetches into
        the shared ledger (slots reserved); `prefetch` here issues the
        actual host->device copies between compute dispatches, preserving
        the two-stream overlap, and `slot` is the use-time sync point.
        With ``fused_prefill`` (and the gate's host-side ids available) the
        per-expert sweep collapses into ONE grouped FFN launch instead —
        same fetch schedule, same bits (see _run_experts_prefill_fused)."""
        acc = self._shared(self._moe_dev(l), xn)
        order = plan.order
        if order:
            self.perf.inc("prefill_moe_layers")
        if self.fused_prefill and order and ids_np is not None:
            return self._run_experts_prefill_fused(l, xn, w, ids, plan,
                                                   ids_np, acc)
        if order:
            self.perf.inc("prefill_ffn_launches", len(order))
            self.perf.max_update("max_prefill_launches_per_layer",
                                 len(order))
        # stage fetches according to the plan
        if plan.prefetch_all_first:
            for e in plan.fetches:
                self.cache.prefetch((l, e))
        elif plan.overlap_first and order:
            self.cache.prefetch((l, order[0]))
        for i, e in enumerate(order):
            if not plan.prefetch_all_first:
                if plan.pipelined and i + 1 < len(order):
                    # comm stream: next expert streams while e computes
                    self.cache.prefetch((l, order[i + 1]))
                elif not plan.pipelined:
                    self.cache.prefetch((l, e))
            eslot = jnp.int32(self.cache.slot((l, e)))
            gate_w = (w * (ids == e)).sum(-1).reshape(-1)
            acc = acc + self._expert(xn, *self.cache.pools, eslot, gate_w)
        return acc.reshape(xn.shape)

    def _run_experts_prefill_fused(self, l, xn, w, ids, plan, ids_np, acc):
        """Fused PrefillPlan execution: the per-expert sweep is ONE grouped
        FFN launch off the residency pools. The plan's fetch schedule is
        preserved verbatim — the same `prefetch` calls are issued in the
        same order (all ahead of the single launch, the degenerate form of
        "between compute dispatches"), then one vectorized slot pass is the
        use-time sync point. Gate weights are folded in on scatter-back,
        one expert at a time IN PLAN ORDER, so the accumulation order — and
        with it every output bit — matches the unfused loop (non-selecting
        tokens contribute exact zeros on both paths)."""
        order = plan.order
        if plan.prefetch_all_first:
            for e in plan.fetches:
                self.cache.prefetch((l, e))
        elif plan.overlap_first:
            self.cache.prefetch((l, order[0]))
        for i, e in enumerate(order):
            if not plan.prefetch_all_first:
                if plan.pipelined and i + 1 < len(order):
                    self.cache.prefetch((l, order[i + 1]))
                elif not plan.pipelined:
                    self.cache.prefetch((l, e))
        T = ids_np.shape[0]
        disp = group_by_expert(ids_np, order, bucket_cap=T,
                               u_bucket_cap=min(self.E, T * self.k))
        raw = self._grouped_ffn_raw(l, order, xn, disp.row_idx)  # [U, C, d]
        self.perf.inc("prefill_ffn_launches")
        self.perf.max_update("max_prefill_launches_per_layer", 1)
        zeros = jnp.zeros((T, raw.shape[-1]), jnp.float32)
        for u, e in enumerate(order):
            gate_w = (w * (ids == e)).sum(-1).reshape(-1)
            n = disp.counts[u]
            if n:
                rows = jnp.asarray(disp.row_idx[u, :n])
                y = zeros.at[rows].set(raw[u, :n])
            else:
                y = zeros
            acc = acc + (y * gate_w[:, None]).astype(acc.dtype)
        return acc.reshape(xn.shape)

    def _prefill_moe(self, l: int, lp, x):
        """Shared per-layer MoE body of both prefill paths: gate, dispatch
        the policy's PrefillPlan, add the expert output, unpin the layer.
        Returns (x_out, per-token ids [T, k] np, sorted active experts)."""
        xn, w, ids = self._gate(self._moe_dev(l), lp, x)
        ids_np = np.asarray(ids)  # sync: gate result needed by dispatcher
        act = sorted(set(int(e) for e in ids_np.ravel()))
        plan = self.sched.prefill_plan(l, act)
        y = self._run_experts_prefill(l, xn, w, ids, plan,
                                      ids_np=ids_np.reshape(-1, self.k))
        x = x + y
        self.sched.end_layer(l)
        return x, ids_np.reshape(-1, self.k), act

    def prefill_chunk(self, chunk: np.ndarray, start: int,
                      kc: List[jax.Array], vc: List[jax.Array],
                      sp: jax.Array, *, need_logits: bool = True):
        """Run ONE prefill chunk [1, C] through all layers incrementally.

        The unit of prefill work for chunked/stall-free serving: the chunk's
        queries attend over the KV prefix written by earlier chunks (slots
        0..start-1 of the per-layer buffers kc/vc, [1, W, Hkv, hd]) plus
        themselves, and append their K/V at slots start..start+C-1. Expert
        scheduling goes through the SAME per-layer `prefill_plan` path as
        monolithic prefill, so the policy's fetch pipeline and cache ledger
        see each chunk as a (smaller) prefill.

        Returns (logits [1, Vp] of the chunk's last position — or None when
        need_logits=False — kc, vc, sp, active_per_layer for this chunk,
        per-token paths [C, L, k]).
        """
        x = self.dev["embed"].at[jnp.asarray(chunk)].get(mode="clip")
        C = chunk.shape[1]
        start_j = jnp.int32(start)
        active: List[List[int]] = []
        paths = np.zeros((C, self.L, self.k), np.int32)
        for l in range(self.L):
            lp = self._layer(l)
            x, kc[l], vc[l], sp = self._attn_prefill_chunk(
                lp, x, kc[l], vc[l], sp, start_j)
            x, ids_np, act = self._prefill_moe(l, lp, x)
            paths[:, l] = ids_np
            active.append(act)
        logits = (self._head(self.dev["ln_f"], self.dev["embed"], x[:, -1])
                  if need_logits else None)
        return logits, kc, vc, sp, active, paths

    def prefill_layers(self, tokens: np.ndarray,
                       chunk_size: Optional[int] = None):
        """Run the layer-by-layer prefill pipeline on tokens [1, S].

        chunk_size (default: the engine's `prefill_chunk_size`): None runs
        the
        whole prompt monolithically via `self_attn_full`; an int >= 1 runs
        it as a sequence of `prefill_chunk` calls over token-budget chunks.
        Both paths produce bit-identical results (tests/test_serving_batch).

        Returns (last_logits [1, Vp], (kc, vc), active_per_layer,
        per-token paths [S, L, k]). Sampling is left to the caller so both
        the single-request and the batched front-end can share this path.
        """
        if chunk_size is None:
            chunk_size = self.prefill_chunk_size
        S = tokens.shape[1]
        if chunk_size is not None:
            # always the incremental path when a chunk size is set — with
            # chunk_size >= S that is one whole-prompt chunk, so the
            # prefill_chunk kernel itself is exercised at every size
            return self._prefill_layers_chunked(tokens, chunk_size)
        x = self.dev["embed"].at[jnp.asarray(tokens)].get(mode="clip")
        kc, vc = [], []
        active: List[List[int]] = []
        paths = np.zeros((S, self.L, self.k), np.int32)
        for l in range(self.L):
            lp = self._layer(l)
            x, k_, v_ = self._attn_prefill(lp, x)
            x, ids_np, act = self._prefill_moe(l, lp, x)
            paths[:, l] = ids_np
            kc.append(k_)
            vc.append(v_)
            active.append(act)
        logits = self._head(self.dev["ln_f"], self.dev["embed"], x[:, -1])
        return logits, (kc, vc), active, paths

    def _prefill_layers_chunked(self, tokens: np.ndarray, chunk_size: int):
        """Chunked drop-in for `prefill_layers`: same return contract, the
        prompt processed `chunk_size` tokens at a time through
        `prefill_chunk` (per-layer KV buffers sized to the prompt)."""
        assert chunk_size >= 1
        S = tokens.shape[1]
        hkv, hd = self.cfg.n_kv_heads, self.cfg.hd
        kc = [jnp.zeros((1, S, hkv, hd), PDT) for _ in range(self.L)]
        vc = [jnp.zeros_like(kc[l]) for l in range(self.L)]
        sp = jnp.full((1, S), -1, jnp.int32)
        active_sets = [set() for _ in range(self.L)]
        paths = np.zeros((S, self.L, self.k), np.int32)
        logits = None
        for start in range(0, S, chunk_size):
            stop = min(start + chunk_size, S)
            logits, kc, vc, sp, act, cpaths = self.prefill_chunk(
                tokens[:, start:stop], start, kc, vc, sp,
                need_logits=(stop == S))
            paths[start:stop] = cpaths
            for l in range(self.L):
                active_sets[l].update(act[l])
        active = [sorted(s) for s in active_sets]
        return logits, (kc, vc), active, paths

    # -- event stream --------------------------------------------------------
    def _emit(self, ev: Event) -> None:
        self._events.append(ev)

    def drain_events(self) -> List[Event]:
        """Take (and clear) every event emitted since the last drain.
        `BatchedServingEngine.step()` drains into its StepEvents return;
        `MoEServingEngine.serve()` drains to assemble its RequestResult;
        the ServingFrontend drains at cancellation sites."""
        evs, self._events = self._events, []
        return evs

    def _sample(self, logits) -> int:
        return self.sample_row(np.asarray(logits, np.float64)[0],
                               self.temperature, self._rng)

    @staticmethod
    def sample_row(lg: np.ndarray, temperature: float, rng) -> int:
        """Sample one token id from a f64 logits row (greedy at temp<=0)."""
        if temperature <= 0:
            return int(lg.argmax())
        lg = lg / temperature
        lg = lg - lg.max()
        p = np.exp(lg)
        p /= p.sum()
        return int(rng.choice(len(p), p=p))


class MoEServingEngine(EngineCore):
    """Single-request engine (paper scope): one prompt at a time, KV cache
    private to the request, decode loop runs the full dual-phase schedule.
    Tokens flow through the EngineCore event sink: ``decode`` emits a
    TokenEvent per step and ``serve`` assembles its RequestResult from the
    drained stream (the same records the batched front-end emits)."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._serve_rid = 0   # event-stream rid per serve() call

    def prefill(self, tokens: np.ndarray):
        """tokens: [1, S]. Returns (next_token, kv_caches, active_per_layer,
        per-token paths [S, L, k])."""
        logits, kv, active, paths = self.prefill_layers(tokens)
        return self._sample(logits), kv, active, paths

    def decode(self, first_token: int, kv, prompt_len: int, max_new: int, *,
               stop_ids: Sequence[int] = (), rid: int = 0,
               temperature: Optional[float] = None, rng=None):
        """Decode up to `max_new` tokens after `first_token`, emitting a
        TokenEvent per token; a token in `stop_ids` terminates the loop
        early (the stop token itself is still emitted). Returns
        (tokens [T<=max_new], trace [T, L, k], pred_trace [T, L, k])."""
        temp = self.temperature if temperature is None else temperature
        rng = self._rng if rng is None else rng
        kc, vc = kv
        cap = prompt_len + max_new + 1
        Wpad = cap
        kc = [jnp.pad(k, ((0, 0), (0, Wpad - k.shape[1]), (0, 0), (0, 0)))
              for k in kc]
        vc = [jnp.pad(v, ((0, 0), (0, Wpad - v.shape[1]), (0, 0), (0, 0)))
              for v in vc]
        sp = jnp.pad(jnp.arange(prompt_len, dtype=jnp.int32),
                     (0, Wpad - prompt_len), constant_values=-1)
        out = [first_token]
        trace = np.zeros((max_new, self.L, self.k), np.int32)
        pred_trace = np.full((max_new, self.L, self.k), -1, np.int32)
        n_dec = 0
        for t in range(max_new):
            st = self.obs.begin("decode.step", lane="decode", rid=rid)
            tok = jnp.asarray([[out[-1]]], jnp.int32)
            x = self.dev["embed"].at[tok].get(mode="clip")
            pos = jnp.int32(prompt_len + t)
            slot = int(prompt_len + t) % Wpad
            sp = sp.at[slot].set(prompt_len + t)
            if isinstance(self.sched, DuoServeScheduler):
                self.sched.begin_decode_step()
            for l in range(self.L):
                lp = self._layer(l)
                x, kc[l], vc[l] = self._attn_decode(lp, x, kc[l], vc[l], sp,
                                                    slot, pos)
                xn, w, ids = self._gate(self._moe_dev(l), lp, x)
                sel = [int(e) for e in np.asarray(ids).ravel()[: self.k]]
                trace[t, l] = sel
                plan = self.sched.decode_plan(l, sel)
                np_pred = plan.predicted[: self.k]
                pred_trace[t, l, : len(np_pred)] = np_pred
                # correction fetches for misses (sync point #1)
                if plan.misses:
                    pt = self.obs.begin("prefetch.correction",
                                        lane="prefetch", rid=rid, layer=l,
                                        n=len(plan.misses))
                    for e in plan.misses:
                        self.cache.prefetch((l, e))
                        self.cache.wait((l, e))
                    self.obs.end(pt)
                acc = self._shared(self._moe_dev(l), xn)
                for e in sel:
                    eslot = jnp.int32(self.cache.slot((l, e)))
                    gate_w = (w * (ids == e)).sum(-1).reshape(-1)
                    acc = acc + self._expert(xn, *self.cache.pools, eslot,
                                             gate_w)
                x = x + acc.reshape(x.shape)
                # prediction stream: prefetch next layer's predicted experts
                if plan.prefetch_next:
                    self.obs.instant("prefetch.dispatch", lane="prefetch",
                                     rid=rid, layer=l,
                                     n=len(plan.prefetch_next))
                for e in plan.prefetch_next:
                    self.cache.prefetch((l + 1, e))
            # the policies end_layer(l) when planning l+1; the LAST layer has
            # no successor, so unpin it here or its pins outlive the step and
            # accumulate until the ledger's all-pinned growth branch fires
            self.sched.end_layer(self.L - 1)
            logits = self._head(self.dev["ln_f"], self.dev["embed"], x[:, -1])
            tok = self.sample_row(np.asarray(logits, np.float64)[0], temp,
                                  rng)
            out.append(tok)
            n_dec = t + 1
            self._emit_token(rid, tok, n_dec)
            self.obs.end(st, token_id=tok)
            if tok in stop_ids:
                break
        return (np.asarray(out[1:]), trace[:n_dec], pred_trace[:n_dec])

    def _emit_token(self, rid: int, token: int, index: int, *,
                    first: bool = False) -> None:
        """The single-request engine's token sink (mirror of
        BatchedServingEngine._emit_token): every streamed token funnels
        through one place so cancellation/TBT accounting — and the
        emit-discipline lint — hold engine-wide."""
        self._emit(TokenEvent(rid=rid, token=token, index=index,
                              t=time.perf_counter(), first=first))

    def serve(self, prompt: np.ndarray, max_new: int = 16, *,
              params: Optional[SamplingParams] = None) -> RequestResult:
        """Serve one prompt end to end — a thin wrapper over the event
        stream: prefill and decode emit TokenEvents through the engine
        sink, and the returned RequestResult's token array is assembled
        from the drained stream. Legacy `max_new=` is compat sugar for
        `params=SamplingParams(max_new_tokens=...)` (which also carries
        temperature, stop_token_ids, and seed)."""
        if params is None:
            params = SamplingParams(max_new_tokens=max_new)
        temp = (self.temperature if params.temperature is None
                else params.temperature)
        rng = (np.random.default_rng(params.seed)
               if params.seed is not None else self._rng)
        rid = self._serve_rid
        self._serve_rid += 1
        self.sched.begin_request()
        h0, m0 = self.sched.cache.hits, self.sched.cache.misses
        self.drain_events()
        t0 = time.perf_counter()
        prompt = np.asarray(prompt, np.int32).reshape(1, -1)
        logits, kv, active, _ = self.prefill_layers(prompt)
        first = self.sample_row(np.asarray(logits, np.float64)[0], temp, rng)
        t1 = time.perf_counter()
        self._emit_token(rid, first, 0, first=True)
        if first in params.stop_token_ids:
            trace = np.zeros((0, self.L, self.k), np.int32)
            pred = np.full((0, self.L, self.k), -1, np.int32)
        else:
            _, trace, pred = self.decode(
                first, kv, prompt.shape[1], params.max_new_tokens,
                stop_ids=params.stop_token_ids, rid=rid,
                temperature=temp, rng=rng)
        t2 = time.perf_counter()
        events = self.drain_events()
        tokens = np.asarray([e.token for e in events
                             if isinstance(e, TokenEvent)], np.int64)
        reason = ("stop_token" if params.stop_token_ids and tokens.size
                  and int(tokens[-1]) in params.stop_token_ids else "length")
        # no FinishEvent here: serve() is synchronous, so completion is the
        # return itself (finish_reason below) — an emitted event could never
        # be observed before this same method drained it
        return RequestResult(
            tokens=tokens,
            prefill_active=active, decode_trace=trace, pred_trace=pred,
            ttft_wall=t1 - t0, e2e_wall=t2 - t0,
            hits=self.sched.cache.hits - h0,
            misses=self.sched.cache.misses - m0,
            finish_reason=reason)


def collect_traces(cfg: ArchConfig, params, prompts: Sequence[np.ndarray],
                   max_new: int = 8) -> Tuple[ExpertsTracer, List[RequestResult]]:
    """Offline preprocess (paper §IV-A): run an ODF-scheduled engine over a
    small dataset slice and record per-token activation paths."""
    engine = MoEServingEngine(cfg, params, policy="odf")
    tracer = ExpertsTracer(cfg.n_layers, cfg.n_experts, cfg.top_k)
    results = []
    for p in prompts:
        r = engine.serve(p, max_new=max_new)
        results.append(r)
        tracer.add_paths(r.decode_trace)
    return tracer, results
