"""Typed serving API: request specs, sampling params, and the event stream.

This module is the public vocabulary of the serving front-end
(``serving/frontend.py``) and the continuous-batching engine
(``serving/batching.py``):

  * ``SamplingParams`` — frozen per-request sampling spec (temperature,
    max_new_tokens, stop_token_ids, seed). Replaces the scattered
    ``temperature=`` / ``max_new=`` kwargs the engines used to take.
  * ``GenerationRequest`` — one request as the caller describes it: prompt,
    sampling params, QoS targets (ttft_slo, tbt_slo), scheduling priority,
    and arrival time. The engine turns this into its internal runtime
    ``Request`` record at submission.
  * ``TokenEvent`` / ``FinishEvent`` / ``RejectEvent`` — the per-step event
    stream ``BatchedServingEngine.step()`` emits instead of mutating token
    lists as its only output. ``StepEvents`` is one step's batch of events
    plus a ``did_work`` flag (admission / prefill-chunk work can be real
    work that emits no token yet).

Nothing here imports the engines, so the spec types are importable from
anywhere (benchmarks, examples, tests) without pulling in jax state.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Frozen per-request sampling specification.

    temperature: None = use the engine's default temperature; <= 0 = greedy.
    max_new_tokens: decode steps after the first token — a request emits at
        most ``max_new_tokens + 1`` tokens total (first token included),
        matching the engines' historical ``max_new`` semantics.
    stop_token_ids: early-termination set — the stop token itself is still
        emitted (so streams stay bit-comparable to un-stopped runs up to and
        including the stop position), then the request finishes with reason
        ``"stop_token"``.
    seed: per-request sampling seed; None derives one from the engine seed
        and the request id (deterministic per submission order).
    """
    temperature: Optional[float] = None
    max_new_tokens: int = 16
    stop_token_ids: Tuple[int, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        # normalize any iterable of stop ids into a hashable int tuple
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        assert self.max_new_tokens >= 0, "max_new_tokens must be >= 0"


@dataclasses.dataclass(frozen=True, eq=False)
class GenerationRequest:
    """One serving request as the caller specifies it (spec, not state).

    prompt: [S] int32 token ids.
    params: sampling spec (see SamplingParams).
    ttft_slo: deadline (seconds, arrival -> first token) for SLO-aware
        admission; None = no deadline.
    tbt_slo: per-request inter-token-gap target (seconds). Admission rejects
        requests whose steady-state gap is structurally unmeetable, and the
        engine's ``prefill_budget="auto"`` tightens its chunk to the minimum
        tbt_slo across in-flight requests.
    priority: higher = served first; ``RequestQueue.pop_admissible`` orders
        candidates by (priority desc, arrival order) — stable, so equal
        priorities keep FIFO.
    arrival: wall-clock arrival time (time.perf_counter domain); None =
        stamped at submission.
    """
    prompt: np.ndarray
    params: SamplingParams = SamplingParams()
    ttft_slo: Optional[float] = None
    tbt_slo: Optional[float] = None
    priority: int = 0
    arrival: Optional[float] = None


def as_request_spec(spec, **kw) -> GenerationRequest:
    """Normalize a front-end ``submit()`` input: pass a GenerationRequest
    through untouched (field kwargs are then disallowed), or build one from
    a raw prompt array plus GenerationRequest fields. Shared by
    ServingFrontend and ClusterFrontend so the two surfaces cannot drift."""
    if isinstance(spec, GenerationRequest):
        assert not kw, ("kwargs are ignored when a full GenerationRequest "
                        "is passed — set the fields on the spec instead")
        return spec
    return GenerationRequest(
        prompt=np.asarray(spec, np.int32).reshape(-1), **kw)


# ---------------------------------------------------------------------------
# request snapshot (pause / handoff / migration primitive)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestSnapshot:
    """A paused request, portable across engines
    (``BatchedServingEngine.snapshot(rid)`` / ``restore(snapshot)``).

    Captures everything needed to resume the request bit-exactly on ANY
    engine whose per-slot KV capacity fits it: the immutable spec, the
    tokens generated so far, the per-layer KV prefix (gathered host-side —
    dense, row p = position p, so ring positions rebuild as ``arange``),
    mid-prefill progress, the per-request decode traces/counters, the
    sampling rng state (carried, never re-derived — a re-derived stream
    would break bit-exactness for temperature > 0), and the TBT-ledger gap
    history (re-seeded via ``TBTLedger.reopen`` so paused wall time is
    never charged as an inter-token gap).

    state is the LOGICAL resume point, not the verbatim source state:
    ``queued`` (never started — re-enqueues without a KV slot),
    ``prefilling`` (mid-prefill, ``prefill_pos`` prompt tokens of KV
    captured), or ``running`` (prefill complete — a ``held`` request on a
    prefill-role replica snapshots as ``running`` and a decode-capable
    engine resumes it straight into its batch).

    Consumers: QosAutopilot preemption (pause low-priority, resume on
    headroom), disaggregated prefill->decode handoff, and replica draining
    (serving/cluster.py). While a snapshot exists its KV lives HOST-side —
    ``kv_bytes`` is what memory accounting should charge there.
    """
    spec: GenerationRequest
    state: str                       # queued | prefilling | running
    tokens: List[int]
    kv_k: List[np.ndarray]           # per layer [P, n_kv_heads, hd]
    kv_v: List[np.ndarray]
    prefill_pos: int
    active_sets: Optional[List[List[int]]]  # accumulating expert sets
    prefill_active: List[List[int]]
    trace: List[np.ndarray]
    pred: List[np.ndarray]
    hits: int
    misses: int
    t_start: float
    t_first: float
    tbt_gaps: List[float]
    rng_state: Optional[dict]
    source_rid: int
    t_snapshot: float
    # tail-only handoff (cross-request prefix reuse, core/prefix.py): the
    # dense kv_k/kv_v arrays cover positions [kv_start, P) — the shared
    # head [0, kv_start) is NOT shipped, the restoring engine rebuilds it
    # from its own PrefixTree (restore asserts the head is present; 0 =
    # the full-prefix snapshot every pre-existing consumer produces)
    kv_start: int = 0

    @property
    def kv_bytes(self) -> int:
        """Host bytes the captured KV prefix occupies while paused (a
        tail-only snapshot only counts the rows it actually carries)."""
        return sum(a.nbytes for a in self.kv_k) + \
            sum(a.nbytes for a in self.kv_v)

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


# ---------------------------------------------------------------------------
# event stream
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """Request `rid` emitted generated token `token` (its `index`-th) at
    wall time `t`; `first` marks the TTFT token."""
    rid: int
    token: int
    index: int
    t: float
    first: bool = False


@dataclasses.dataclass(frozen=True)
class FinishEvent:
    """Request `rid` left the engine: reason is ``"length"`` (max_new_tokens
    reached), ``"stop_token"``, ``"cancelled"`` (caller-initiated), or
    ``"slo_shed"`` (QosAutopilot shed a request whose TTFT/TBT deadline was
    already unmeetable mid-flight). After a FinishEvent the engine emits no
    further events for `rid` — ever."""
    rid: int
    reason: str
    n_tokens: int
    t: float


@dataclasses.dataclass(frozen=True)
class RejectEvent:
    """Request `rid` was shed before it ran: reason ``"slo"`` (engine
    admission predicted an SLO breach) or ``"router_slo"`` (the cluster's
    slo_headroom router found NO replica able to meet its deadlines)."""
    rid: int
    reason: str
    t: float


Event = Union[TokenEvent, FinishEvent, RejectEvent]


class StepEvents(list):
    """One ``step()``'s events, in emission order, plus ``did_work``.

    A list subclass so existing consumers can iterate/len it directly;
    ``did_work`` is True when the step admitted, prefilled, or decoded
    anything — prefill-chunk work is real work that may emit no event, so
    idle detection must use ``did_work`` (or the engine's ``idle``
    property), not truthiness of the list.
    """

    def __init__(self, events: Iterable[Event] = (), did_work: bool = False):
        super().__init__(events)
        self.did_work = did_work

    def for_rid(self, rid: int) -> "StepEvents":
        return StepEvents([e for e in self if e.rid == rid], self.did_work)
