"""DuoServe-MoE serving runtime: spec -> handle -> events -> cluster.

The public serving surface, top down:

  * ``cluster`` — the multi-replica tier (LLM-as-a-Service scope):
    ``ReplicaPool`` holds N independent ``BatchedServingEngine`` replicas
    (each with its own KV slots, queue, scheduler, and ExpertResidency)
    behind a pluggable ``Router`` — ``round_robin`` / ``least_loaded`` /
    ``slo_headroom`` (max SLO margin, reject only if NO replica can meet
    the deadlines) / ``expert_affinity`` (overlap between the request's
    likely-expert set and each replica's live residency) / ``disagg``
    (prefill/decode phase disaggregation: per-replica role overrides, new
    requests to prefill replicas, finished-prefill KV snapshots handed to
    the decode replica with the best per-request expert affinity) /
    ``prefix_affinity`` (overload-gated longest-cached-prefix routing:
    each replica scored by ``BatchedServingEngine.prefix_score`` — its
    radix ``PrefixTree`` contents PLUS the prompts of live requests, so a
    burst of same-template arrivals co-locates on one replica).
    ``ClusterFrontend`` keeps the exact single-engine surface below, and
    ``QosAutopilot`` (attachable to either front-end) sheds requests whose
    TTFT/TBT deadline is already unmeetable mid-flight
    (``FinishEvent(reason="slo_shed")``, resources reclaimed
    synchronously) and, with ``preempt=True``, pauses/resumes
    low-priority requests host-side instead of killing them.
    ``ReplicaPool.drain(i)`` migrates a replica's in-flight requests to
    the survivors (elasticity), all via the one snapshot primitive below.
  * ``RequestSnapshot`` (``api``) + ``BatchedServingEngine.snapshot`` /
    ``restore`` — the request-level pause/handoff/migration primitive: KV
    prefix gathered host-side, engine resources released like a cancel,
    resume is bit-exact on any engine that fits the request (frontends'
    ``pause``/``resume`` rebind the live ``RequestHandle`` across hops).
    With prefix caching on the destination, ``ReplicaPool.migrate`` ships
    only the KV *tail* past the receiver's longest cached prefix
    (``snapshot(req, kv_start=head)``; ``restore`` reseeds the head from
    the destination's own cache — still bit-exact, bytes-on-the-wire
    accounted in ``handoff_bytes_saved``).
  * ``core.prefix.PrefixTree`` + ``BatchedServingEngine(prefix_cache=
    True)`` — cross-request prefix/KV reuse: retired slots are retained
    as a token-level radix tree over the slot-pool KV rows; admission
    copies the longest cached prefix into the new request's carry
    buffers and prefills only the un-hit suffix (admission charges only
    that suffix), with LRU whole-slot eviction reclaiming tree-owned
    slots on demand. Reused prefixes are bit-exact vs a cold prefill at
    temperature 0 (tests/test_prefix.py).
  * ``api`` — the typed vocabulary: ``SamplingParams`` (frozen sampling
    spec: temperature, max_new_tokens, stop_token_ids, seed),
    ``GenerationRequest`` (prompt + params + ttft_slo/tbt_slo QoS targets +
    priority + arrival), and the event records ``TokenEvent`` /
    ``FinishEvent`` / ``RejectEvent`` grouped per step as ``StepEvents``.
  * ``frontend.ServingFrontend`` — the streaming request-handle front-end:
    ``submit(GenerationRequest) -> RequestHandle``; each cooperative
    ``poll()`` runs one engine step and routes its events; a handle is an
    iterator yielding tokens as they land, with ``.status``, ``.result()``
    and mid-flight ``.cancel()`` (KV slot, expert-residency contributions,
    and TBT-ledger entry reclaimed synchronously).
  * ``batching.BatchedServingEngine`` — the continuous-batching engine the
    frontend drives: SLO-aware priority admission (``RequestQueue``),
    chunked stall-free prefill (fairness: rr / srf / fifo), one batched
    decode step per iteration, per-layer expert selections unioned into
    ONE shared scheduler/ExpertResidency ledger (expert HBM bounded by
    ``capacity * bytes_per_expert`` at every step). ``step()`` emits the
    event stream; ``run_until_drained()`` is a thin compat wrapper.
  * ``engine.MoEServingEngine`` — the paper-scope single-request engine;
    its ``serve()`` is likewise a thin wrapper assembling a
    ``RequestResult`` from the same event records.

Determinism contract: at temperature 0 every front-end — handle streams
under ANY poll() schedule, ``run_until_drained()``, single-request
``serve()``, and a ClusterFrontend of ANY replica count under any router —
yields bit-identical tokens for the same prompt, including chunked
prefill, mid-flight admission, and batches shrunk by cancellation
(tests/test_serving_batch.py, tests/test_frontend.py,
tests/test_cluster.py).
"""
from repro.serving.api import (Event, FinishEvent,  # noqa: F401
                               GenerationRequest, RejectEvent,
                               RequestSnapshot, SamplingParams, StepEvents,
                               TokenEvent)
from repro.serving.cluster import (ClusterFrontend, DisaggRouter,  # noqa: F401
                                   QosAutopilot, ReplicaPool, Router,
                                   ROUTERS, make_router)
from repro.serving.engine import (EngineCore, MoEServingEngine,  # noqa: F401
                                  RequestResult, collect_traces)
from repro.serving.frontend import (RequestHandle,  # noqa: F401
                                    ServingFrontend)
