"""DuoServe-MoE serving runtime: spec -> handle -> events.

The public serving surface, top down:

  * ``api`` — the typed vocabulary: ``SamplingParams`` (frozen sampling
    spec: temperature, max_new_tokens, stop_token_ids, seed),
    ``GenerationRequest`` (prompt + params + ttft_slo/tbt_slo QoS targets +
    priority + arrival), and the event records ``TokenEvent`` /
    ``FinishEvent`` / ``RejectEvent`` grouped per step as ``StepEvents``.
  * ``frontend.ServingFrontend`` — the streaming request-handle front-end:
    ``submit(GenerationRequest) -> RequestHandle``; each cooperative
    ``poll()`` runs one engine step and routes its events; a handle is an
    iterator yielding tokens as they land, with ``.status``, ``.result()``
    and mid-flight ``.cancel()`` (KV slot, expert-residency contributions,
    and TBT-ledger entry reclaimed synchronously).
  * ``batching.BatchedServingEngine`` — the continuous-batching engine the
    frontend drives: SLO-aware priority admission (``RequestQueue``),
    chunked stall-free prefill (fairness: rr / srf / fifo), one batched
    decode step per iteration, per-layer expert selections unioned into
    ONE shared scheduler/ExpertResidency ledger (expert HBM bounded by
    ``capacity * bytes_per_expert`` at every step). ``step()`` emits the
    event stream; ``run_until_drained()`` is a thin compat wrapper.
  * ``engine.MoEServingEngine`` — the paper-scope single-request engine;
    its ``serve()`` is likewise a thin wrapper assembling a
    ``RequestResult`` from the same event records.

Determinism contract: at temperature 0 every front-end — handle streams
under ANY poll() schedule, ``run_until_drained()``, single-request
``serve()`` — yields bit-identical tokens for the same prompt, including
chunked prefill, mid-flight admission, and batches shrunk by cancellation
(tests/test_serving_batch.py, tests/test_frontend.py).
"""
from repro.serving.api import (Event, FinishEvent,  # noqa: F401
                               GenerationRequest, RejectEvent,
                               SamplingParams, StepEvents, TokenEvent)
from repro.serving.engine import (EngineCore, MoEServingEngine,  # noqa: F401
                                  RequestResult, collect_traces)
from repro.serving.frontend import (RequestHandle,  # noqa: F401
                                    ServingFrontend)
