"""DuoServe-MoE serving runtime.

Two front-ends over one execution substrate:

  * ``engine.MoEServingEngine`` — the paper-scope single-request engine
    (layer-by-layer prefill/decode with the dual-phase expert scheduler).
  * ``batching.BatchedServingEngine`` — continuous batching for concurrent
    load: an SLO-aware ``RequestQueue`` admits requests mid-flight, prefill
    for new arrivals interleaves with one batched decode step per iteration,
    KV lives in a slot pool with per-request write positions, and each
    step's per-layer expert selections are unioned across the batch before
    they reach the ONE shared scheduler/ExpertResidency ledger (decode-plan
    union semantics: one fetch per distinct expert per step, hit/miss
    accounting over distinct experts). Expert weights live in the
    residency's fixed slot-pool device buffers — expert HBM is bounded by
    ``capacity * bytes_per_expert`` at every step.

Both produce ``RequestResult`` records; at temperature 0 they emit identical
tokens for the same prompt (batched decode is bit-exact per row).
"""
from repro.serving.engine import (EngineCore, MoEServingEngine,  # noqa: F401
                                  RequestResult, collect_traces)
