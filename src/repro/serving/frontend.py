"""Streaming request-handle front-end over the continuous-batching engine.

The production-shaped serving interface (cf. vLLM-style serving stacks):
callers submit a typed ``GenerationRequest`` and get back a live
``RequestHandle`` — an iterator that yields tokens as the engine emits
them, with ``.status``, ``.result()``, and ``.cancel()``. Everything is
cooperative and thread-free: ``ServingFrontend.poll()`` runs exactly one
engine ``step()`` and routes its event stream (``serving/api.py``) to the
right handles; iterating a handle polls on the caller's behalf until its
next token lands. Because ``poll()`` advances the WHOLE engine
deterministically, the token sequence each handle yields at temperature 0
is bit-identical for every poll/read schedule — and identical to
``run_until_drained()`` on the same workload (tests/test_frontend.py).

    fe = ServingFrontend(BatchedServingEngine(cfg, params, ...))
    h = fe.submit(GenerationRequest(prompt=ids,
                                    params=SamplingParams(max_new_tokens=32),
                                    ttft_slo=0.5, priority=1))
    for tok in h:                  # streams; first iteration shows TTFT
        if deadline_blown():
            h.cancel()             # frees KV slot + expert budget NOW
            break
    r = h.result()                 # RequestResult (partial if cancelled)

Cancellation is synchronous: when ``cancel()`` returns, the request's KV
slot is back in the free pool, its expert-residency contributions are
dropped from the shared ledger, its TBT-ledger entry is closed, and the
handle is terminal — the engine will never emit another event for it.
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional

from repro.obs.spans import monotonic
from repro.serving.api import (Event, FinishEvent, RejectEvent,
                               RequestSnapshot, StepEvents, TokenEvent,
                               as_request_spec)
from repro.serving.batching import BatchedServingEngine, Request
from repro.serving.engine import RequestResult


class RequestHandle:
    """Live handle for one submitted request.

    tokens: generated token ids received so far (grows as the engine runs).
    events: this request's full event stream (Token/Finish/Reject).
    Iterating the handle yields each generated token exactly once, driving
    ``frontend.poll()`` cooperatively while the next token is pending, and
    stops at the request's FinishEvent (or Reject/cancel).
    """

    def __init__(self, frontend, req: Request):
        self._fe = frontend   # ServingFrontend or cluster.ClusterFrontend
        self.req = req
        self.rid = req.rid
        self.replica: Optional[int] = None   # set by ClusterFrontend.submit
        self.tokens: List[int] = []
        self.events: List[Event] = []
        self.finish_reason: Optional[str] = None  # incl. 'rejected'
        self.last_token_t: Optional[float] = None  # wall time of last token
        # one record per snapshot/restore hop this request took (disagg
        # prefill->decode handoff, preemption resume, drain migration):
        # {"t_snapshot", "t_restore", "src", "dst"} — replica indices are
        # None for plain-frontend pauses. Handoff latency = first
        # TokenEvent.t after t_snapshot minus t_snapshot.
        self.handoffs: List[dict] = []
        self._cursor = 0

    # -- state ---------------------------------------------------------------
    @property
    def status(self) -> str:
        """Engine-side lifecycle state: queued | prefilling | running |
        held (prefill done, awaiting KV handoff) | paused (host-side
        snapshot, will resume) | done | rejected | cancelled."""
        return self.req.state

    @property
    def done(self) -> bool:
        """Terminal: finished, rejected, or cancelled — no further events
        will ever arrive for this handle."""
        return self.finish_reason is not None

    # -- event delivery (called by the frontend dispatcher) ------------------
    def _on_event(self, ev: Event) -> None:
        assert not self.done, \
            f"event {ev} for terminal request {self.rid} (engine bug)"
        self.events.append(ev)
        if isinstance(ev, TokenEvent):
            self.tokens.append(ev.token)
            self.last_token_t = ev.t
        elif isinstance(ev, FinishEvent):
            self.finish_reason = ev.reason
        elif isinstance(ev, RejectEvent):
            self.finish_reason = "rejected"

    # -- streaming -----------------------------------------------------------
    def __iter__(self) -> Iterator[int]:
        return self

    def __next__(self) -> int:
        polls = 0
        while self._cursor >= len(self.tokens) and not self.done:
            ev = self._fe.poll()
            polls += 1
            if not ev.did_work and self._fe.idle and not self.done:
                raise RuntimeError(
                    f"request {self.rid} cannot advance: engine idle")
            assert polls < 1_000_000, "handle iteration did not progress"
        if self._cursor < len(self.tokens):
            tok = self.tokens[self._cursor]
            self._cursor += 1
            return tok
        raise StopIteration

    # -- completion ----------------------------------------------------------
    def result(self, max_steps: int = 100_000) -> RequestResult:
        """Drive the engine until this request is terminal and return its
        RequestResult (partial tokens + finish_reason='cancelled' for a
        cancelled request). Raises for a rejected request — it never ran."""
        for _ in range(max_steps):
            if self.done:
                break
            self._fe.poll()
        assert self.done, f"request {self.rid} not terminal in {max_steps}"
        if self.finish_reason == "rejected":
            raise RuntimeError(
                f"request {self.rid} was rejected at admission (SLO shed)")
        return self.req.result()

    def cancel(self, reason: str = "cancelled") -> bool:
        """Cancel this request (see BatchedServingEngine.cancel). When this
        returns True the handle is terminal, the engine has reclaimed the
        request's KV slot / expert-residency / TBT-ledger resources, and no
        further events will ever arrive. False if already terminal.
        `reason` becomes the FinishEvent reason — the QosAutopilot passes
        "slo_shed" so shed requests are distinguishable from caller
        cancellations."""
        return self._fe.cancel(self, reason=reason)


class CooperativeDriver:
    """Shared cooperative poll-loop surface for any front-end exposing
    ``poll()`` + ``idle`` (ServingFrontend here, ClusterFrontend in
    serving/cluster.py) — one definition so the two surfaces cannot
    drift."""

    autopilot = None   # QosAutopilot registers itself here

    def drain(self, max_steps: int = 100_000) -> None:
        """Poll until idle (the frontend analogue of ``run_until_drained``;
        callers read results off the handles they kept from ``submit``)."""
        for _ in range(max_steps):
            if self.idle:
                break
            self.poll()

    def _cancel_paused(self, handle: RequestHandle, reason: str) -> bool:
        """Terminate a host-paused request: the engine holds nothing for
        it, so cancellation is dropping the snapshot (the autopilot's, if
        it is the owner) and finishing the handle directly."""
        ap = self.autopilot
        if ap is not None:
            ap.paused = [(h, s) for (h, s) in ap.paused if h is not handle]
        req = handle.req
        req.state = "cancelled"
        req.finish_reason = reason
        req.t_done = time.perf_counter()
        # the engine never sees this cancel, so record the terminal span
        # here on the recorder of the engine that last ran the request
        # (rid is still that engine's — restore would have re-rid'd it)
        self.engine_of(handle).obs.terminal(req.rid, reason,
                                            n_tokens=len(req.tokens))
        handle._on_event(FinishEvent(rid=req.rid, reason=reason,
                                     n_tokens=len(req.tokens), t=req.t_done))
        return True


class ServingFrontend(CooperativeDriver):
    """Event-driven front-end owning the engine step loop.

    One cooperative driver: each ``poll()`` runs one ``engine.step()`` and
    dispatches the resulting events to the submitted handles. No threads —
    callers interleave ``poll()`` with their own logic (or just iterate a
    handle / call ``result()`` and let the handle poll for them).
    """

    def __init__(self, engine: BatchedServingEngine):
        self.engine = engine
        self._handles: Dict[int, RequestHandle] = {}
        # QosAutopilot (serving/cluster.py) registers itself here; poll()
        # then runs its shed scan after dispatching each step's events
        self.autopilot = None

    def submit(self, spec, **kw) -> RequestHandle:
        """Submit a GenerationRequest (or a raw prompt array plus
        GenerationRequest fields as kwargs); returns its RequestHandle."""
        spec = as_request_spec(spec, **kw)
        req = self.engine.submit_request(spec)
        handle = RequestHandle(self, req)
        self._handles[req.rid] = handle
        return handle

    # -- pause / resume (snapshot primitive, serving/api.py) -----------------
    def pause(self, handle: RequestHandle) -> RequestSnapshot:
        """Snapshot `handle`'s request host-side (engine resources released
        like a cancel, NO FinishEvent — the request is paused, not
        terminal) and unregister its event route. The caller owns the
        returned snapshot; ``resume`` it here or on any other frontend."""
        assert not handle.done, "cannot pause a terminal request"
        snap = self.engine.snapshot(handle.req)
        self._handles.pop(handle.rid, None)
        return snap

    def resume(self, snap: RequestSnapshot,
               handle: Optional[RequestHandle] = None, *,
               src: Optional[int] = None,
               dst: Optional[int] = None) -> RequestHandle:
        """Restore a snapshot into this frontend's engine. Pass the
        original handle to keep the caller's streaming surface alive across
        the pause — it is rebound to the restored request (fresh
        engine-local rid) and its event stream simply continues; with no
        handle a fresh one is created (its ``tokens`` pre-seeded with the
        carried prefix). Records the hop on ``handle.handoffs``
        (src/dst: replica indices when a cluster migration drives this)."""
        req = self.engine.restore(snap)
        if handle is None:
            handle = RequestHandle(self, req)
            handle.tokens = list(req.tokens)
        else:
            handle.req = req
            handle.rid = req.rid
        # t_restore comes from the SAME monotonic clock engine.snapshot
        # stamped t_snapshot with (repro.obs.spans.monotonic) — handoff
        # latency is a difference of one clock, never of two
        handle.handoffs.append({
            "t_snapshot": snap.t_snapshot, "t_restore": monotonic(),
            "src": src, "dst": dst})
        self._handles[req.rid] = handle
        return handle

    @property
    def idle(self) -> bool:
        # host-paused requests keep the frontend non-idle: the autopilot
        # that parked them resumes them from a later poll's scan
        return self.engine.idle and not (
            self.autopilot is not None and self.autopilot.paused)

    def poll(self, now: Optional[float] = None) -> StepEvents:
        """Advance the engine one step and deliver its events. With a
        QosAutopilot attached, its shed scan runs after dispatch — a shed
        request is terminal before poll() returns, and its
        FinishEvent("slo_shed") is appended to the returned stream so
        event-stream consumers observe the termination too."""
        events = self.engine.step(now)
        self._dispatch(events)
        if self.autopilot is not None:
            self.autopilot.scan_into(now, events)
        return events

    def live_handles(self) -> List[RequestHandle]:
        """Non-terminal handles (the dispatch table reaps terminal ones) —
        what the QosAutopilot scans."""
        return list(self._handles.values())

    def engine_of(self, handle: RequestHandle) -> BatchedServingEngine:
        """The engine serving `handle` (trivially THE engine here; the
        cluster front-end resolves the owning replica)."""
        return self.engine

    def _dispatch(self, events) -> None:
        for ev in events:
            handle = self._handles.get(ev.rid)
            if handle is None:
                continue  # raw-engine submission or already-reaped handle
            handle._on_event(ev)
            if handle.done:
                # terminal handles receive nothing further — reap the route
                # so a long-running server's dispatch table stays bounded
                del self._handles[ev.rid]

    def cancel(self, handle: RequestHandle, reason: str = "cancelled"
               ) -> bool:
        if handle.done:
            return False
        if handle.req.state == "paused":
            return self._cancel_paused(handle, reason)
        ok = self.engine.cancel(handle.req, reason=reason)
        # the engine emitted FinishEvent('cancelled') synchronously; deliver
        # it now so the handle is terminal the moment cancel() returns
        self._dispatch(StepEvents(self.engine.drain_events()))
        return ok
