"""Streaming request-handle front-end over the continuous-batching engine.

The production-shaped serving interface (cf. vLLM-style serving stacks):
callers submit a typed ``GenerationRequest`` and get back a live
``RequestHandle`` — an iterator that yields tokens as the engine emits
them, with ``.status``, ``.result()``, and ``.cancel()``. Everything is
cooperative and thread-free: ``ServingFrontend.poll()`` runs exactly one
engine ``step()`` and routes its event stream (``serving/api.py``) to the
right handles; iterating a handle polls on the caller's behalf until its
next token lands. Because ``poll()`` advances the WHOLE engine
deterministically, the token sequence each handle yields at temperature 0
is bit-identical for every poll/read schedule — and identical to
``run_until_drained()`` on the same workload (tests/test_frontend.py).

    fe = ServingFrontend(BatchedServingEngine(cfg, params, ...))
    h = fe.submit(GenerationRequest(prompt=ids,
                                    params=SamplingParams(max_new_tokens=32),
                                    ttft_slo=0.5, priority=1))
    for tok in h:                  # streams; first iteration shows TTFT
        if deadline_blown():
            h.cancel()             # frees KV slot + expert budget NOW
            break
    r = h.result()                 # RequestResult (partial if cancelled)

Cancellation is synchronous: when ``cancel()`` returns, the request's KV
slot is back in the free pool, its expert-residency contributions are
dropped from the shared ledger, its TBT-ledger entry is closed, and the
handle is terminal — the engine will never emit another event for it.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.serving.api import (Event, FinishEvent, GenerationRequest,
                               RejectEvent, StepEvents, TokenEvent)
from repro.serving.batching import BatchedServingEngine, Request
from repro.serving.engine import RequestResult


class RequestHandle:
    """Live handle for one submitted request.

    tokens: generated token ids received so far (grows as the engine runs).
    events: this request's full event stream (Token/Finish/Reject).
    Iterating the handle yields each generated token exactly once, driving
    ``frontend.poll()`` cooperatively while the next token is pending, and
    stops at the request's FinishEvent (or Reject/cancel).
    """

    def __init__(self, frontend: "ServingFrontend", req: Request):
        self._fe = frontend
        self.req = req
        self.rid = req.rid
        self.tokens: List[int] = []
        self.events: List[Event] = []
        self.finish_reason: Optional[str] = None  # incl. 'rejected'
        self._cursor = 0

    # -- state ---------------------------------------------------------------
    @property
    def status(self) -> str:
        """Engine-side lifecycle state: queued | prefilling | running |
        done | rejected | cancelled."""
        return self.req.state

    @property
    def done(self) -> bool:
        """Terminal: finished, rejected, or cancelled — no further events
        will ever arrive for this handle."""
        return self.finish_reason is not None

    # -- event delivery (called by the frontend dispatcher) ------------------
    def _on_event(self, ev: Event) -> None:
        assert not self.done, \
            f"event {ev} for terminal request {self.rid} (engine bug)"
        self.events.append(ev)
        if isinstance(ev, TokenEvent):
            self.tokens.append(ev.token)
        elif isinstance(ev, FinishEvent):
            self.finish_reason = ev.reason
        elif isinstance(ev, RejectEvent):
            self.finish_reason = "rejected"

    # -- streaming -----------------------------------------------------------
    def __iter__(self) -> Iterator[int]:
        return self

    def __next__(self) -> int:
        polls = 0
        while self._cursor >= len(self.tokens) and not self.done:
            ev = self._fe.poll()
            polls += 1
            if not ev.did_work and self._fe.idle and not self.done:
                raise RuntimeError(
                    f"request {self.rid} cannot advance: engine idle")
            assert polls < 1_000_000, "handle iteration did not progress"
        if self._cursor < len(self.tokens):
            tok = self.tokens[self._cursor]
            self._cursor += 1
            return tok
        raise StopIteration

    # -- completion ----------------------------------------------------------
    def result(self, max_steps: int = 100_000) -> RequestResult:
        """Drive the engine until this request is terminal and return its
        RequestResult (partial tokens + finish_reason='cancelled' for a
        cancelled request). Raises for a rejected request — it never ran."""
        for _ in range(max_steps):
            if self.done:
                break
            self._fe.poll()
        assert self.done, f"request {self.rid} not terminal in {max_steps}"
        if self.finish_reason == "rejected":
            raise RuntimeError(
                f"request {self.rid} was rejected at admission (SLO shed)")
        return self.req.result()

    def cancel(self) -> bool:
        """Cancel this request (see BatchedServingEngine.cancel). When this
        returns True the handle is terminal, the engine has reclaimed the
        request's KV slot / expert-residency / TBT-ledger resources, and no
        further events will ever arrive. False if already terminal."""
        return self._fe.cancel(self)


class ServingFrontend:
    """Event-driven front-end owning the engine step loop.

    One cooperative driver: each ``poll()`` runs one ``engine.step()`` and
    dispatches the resulting events to the submitted handles. No threads —
    callers interleave ``poll()`` with their own logic (or just iterate a
    handle / call ``result()`` and let the handle poll for them).
    """

    def __init__(self, engine: BatchedServingEngine):
        self.engine = engine
        self._handles: Dict[int, RequestHandle] = {}

    def submit(self, spec, **kw) -> RequestHandle:
        """Submit a GenerationRequest (or a raw prompt array plus
        GenerationRequest fields as kwargs); returns its RequestHandle."""
        if isinstance(spec, GenerationRequest):
            assert not kw, ("kwargs are ignored when a full "
                            "GenerationRequest is passed — set the fields "
                            "on the spec instead")
        else:
            spec = GenerationRequest(
                prompt=np.asarray(spec, np.int32).reshape(-1), **kw)
        req = self.engine.submit_request(spec)
        handle = RequestHandle(self, req)
        self._handles[req.rid] = handle
        return handle

    @property
    def idle(self) -> bool:
        return self.engine.idle

    def poll(self, now: Optional[float] = None) -> StepEvents:
        """Advance the engine one step and deliver its events."""
        events = self.engine.step(now)
        self._dispatch(events)
        return events

    def _dispatch(self, events) -> None:
        for ev in events:
            handle = self._handles.get(ev.rid)
            if handle is None:
                continue  # raw-engine submission or already-reaped handle
            handle._on_event(ev)
            if handle.done:
                # terminal handles receive nothing further — reap the route
                # so a long-running server's dispatch table stays bounded
                del self._handles[ev.rid]

    def cancel(self, handle: RequestHandle) -> bool:
        if handle.done:
            return False
        ok = self.engine.cancel(handle.req)
        # the engine emitted FinishEvent('cancelled') synchronously; deliver
        # it now so the handle is terminal the moment cancel() returns
        self._dispatch(StepEvents(self.engine.drain_events()))
        return ok

    def drain(self, max_steps: int = 100_000) -> None:
        """Poll until the engine is idle (the frontend analogue of
        ``run_until_drained``; callers read results off the handles they
        kept from ``submit``)."""
        for _ in range(max_steps):
            if self.idle:
                break
            self.poll()
