"""Hypothesis property tests on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cache import CacheState
from repro.core.scheduler import union_selection
from repro.core.tracer import ExpertsTracer
from repro.models import moe_layer as M
from repro.configs.base import ArchConfig

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# CacheState invariants
# ---------------------------------------------------------------------------

ops = st.lists(
    st.tuples(st.sampled_from(["lookup", "admit", "unpin", "end"]),
              st.integers(0, 3), st.integers(0, 7)),
    min_size=1, max_size=60)


@given(cap=st.integers(2, 10), seq=ops)
def test_cache_capacity_and_counters(cap, seq):
    c = CacheState(cap, bytes_per_expert=100)
    for op, l, e in seq:
        if op == "lookup":
            c.lookup((l, e))
        elif op == "admit":
            c.admit((l, e), pinned=(e % 2 == 0))
        elif op == "unpin":
            c.unpin((l, e))
        elif op == "end":
            for k in list(c.resident):
                if k[0] == l:
                    c.unpin(k)
        # capacity respected unless everything resident is pinned
        if len(c.resident) > cap:
            assert all(c.resident.values()), \
                "over capacity while unpinned entries existed"
    assert c.hits + c.misses == sum(1 for op, _, _ in seq if op == "lookup")
    assert c.peak_resident >= len(c.resident) - 0
    assert c.peak_bytes == c.peak_resident * 100


cache_ops = st.lists(
    st.tuples(st.sampled_from(["lookup", "admit_pinned", "admit", "unpin"]),
              st.integers(0, 2), st.integers(0, 5)),
    min_size=1, max_size=60)


@given(cap=st.integers(2, 6), seq=cache_ops)
def test_cache_lru_eviction_order(cap, seq):
    """Every evicted victim is the least-recently-used unpinned entry at the
    moment of eviction, verified against an external recency/pin model."""
    c = CacheState(cap, 1)
    clock = 0
    recency = {}   # key -> last-touch time observed from outside
    pins = {}      # key -> pinned state we expect
    for op, l, e in seq:
        key, clock = (l, e), clock + 1
        if op == "lookup":
            if c.lookup(key):
                recency[key] = clock
        elif op == "unpin":
            evicted = c.unpin(key)
            if key in pins:
                pins[key] = False
            for v in evicted:   # shrink-on-unpin of an over-grown cache
                assert not pins.pop(v), "shrink evicted a pinned entry"
                recency.pop(v, None)
        else:
            pinned = op == "admit_pinned"
            was_resident = c.contains(key)
            before = dict(pins)
            evicted = c.admit(key, pinned=pinned)
            for v in evicted:
                assert not before[v], f"evicted a pinned entry {v}"
                # no other unpinned entry (still resident) was older
                others = [k for k in c.resident
                          if k != key and not before.get(k, True)]
                assert all(recency[v] <= recency[k] for k in others), \
                    f"victim {v} was not the LRU unpinned entry"
                recency.pop(v, None)
                pins.pop(v, None)
            if c.contains(key):
                pins[key] = pinned or (was_resident
                                       and before.get(key, False))
                recency[key] = clock
            else:  # speculative admit declined by an all-pinned full cache
                assert not pinned and not evicted
                assert all(before.values()) and len(before) >= cap
        # THE invariant: over capacity only while everything is pinned
        if len(c.resident) > cap:
            assert all(c.resident.values()), \
                "over capacity with unpinned entries"
    assert set(c.resident) == set(pins)


@given(cap=st.integers(2, 6), fill=st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, 5)), min_size=1, max_size=40))
def test_cache_pin_survives_pressure(cap, fill):
    """A pinned entry is NEVER evicted, however much unpinned churn follows."""
    c = CacheState(cap, 1)
    protected = (9, 9)
    c.admit(protected, pinned=True)
    for k in fill:
        if k == protected:
            continue
        c.admit(k, pinned=False)
        assert c.contains(protected)
        assert len(c.resident) <= cap


# ---------------------------------------------------------------------------
# union_selection invariants
# ---------------------------------------------------------------------------

_leaf = st.integers(0, 9)
_row = st.lists(_leaf, min_size=0, max_size=4)
_element = st.one_of(
    _leaf,
    _row,
    _row.map(lambda r: np.asarray(r, np.int32)),
    st.lists(st.lists(_leaf, min_size=2, max_size=2), min_size=0, max_size=3)
    .map(lambda rows: np.asarray(rows, np.int32).reshape(-1, 2)),
)
selections = st.lists(_element, min_size=0, max_size=6)


def _flatten(sel):
    out = []
    for e in sel:
        if isinstance(e, (list, tuple, np.ndarray)):
            out.extend(_flatten(list(e)))
        else:
            out.append(int(e))
    return out


@given(sel=selections)
def test_union_selection_properties(sel):
    """Duplicate-free, first-appearance order-stable, nested/flat/ndarray
    inputs all flatten to the same reference order."""
    got = union_selection(sel)
    flat = _flatten(sel)
    expected = list(dict.fromkeys(flat))
    assert got == expected
    assert len(got) == len(set(got))
    # idempotent and insensitive to re-nesting
    assert union_selection(got) == got
    assert union_selection([flat]) == expected


# ---------------------------------------------------------------------------
# Tracer invariants
# ---------------------------------------------------------------------------

@given(st.data())
def test_tracer_normalization(data):
    L = data.draw(st.integers(2, 5))
    E = data.draw(st.integers(2, 8))
    K = data.draw(st.integers(1, min(3, E)))
    n = data.draw(st.integers(1, 20))
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    tr = ExpertsTracer(L, E, K)
    for _ in range(n):
        path = np.stack([rng.choice(E, K, replace=False) for _ in range(L)])
        tr.add_path(path)
    s = tr.stats()
    np.testing.assert_allclose(s.popularity.sum(1), 1.0, rtol=1e-5)
    rs = s.affinity.sum(2)
    assert ((np.abs(rs - 1) < 1e-5) | (rs == 0)).all()
    assert (s.popularity >= 0).all() and (s.affinity >= 0).all()


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@given(st.data())
def test_moe_capacity_matches_oracle_when_dropless(data):
    """With capacity >= T*k the sort+capacity dispatch must equal the dense
    per-expert oracle exactly (no drops possible)."""
    E = data.draw(st.sampled_from([4, 6, 8]))
    K = data.draw(st.integers(1, 2))
    T = data.draw(st.sampled_from([8, 16]))
    d, de = 32, 16
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=d,
                     n_heads=2, n_kv_heads=2, d_ff=de, vocab=64,
                     n_experts=E, top_k=K, d_expert=de)
    key = jax.random.PRNGKey(data.draw(st.integers(0, 100)))
    k1, k2 = jax.random.split(key)
    p = M.moe_params(k1, cfg, n_model=1, dtype=jnp.float32)
    x = jax.random.normal(k2, (T, d), jnp.float32) * 0.5
    y_cap, aux1 = M.moe_ffn_local(x, p, cfg, capacity=T * K)
    y_ref, aux2 = M.moe_ffn_ref(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


@given(st.integers(0, 1000))
def test_moe_router_weights_normalized(seed):
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=8, vocab=64,
                     n_experts=6, top_k=3, d_expert=8)
    key = jax.random.PRNGKey(seed)
    router = jax.random.normal(key, (16, 8))  # padded to 8
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (5, 16))
    w, ids, probs = M.route(x, router, cfg.n_experts, cfg.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(ids) < cfg.n_experts).all()  # never routes to padding
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-4)


@given(st.integers(2, 64), st.integers(1, 16))
def test_capacity_rounding(t_loc, e_pad):
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=8,
                     n_heads=1, n_kv_heads=1, d_ff=8, vocab=8,
                     n_experts=e_pad, top_k=min(2, e_pad), d_expert=8)
    c = M.capacity_for(t_loc, cfg, e_pad)
    assert 1 <= c <= max(t_loc * cfg.top_k, cfg.top_k)


# ---------------------------------------------------------------------------
# Ring cache invariant
# ---------------------------------------------------------------------------

@given(prompt=st.integers(1, 12), extra=st.integers(1, 12))
def test_ring_cache_pad_invariant(prompt, extra):
    """After prefill + pad_cache, slot i holds position i for i < prompt and
    the next write slot (pos % cap) is empty."""
    from repro.models.model import pad_cache
    cap = prompt + extra
    cache = {
        "k": jnp.arange(prompt, dtype=jnp.float32)[None, None, :, None, None],
        "slot_pos": jnp.arange(prompt, dtype=jnp.int32),
        "pos": jnp.int32(prompt),
    }
    out = pad_cache(cache, cap, {"k": 2})
    sp = np.asarray(out["slot_pos"])
    assert sp.shape[0] == cap
    assert (sp[:prompt] == np.arange(prompt)).all()
    assert (sp[prompt:] == -1).all()
    assert sp[int(out["pos"]) % cap] == -1
