"""Unified ExpertResidency invariants: ONE ledger per engine, slot-pool
device buffers mirroring it exactly, and a hard expert-HBM bound.

The tentpole contract (ISSUE 3):
  * exactly one CacheState exists per engine — the scheduler and the device
    buffers share the ExpertResidency by reference;
  * at every step, ``set(slot_of) == set(state.resident)`` and device expert
    bytes == ``pool_capacity * bytes_per_expert`` with ``pool_capacity ==
    capacity`` (the all-pinned growth branch never fires in a sized engine);
  * slot-pool weight reads are bit-exact vs the old dict path
    (``device_put`` per expert) at temperature 0.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.cache import CacheState, ExpertResidency, HostExpertStore
from repro.core.tracer import ExpertsTracer
from repro.models.model import build
from repro.serving.batching import BatchedServingEngine
from repro.serving.engine import MoEServingEngine

POLICIES = ["odf", "lfp", "mif", "duo"]


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (12, 9, 14)]
    tracer = ExpertsTracer(cfg.n_layers, cfg.n_experts, cfg.top_k)
    for _ in range(8):
        tracer.add_path(np.stack([
            rng.choice(cfg.n_experts, cfg.top_k, replace=False)
            for _ in range(cfg.n_layers)]))
    return cfg, params, prompts, tracer.stats()


def assert_residency_invariants(res: ExpertResidency):
    """The full slot-pool <-> ledger mirror contract, checked at a step
    boundary."""
    assert set(res.slot_of) == set(res.resident), \
        "slot map and ledger diverged"
    # HBM bound: the pool IS the footprint, and it never regrew (the
    # shared predicate first, then its pieces for sharper failures)
    assert res.hbm_bound_ok
    assert res.regrow_events == 0
    assert res.pool_capacity == res.capacity
    assert res.device_bytes == res.pool_capacity * res.bytes_per_expert
    assert len(res.resident) <= res.capacity
    assert res.peak_resident <= res.capacity
    # every slot is either free or mapped, never both
    assert len(res._free) + len(res.slot_of) == res.pool_capacity
    assert set(res._free).isdisjoint(res.slot_of.values())
    # loaded keys are a subset of mapped keys
    assert res._loaded <= set(res.slot_of)


@pytest.mark.parametrize("policy", POLICIES)
def test_single_engine_residency_parity(setup, policy):
    """One ledger per engine; slot map == residency after a request."""
    cfg, params, prompts, stats = setup
    eng = MoEServingEngine(cfg, params, policy=policy, stats=stats,
                           temperature=0.0)
    assert eng.cache is eng.sched.cache, "two ledgers exist"
    assert isinstance(eng.cache, ExpertResidency)
    for p in prompts:
        eng.serve(p, max_new=3)
        assert_residency_invariants(eng.cache)


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("budget", [None, 3])
def test_batched_residency_parity_per_step(setup, policy, budget):
    """After EVERY engine step (batched, monolithic AND chunked prefill):
    slot map == residency and expert HBM stays at the fixed bound."""
    cfg, params, prompts, stats = setup
    eng = BatchedServingEngine(cfg, params, policy=policy, stats=stats,
                               max_batch=2, max_seq=32, temperature=0.0,
                               prefill_budget=budget)
    assert eng.cache is eng.sched.cache
    for p in prompts:
        eng.submit(p, max_new=3)
    for _ in range(200):
        eng.step()
        assert_residency_invariants(eng.cache)
        if not eng.running and not eng.prefilling and not len(eng.queue):
            break
    assert len(eng.finished) == len(prompts)


def test_slot_pool_reads_bit_exact_vs_host(setup):
    """Every loaded pool slot holds exactly the host store's bytes."""
    cfg, params, prompts, stats = setup
    eng = MoEServingEngine(cfg, params, policy="duo", temperature=0.0)
    eng.serve(prompts[0], max_new=3)
    res = eng.cache
    assert res._loaded, "no experts loaded?"
    for key in res._loaded:
        s = res.slot_of[key]
        for pool, host in zip(res.pools, res.store.get(key)):
            np.testing.assert_array_equal(np.asarray(pool[s]), host)


def test_slot_path_matches_dict_path_bit_exact(setup):
    """The jitted slot-indexed expert kernel reproduces the old dict-cache
    path (device_put per expert, weights as plain jit args) bit-for-bit."""
    cfg, params, prompts, stats = setup
    eng = MoEServingEngine(cfg, params, policy="duo", temperature=0.0)
    eng.serve(prompts[0], max_new=2)
    res = eng.cache

    @jax.jit
    def raw_dict_path(xn, w1, w3, w2):
        x2 = xn.reshape(-1, xn.shape[-1])
        h = jax.nn.silu(x2 @ w1) * (x2 @ w3)
        return (h @ w2).astype(jnp.float32)

    rng = np.random.default_rng(0)
    xn = jnp.asarray(rng.standard_normal((1, 1, cfg.d_model)),
                     jnp.bfloat16)
    for key in sorted(res._loaded):
        s = jnp.int32(res.slot_of[key])
        got = np.asarray(eng._expert_raw(xn, *res.pools, s))
        w1, w3, w2 = [jax.device_put(a) for a in res.store.get(key)]
        want = np.asarray(raw_dict_path(xn, w1, w3, w2))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"slot path diverged for {key}")


def test_greedy_tokens_invariant_across_policies(setup):
    """Residency/scheduling must never change greedy outputs (the old
    dict-cache engines shared this invariant — pins no-drift through the
    refactor)."""
    cfg, params, prompts, stats = setup
    outs = {}
    for pol in POLICIES:
        eng = MoEServingEngine(cfg, params, policy=pol, stats=stats,
                               temperature=0.0)
        outs[pol] = eng.serve(prompts[1], max_new=4).tokens
    ref = outs[POLICIES[0]]
    for pol, toks in outs.items():
        np.testing.assert_array_equal(toks, ref, err_msg=f"{pol} diverged")


# ---------------------------------------------------------------------------
# unit-level: hooks, drop, regrow, rescale
# ---------------------------------------------------------------------------


def _tiny_store(n_layers=2, n_experts=3, d=4, de=2):
    rng = np.random.default_rng(0)
    w = {}
    for l in range(n_layers):
        for e in range(n_experts):
            w[(l, e)] = (rng.standard_normal((d, de)).astype(np.float32),
                         rng.standard_normal((d, de)).astype(np.float32),
                         rng.standard_normal((de, d)).astype(np.float32))
    return HostExpertStore(w)


def test_evict_frees_slot_and_admit_reuses_it():
    res = ExpertResidency(_tiny_store(), capacity=2)
    res.admit((0, 0), pinned=False)
    res.admit((0, 1), pinned=False)
    s0 = res.slot_of[(0, 0)]
    res.prefetch((0, 0))
    evicted = res.admit((0, 2), pinned=False)   # LRU evicts (0,0)
    assert evicted == [(0, 0)]
    assert (0, 0) not in res.slot_of and (0, 0) not in res._loaded
    assert res.slot_of[(0, 2)] == s0            # slot reused, not leaked
    # re-admitted key transfers fresh weights into its (new) slot
    res.admit((0, 0), pinned=True)
    res.prefetch((0, 0))
    s = res.slot_of[(0, 0)]
    np.testing.assert_array_equal(np.asarray(res.pools[0][s]),
                                  res.store.get((0, 0))[0])


def test_drop_frees_device_slot_without_evict_event():
    """ODF free-after-forward: drop releases the slot but records no evict
    event (parity with the simulator's ledger replay)."""
    res = ExpertResidency(_tiny_store(), capacity=4)
    res.admit((0, 0))
    res.prefetch((0, 0))
    n_events = len(res.events)
    assert res.drop((0, 0))
    assert (0, 0) not in res.slot_of
    assert len(res._free) == 4
    assert len(res.events) == n_events          # no evict event
    assert not res.drop((0, 0))                 # idempotent


def test_unpin_shrink_frees_slots():
    res = ExpertResidency(_tiny_store(), capacity=2)
    res.admit((0, 0), pinned=True)
    res.admit((0, 1), pinned=True)
    res.admit((0, 2), pinned=True)              # all-pinned growth
    assert len(res.resident) == 3
    assert res.pool_capacity >= 3               # pool regrew to cover it
    assert res.regrow_events == 1
    res.unpin((0, 0))                            # shrink-on-unpin
    assert (0, 0) not in res.slot_of
    assert len(res.resident) == 2
    assert len(res._free) + len(res.slot_of) == res.pool_capacity


def test_rescale_grows_pool_without_counting_overflow():
    res = ExpertResidency(_tiny_store(), capacity=2)
    res.admit((0, 0))
    res.prefetch((0, 0))
    before = np.asarray(res.pools[0][res.slot_of[(0, 0)]]).copy()
    res.rescale(5)
    assert res.capacity == 5 and res.pool_capacity == 5
    assert res.regrow_events == 0               # provisioning, not overflow
    assert res.device_bytes == 5 * res.bytes_per_expert
    # existing slot contents survive the regrow
    np.testing.assert_array_equal(
        np.asarray(res.pools[0][res.slot_of[(0, 0)]]), before)
    with pytest.raises(AssertionError):
        res.rescale(3)                           # grow-only


def test_shared_state_construction():
    """make_scheduler(state=...) drives the given ledger instead of a
    private one, rescaling it if the policy needs more room."""
    from repro.core.scheduler import default_capacity, make_scheduler
    store = _tiny_store()
    res = ExpertResidency(store, capacity=2)
    sched = make_scheduler("lfp", 2, 3, 1, store.bytes_per_expert,
                           state=res)
    assert sched.cache is res
    assert res.capacity == default_capacity("lfp", 2, 3, 1) == 6
    assert res.pool_capacity == 6
    # simulator path: no state -> a plain ledger-only CacheState
    sim = make_scheduler("lfp", 2, 3, 1, store.bytes_per_expert)
    assert isinstance(sim.cache, CacheState)
    assert not isinstance(sim.cache, ExpertResidency)
