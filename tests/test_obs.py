"""repro.obs: metrics registry, span recorder, and Perfetto export
(ISSUE 10 tentpole).

  * Registry — get-or-create identity, kind conflicts, label series,
    push/pull gauges, P² histogram summaries, a GOLDEN Prometheus
    exposition, and snapshot schema validation (bool/None/inf rejected).
  * Spans — nesting, double-end detection, disabled/sampled-out no-ops,
    deterministic rid sampling, ring eviction that can never orphan an
    open span, and the one-terminal-per-rid invariant.
  * Lifecycle integration — every finish path a request can take
    (length, cancel, admission reject, slo_shed, preempt+resume,
    cancel-while-paused, disagg handoff) records EXACTLY ONE terminal
    span on the rid chain that served it.
  * Perfetto export — schema-valid JSON, one process per replica with
    lifecycle/prefill/decode/prefetch lanes, handoff flow s/f pairing
    across replica tracks, and unpaired flows rejected.
  * Clocks — `RequestHandle.handoffs` t_snapshot/t_restore come from the
    one monotonic clock, so hop latency is non-negative by construction.
"""
import json
import math
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.model import build
from repro.obs import (MetricsRegistry, SpanRecorder, monotonic,
                       to_chrome_trace, validate_metrics_snapshot,
                       validate_trace, write_trace)
from repro.obs.metrics import METRICS_SCHEMA
from repro.serving.api import GenerationRequest, SamplingParams
from repro.serving.batching import BatchedServingEngine
from repro.serving.cluster import ClusterFrontend, QosAutopilot, ReplicaPool
from repro.serving.frontend import ServingFrontend

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    params = build(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (12, 16, 9, 14)]
    return cfg, params, prompts


def _fe(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("prefill_budget", 3)
    kw.setdefault("spans", True)
    return ServingFrontend(BatchedServingEngine(
        cfg, params, policy="duo", max_seq=32, temperature=0.0, **kw))


def _spec(p, max_new=MAX_NEW, **kw):
    return GenerationRequest(prompt=p,
                             params=SamplingParams(max_new_tokens=max_new),
                             **kw)


def _poll_until(fe, pred, limit=500):
    for _ in range(limit):
        if pred():
            return
        fe.poll()
    raise AssertionError("condition not reached")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create: same (name, labels) is the same object
    assert reg.counter("reqs_total") is c
    assert reg.counter("reqs_total", replica="1") is not c


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")


def test_gauge_push_and_pull():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(2)
    g.max_update(7)
    g.max_update(3)
    assert g.value == 7.0
    src = {"v": 0}
    p = reg.gauge("pulled", fn=lambda: src["v"])
    src["v"] = 42
    assert p.value == 42.0          # evaluated at read time
    with pytest.raises(ValueError, match="pull-mode"):
        p.set(1)


def test_gauge_late_fn_binding():
    """gauge() without fn first (e.g. a reader), then with fn: the callback
    binds onto the existing instrument instead of being dropped."""
    reg = MetricsRegistry()
    g1 = reg.gauge("late")
    g2 = reg.gauge("late", fn=lambda: 5)
    assert g1 is g2 and g1.value == 5.0


def test_histogram_summary():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", qs=(50,))
    assert h.summary() == {"count": 0.0, "sum": 0.0}   # no min/max/pXX yet
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["min"] == 0.1 and s["max"] == 0.3
    assert s["p50"] == pytest.approx(0.2)


def test_snapshot_label_keys():
    reg = MetricsRegistry()
    reg.counter("shed_total", reason="ttft").inc(2)
    reg.counter("shed_total", reason="tbt").inc(1)
    snap = reg.snapshot()
    assert snap['shed_total{reason="tbt"}'] == 1.0
    assert snap['shed_total{reason="ttft"}'] == 2.0
    assert len(reg.series("shed_total")) == 2


def test_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("requests_total", "Requests offered", replica="0").inc(3)
    reg.gauge("queue_depth", "Waiting requests").set(2)
    h = reg.histogram("step_seconds", "Decode step wall", qs=(50,))
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    assert reg.exposition() == (
        "# HELP queue_depth Waiting requests\n"
        "# TYPE queue_depth gauge\n"
        "queue_depth 2\n"
        "# HELP requests_total Requests offered\n"
        "# TYPE requests_total counter\n"
        'requests_total{replica="0"} 3\n'
        "# HELP step_seconds Decode step wall\n"
        "# TYPE step_seconds summary\n"
        'step_seconds{quantile="0.5"} 0.2\n'
        f"step_seconds_sum {repr(0.1 + 0.2 + 0.3)}\n"
        "step_seconds_count 3\n")


def test_validate_metrics_snapshot():
    good = {"schema": METRICS_SCHEMA,
            "cluster": {"handoffs": 3},
            "replicas": [{"a{r=\"0\"}": 1.5, "note": "str ok",
                          "h": {"p50": float("nan")}}]}
    assert validate_metrics_snapshot(good) == []
    assert validate_metrics_snapshot({"schema": "wrong"})
    assert validate_metrics_snapshot({"schema": METRICS_SCHEMA, "x": True})
    assert validate_metrics_snapshot({"schema": METRICS_SCHEMA, "x": None})
    assert validate_metrics_snapshot(
        {"schema": METRICS_SCHEMA, "x": float("inf")})
    assert validate_metrics_snapshot([1, 2])


# ---------------------------------------------------------------------------
# span recorder
# ---------------------------------------------------------------------------


def test_span_nesting_and_order():
    rec = SpanRecorder(enabled=True)
    outer = rec.begin("decode.step", lane="decode")
    inner = rec.begin("prefetch.correction", lane="prefetch", layer=0)
    rec.end(inner)
    rec.end(outer, batch=2)
    spans = rec.spans()
    assert [s.name for s in spans] == ["prefetch.correction", "decode.step"]
    inner_s, outer_s = spans
    # the inner interval nests inside the outer one
    assert outer_s.t0 <= inner_s.t0 <= inner_s.t1 <= outer_s.t1
    assert outer_s.args["batch"] == 2 and not rec.open_spans()


def test_span_double_end_raises():
    rec = SpanRecorder(enabled=True)
    tok = rec.begin("x")
    rec.end(tok)
    with pytest.raises(ValueError, match="twice"):
        rec.end(tok)


def test_span_disabled_is_noop():
    rec = SpanRecorder(enabled=False)
    assert rec.begin("x") is None
    rec.end(None)                       # no-op by contract
    rec.instant("y")
    rec.terminal(1, "length")
    assert rec.spans() == [] and rec.terminal_reasons() == {}


def test_sampling_deterministic_and_engine_spans_kept():
    rec = SpanRecorder(enabled=True, sample=0.5)
    kept = {rid for rid in range(200) if rec.sampled(rid)}
    assert 0 < len(kept) < 200              # a strict subset survives
    assert kept == {rid for rid in range(200) if rec.sampled(rid)}
    assert rec.sampled(None)                # engine-phase spans always kept
    for rid in range(200):
        rec.instant("request.queued", rid=rid)
    assert {s.rid for s in rec.spans()} == kept


def test_ring_eviction_never_orphans_open_spans():
    rec = SpanRecorder(enabled=True, capacity=4)
    tok = rec.begin("decode.step", lane="decode")
    for i in range(10):
        rec.instant("ffn.launch", lane="decode", layer=i)
    assert len(rec.spans()) == 4 and rec.n_dropped == 6
    assert [s.args["layer"] for s in rec.spans()] == [6, 7, 8, 9]
    # the open span survived the churn and still closes cleanly
    assert len(rec.open_spans()) == 1
    rec.end(tok)
    assert rec.spans()[-1].name == "decode.step" and not rec.open_spans()


def test_terminal_twice_raises():
    rec = SpanRecorder(enabled=True)
    rec.terminal(7, "length")
    assert rec.terminal_reasons() == {7: "length"}
    with pytest.raises(RuntimeError, match="second terminal"):
        rec.terminal(7, "cancelled")


# ---------------------------------------------------------------------------
# request lifecycle: exactly one terminal per rid chain, every finish path
# ---------------------------------------------------------------------------


def _terminals(*engines):
    out = {}
    for e in engines:
        for rid, reason in e.obs.terminal_reasons().items():
            assert rid not in out, f"rid {rid} terminal on two engines"
            out[rid] = reason
    return out


def test_terminal_once_finished(setup):
    cfg, params, prompts = setup
    fe = _fe(cfg, params)
    hs = [fe.submit(_spec(p)) for p in prompts[:3]]
    fe.drain()
    terms = _terminals(fe.engine)
    assert sorted(terms) == sorted(h.rid for h in hs)
    assert set(terms.values()) == {"length"}
    # queued/admitted instants present for each rid; no span left open
    names = {(s.rid, s.name) for s in fe.engine.obs.spans()}
    for h in hs:
        assert (h.rid, "request.queued") in names
        assert (h.rid, "request.admitted") in names
    assert fe.engine.obs.open_spans() == []


def test_terminal_once_cancelled(setup):
    cfg, params, prompts = setup
    fe = _fe(cfg, params)
    h = fe.submit(_spec(prompts[0], max_new=16))
    _poll_until(fe, lambda: len(h.tokens) >= 2)
    h.cancel()
    fe.drain()
    assert _terminals(fe.engine)[h.rid] == "cancelled"


def test_terminal_once_slo_shed(setup):
    cfg, params, prompts = setup
    fe = _fe(cfg, params)
    QosAutopilot(fe)
    h = fe.submit(_spec(prompts[0], max_new=16, tbt_slo=60.0))
    _poll_until(fe, lambda: len(h.tokens) >= 2)
    fe.poll(time.perf_counter() + 100.0)    # deadline long past -> shed
    assert h.finish_reason == "slo_shed"
    assert _terminals(fe.engine)[h.rid] == "slo_shed"
    fe.drain()
    assert _terminals(fe.engine)[h.rid] == "slo_shed"   # still exactly one


def test_terminal_once_admission_rejected(setup):
    cfg, params, prompts = setup
    fe = _fe(cfg, params, max_batch=1)
    busy = fe.submit(_spec(prompts[0], max_new=16))
    _poll_until(fe, lambda: len(busy.tokens) >= 1)
    # an unmeetable TTFT deadline behind a busy slot is rejected at
    # admission — that rejection is that rid's one terminal
    doomed = fe.submit(_spec(prompts[1], ttft_slo=1e-9))
    _poll_until(fe, lambda: doomed.done)
    assert doomed.finish_reason == "rejected"
    assert _terminals(fe.engine)[doomed.rid] == "rejected"
    busy.cancel()
    fe.drain()


def test_terminal_once_preempt_resume(setup):
    """pause+resume re-rids the request; the CHAIN still ends in exactly
    one terminal (on the resumed rid), and the paused/restored instants
    carry the linkage."""
    cfg, params, prompts = setup
    fe = _fe(cfg, params, max_batch=1)
    ap = QosAutopilot(fe, preempt=True)
    lo = fe.submit(_spec(prompts[0], priority=0))
    rid0 = lo.rid
    _poll_until(fe, lambda: len(lo.tokens) >= 2)
    hi = fe.submit(_spec(prompts[2], priority=5))
    fe.poll()
    assert lo.status == "paused" and ap.n_preempted == 1
    fe.drain()
    assert lo.done and hi.done
    terms = _terminals(fe.engine)
    assert rid0 not in terms                # paused is not a terminal
    assert terms[lo.rid] == "length" and terms[hi.rid] == "length"
    spans = fe.engine.obs.spans()
    assert any(s.name == "request.paused" and s.rid == rid0 for s in spans)
    assert any(s.name == "request.restored" and s.rid == lo.rid
               and s.args["source_rid"] == rid0 for s in spans)
    assert any(s.name == "autopilot.preempt" and s.rid == rid0
               for s in spans)


def test_terminal_once_cancel_while_paused(setup):
    """A handle cancelled while paused never touches an engine again; the
    frontend records the chain's one terminal on the owning recorder."""
    cfg, params, prompts = setup
    fe = _fe(cfg, params, max_batch=1)
    QosAutopilot(fe, preempt=True)
    lo = fe.submit(_spec(prompts[0], priority=0))
    _poll_until(fe, lambda: len(lo.tokens) >= 1)
    hi = fe.submit(_spec(prompts[2], priority=5))
    fe.poll()
    assert lo.status == "paused"
    paused_rid = lo.rid
    lo.cancel()
    assert lo.finish_reason == "cancelled"
    assert _terminals(fe.engine)[paused_rid] == "cancelled"
    fe.drain()


def test_terminal_once_disagg_handoff(setup):
    """Across the prefill->decode hop the chain is: source rid (paused at
    the handoff, never terminal) -> destination rid (one terminal)."""
    cfg, params, prompts = setup
    pool = ReplicaPool.build(
        cfg, params, policy="duo", max_batch=2, max_seq=32,
        prefill_budget=3, temperature=0.0, spans=True,
        overrides=[{"role": "prefill"}, {"role": "decode"}])
    fe = ClusterFrontend(pool, router="disagg")
    hs = [fe.submit(_spec(p)) for p in prompts[:2]]
    fe.drain()
    src, dst = pool.engines
    assert src.obs.terminal_reasons() == {}     # prefill replica: no finishes
    terms = _terminals(src, dst)
    assert sorted(terms) == sorted(h.rid for h in hs)
    assert set(terms.values()) == {"length"}
    # the hop itself: snapshot instant on source, restore instant on dest,
    # sharing a flow id
    snaps = [s for s in src.obs.spans() if s.name == "handoff.snapshot"]
    rests = [s for s in dst.obs.spans() if s.name == "handoff.restore"]
    assert len(snaps) == len(rests) == 2
    assert ({s.args["flow"] for s in snaps}
            == {r.args["flow"] for r in rests})


def test_handoff_timing_monotonic(setup):
    """t_snapshot/t_restore come from the spans' monotonic clock: the hop
    latency is non-negative and consistent with `monotonic()` now."""
    cfg, params, prompts = setup
    pool = ReplicaPool.build(
        cfg, params, policy="duo", max_batch=2, max_seq=32,
        prefill_budget=3, temperature=0.0,
        overrides=[{"role": "prefill"}, {"role": "decode"}])
    fe = ClusterFrontend(pool, router="disagg")
    h = fe.submit(_spec(prompts[0]))
    fe.drain()
    assert len(h.handoffs) == 1
    hop = h.handoffs[0]
    assert hop["t_snapshot"] <= hop["t_restore"] <= monotonic()


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------


def _two_replica_recorders():
    a = SpanRecorder(enabled=True, replica=0)
    b = SpanRecorder(enabled=True, replica=1)
    t = a.begin("prefill.chunk", lane="prefill", rid=1, tokens=3)
    a.end(t)
    a.instant("handoff.snapshot", lane="lifecycle", flow=7, src=0, dst=1)
    b.instant("handoff.restore", lane="lifecycle", flow=7, src=0, dst=1)
    t = b.begin("decode.step", lane="decode", batch=2)
    b.end(t)
    b.instant("prefetch.dispatch", lane="prefetch", layer=0, n=2)
    return a, b


def test_chrome_trace_layout_and_flows():
    a, b = _two_replica_recorders()
    trace = to_chrome_trace([a, b])
    assert validate_trace(trace) == []
    evs = trace["traceEvents"]
    # one process per replica, named lanes
    names = {(e["pid"], e["args"]["name"]) for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    for pid in (0, 1):
        for lane in ("lifecycle", "prefill-chunk", "batched-decode",
                     "expert-prefetch"):
            assert (pid, lane) in names
    # intervals are X on the right lane-tid; instants are i
    x = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert x["prefill.chunk"]["pid"] == 0 and x["prefill.chunk"]["cat"] == "prefill"
    assert x["decode.step"]["pid"] == 1
    assert x["prefill.chunk"]["tid"] != x["decode.step"]["tid"]
    assert any(e["ph"] == "i" and e["name"] == "prefetch.dispatch"
               for e in evs)
    # the handoff flow: s on pid 0, f (bp="e") on pid 1, same id
    s = next(e for e in evs if e["ph"] == "s")
    f = next(e for e in evs if e["ph"] == "f")
    assert s["id"] == f["id"] == 7
    assert s["pid"] == 0 and f["pid"] == 1 and f["bp"] == "e"
    # timestamps are non-negative and rebased to the earliest span
    assert min(e["ts"] for e in evs if "ts" in e) == 0.0


def test_unpaired_flow_rejected():
    a, _ = _two_replica_recorders()
    trace = to_chrome_trace([a])        # restore end lives on recorder b
    errs = validate_trace(trace)
    assert errs and "unpaired" in errs[0]


def test_write_trace_roundtrip(tmp_path, setup):
    cfg, params, prompts = setup
    pool = ReplicaPool.build(
        cfg, params, policy="duo", max_batch=2, max_seq=32,
        prefill_budget=3, temperature=0.0, spans=True,
        overrides=[{"role": "prefill"}, {"role": "decode"}])
    fe = ClusterFrontend(pool, router="disagg")
    hs = [fe.submit(_spec(p)) for p in prompts[:2]]
    fe.drain()
    assert all(h.done for h in hs)
    out = tmp_path / "trace.json"
    write_trace(str(out), pool.recorders())
    trace = json.loads(out.read_text())
    assert validate_trace(trace) == []
    cats = {e.get("cat") for e in trace["traceEvents"]}
    assert {"prefill", "decode", "lifecycle", "handoff"} <= cats
    # the pool-level metrics snapshot validates too
    snap = pool.metrics_snapshot()
    assert validate_metrics_snapshot(snap) == []
    assert snap["cluster"]["cluster_handoffs_total"] == 2.0
    assert len(snap["replicas"]) == 2


# ---------------------------------------------------------------------------
# legacy views over the registry
# ---------------------------------------------------------------------------


def test_perf_counters_are_registry_views(setup):
    cfg, params, prompts = setup
    fe = _fe(cfg, params)
    h = fe.submit(_spec(prompts[0]))
    fe.drain()
    assert h.done
    eng = fe.engine
    # prefilled_tokens is a counter view and matches the offered prompt
    assert eng.prefilled_tokens == len(prompts[0])
    with pytest.raises(AttributeError):
        eng.prefilled_tokens = 0
    # PerfCounters fields read through the registry and reject writes
    assert eng.perf.decode_layers > 0
    with pytest.raises(AttributeError):
        eng.perf.decode_layers = 0
    snap = eng.metrics.snapshot()
    assert snap["engine_prefilled_tokens_total"] == float(len(prompts[0]))
    exp = eng.metrics.exposition()
    assert "# TYPE engine_prefilled_tokens_total counter" in exp
    assert math.isfinite(snap["decode_step_seconds"]["sum"])
