"""Cluster serving layer invariants (ISSUE 5 tentpole):

  * 1-replica parity — a 1-replica ClusterFrontend is bit-identical to a
    plain ServingFrontend at temperature 0 under EVERY router policy
    (parametrized over ROUTERS, so the PR-6 `disagg` router is covered
    too: on an all-role-"both" pool it degrades to least-loaded dispatch
    with no handoffs; its real prefill/decode split lives in
    tests/test_snapshot.py).
  * N-replica exactness + residency — every request served by any replica
    reproduces the single-engine reference tokens, and every replica's
    ExpertResidency keeps the full slot-pool/ledger invariants after every
    cluster poll (per-replica expert HBM stays at the fixed bound).
  * Router behaviour — least_loaded avoids the busy replica; slo_headroom
    rejects only when NO replica can meet the request's deadlines (terminal
    handle with RejectEvent("router_slo"), no engine queue touched).
  * Cancellation through the cluster frees the OWNING replica's KV slot and
    leaves survivors bit-exact.
  * QosAutopilot — attached to a plain ServingFrontend or a cluster, it
    sheds mid-flight requests whose TTFT/TBT deadline is unmeetable with
    FinishEvent(reason="slo_shed"), reclaiming resources synchronously;
    SLO-less survivors stay bit-exact.
"""
import time

import jax
import numpy as np
import pytest

from test_residency import assert_residency_invariants

from repro.configs.base import get_config, reduced
from repro.models.model import build
from repro.serving.api import (FinishEvent, GenerationRequest, RejectEvent,
                               SamplingParams)
from repro.serving.batching import BatchedServingEngine
from repro.serving.cluster import (ClusterFrontend, QosAutopilot,
                                   ReplicaPool, ROUTERS)
from repro.serving.frontend import ServingFrontend

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (12, 16, 9, 14)]
    # reference tokens from the plain PR-4 front-end (itself pinned
    # bit-exact to sequential serve() by tests/test_frontend.py)
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0,
                               prefill_budget=3)
    fe = ServingFrontend(eng)
    handles = [fe.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=MAX_NEW)))
        for p in prompts]
    fe.drain()
    refs = [list(h.tokens) for h in handles]
    return cfg, params, prompts, refs


def _pool(cfg, params, n, *, max_batch=2, prefill_budget=3, policy="duo"):
    return ReplicaPool.build(cfg, params, n, policy=policy,
                             max_batch=max_batch, max_seq=32,
                             temperature=0.0,
                             prefill_budget=prefill_budget)


def _specs(prompts, **kw):
    return [GenerationRequest(prompt=p,
                              params=SamplingParams(max_new_tokens=MAX_NEW),
                              **kw) for p in prompts]


# ---------------------------------------------------------------------------
# parity + exactness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("router", ROUTERS)
def test_one_replica_cluster_bit_exact(setup, router):
    """A 1-replica cluster is the plain front-end, bit for bit, whichever
    router fronts it."""
    cfg, params, prompts, refs = setup
    fe = ClusterFrontend(_pool(cfg, params, 1), router=router)
    handles = [fe.submit(s) for s in _specs(prompts)]
    fe.drain()
    assert fe.idle
    for h, ref in zip(handles, refs):
        assert h.replica == 0
        assert h.finish_reason == "length"
        assert list(h.tokens) == ref, f"{router} diverged"


def test_multi_replica_exactness_and_residency(setup):
    """2 replicas: per-poll residency invariants hold on EVERY replica, and
    each request — wherever it was routed — reproduces the single-engine
    reference tokens (row-wise exactness composes across replicas)."""
    cfg, params, prompts, refs = setup
    pool = _pool(cfg, params, 2)
    fe = ClusterFrontend(pool, router="least_loaded")
    handles = [fe.submit(s) for s in _specs(prompts)]
    for _ in range(300):
        fe.poll()
        for eng in pool.engines:
            assert_residency_invariants(eng.cache)
        if fe.idle:
            break
    assert fe.idle
    assert sum(len(e.finished) for e in pool.engines) == len(prompts)
    assert {h.replica for h in handles} == {0, 1}, \
        "least_loaded never spread the batch"
    for h, ref in zip(handles, refs):
        assert list(h.tokens) == ref, f"replica {h.replica} diverged"


# ---------------------------------------------------------------------------
# router behaviour
# ---------------------------------------------------------------------------


def test_least_loaded_avoids_busy_replica(setup):
    """Back-to-back submissions land on different replicas: the first loads
    replica 0, so the second must route to replica 1 (and a third goes to
    whichever is lighter — here the short prompt's replica)."""
    cfg, params, prompts, refs = setup
    pool = _pool(cfg, params, 2)
    fe = ClusterFrontend(pool, router="least_loaded")
    h0 = fe.submit(_specs([prompts[1]])[0])   # 16 tokens -> replica 0
    h1 = fe.submit(_specs([prompts[2]])[0])   # 9 tokens -> replica 1
    h2 = fe.submit(_specs([prompts[0]])[0])   # 12 -> lighter replica 1
    assert (h0.replica, h1.replica, h2.replica) == (0, 1, 1)
    fe.drain()
    assert list(h0.tokens) == refs[1]
    assert list(h1.tokens) == refs[2]
    assert list(h2.tokens) == refs[0]


def test_slo_headroom_rejects_only_when_no_replica_can_meet(setup):
    """With every replica's cost model pessimistic, a deadlined request is
    rejected AT THE ROUTER: terminal handle, RejectEvent("router_slo"), no
    engine queue ever sees it. An SLO-less request still routes."""
    cfg, params, prompts, refs = setup
    pool = _pool(cfg, params, 2)
    for eng in pool.engines:
        eng.queue.admission.model.prefill_per_token = 10.0
    fe = ClusterFrontend(pool, router="slo_headroom")
    doomed = fe.submit(GenerationRequest(
        prompt=prompts[0], params=SamplingParams(max_new_tokens=MAX_NEW),
        ttft_slo=0.5))
    assert doomed.done and doomed.finish_reason == "rejected"
    assert doomed.replica is None
    assert isinstance(doomed.events[0], RejectEvent)
    assert doomed.events[0].reason == "router_slo"
    assert all(len(e.queue) == 0 for e in pool.engines)
    assert fe.n_router_rejected == 1 and len(fe.router_rejected) == 1
    assert list(doomed) == []                    # iteration yields nothing
    with pytest.raises(RuntimeError):
        doomed.result()
    assert not doomed.cancel()                   # already terminal
    # headroom is per-request: no SLO -> +inf everywhere -> still served
    ok = fe.submit(_specs([prompts[2]])[0])
    assert ok.replica is not None
    fe.drain()
    assert list(ok.tokens) == refs[2]


def test_slo_headroom_routes_queue_band_instead_of_rejecting(setup):
    """When every replica's BACKLOG-inclusive prediction breaches but an
    immediate start would fit (admission's QUEUE band), the router must
    still route — rejection is reserved for deadlines hopeless everywhere
    even from an immediate start."""
    cfg, params, prompts, refs = setup
    pool = _pool(cfg, params, 2)
    for fe_i in pool.frontends:
        fe_i.submit(_specs([prompts[1]])[0])      # 16 queued tokens each
    for eng in pool.engines:
        eng.queue.admission.model.prefill_per_token = 0.3
        eng.queue.admission.model.decode_step = 0.01
    fe = ClusterFrontend(pool, router="slo_headroom")
    # prompt 9 @0.3s/tok: immediate ~2.7s fits the 5s SLO, with the 16
    # queued tokens ahead (~7.5s) it does not — QUEUE band, not REJECT
    spec = GenerationRequest(
        prompt=prompts[2], params=SamplingParams(max_new_tokens=MAX_NEW),
        ttft_slo=5.0)
    h = fe.submit(spec)
    assert h.replica is not None, "QUEUE-band request was router-rejected"
    assert fe.n_router_rejected == 0


def test_expert_affinity_prefers_warm_replica_until_overloaded(setup):
    """The affinity ranking itself: with equal load, the replica holding
    the likely-expert set resident wins; once that replica is overloaded
    past the gate, affinity defers to load. Pure routing logic — no engine
    steps run."""
    from repro.core.tracer import ExpertsTracer
    cfg, params, prompts, refs = setup
    rng = np.random.default_rng(7)
    tracer = ExpertsTracer(cfg.n_layers, cfg.n_experts, cfg.top_k)
    for _ in range(8):
        tracer.add_path(np.stack([
            rng.choice(cfg.n_experts, cfg.top_k, replace=False)
            for _ in range(cfg.n_layers)]))
    pool = ReplicaPool.build(cfg, params, 2, policy="duo",
                             stats=tracer.stats(), max_batch=2, max_seq=32,
                             temperature=0.0, prefill_budget=3)
    fe = ClusterFrontend(pool, router="expert_affinity")
    keys = pool.likely_keys()
    assert keys, "popularity prior should yield a non-empty likely set"
    # warm replica 1's residency with the likely set; replica 0 stays cold
    for key in keys:
        pool.engines[1].cache.admit(key, pinned=False)
    assert pool.engines[1].cache.residency_overlap(keys) == len(keys)
    assert pool.engines[0].cache.residency_overlap(keys) == 0
    spec = _specs([prompts[0]])[0]                       # 12-token prompt
    assert fe.router.choose(spec, pool, 0.0) == 1, \
        "equal load: the warm replica must win"
    # overload the warm replica (two queued 16-token prompts exceed the
    # overload gate: floor 0 + 2.0 * 12 = 24 < 32) -> load wins
    for _ in range(2):
        pool.frontends[1].submit(_specs([prompts[1]])[0])
    assert pool.engines[1].load().total_tokens > 24
    assert fe.router.choose(spec, pool, 0.0) == 0, \
        "overloaded warm replica must lose to the cold idle one"


# ---------------------------------------------------------------------------
# cancellation + autopilot
# ---------------------------------------------------------------------------


def test_cancel_through_cluster_frees_owning_slot(setup):
    cfg, params, prompts, refs = setup
    pool = _pool(cfg, params, 2)
    fe = ClusterFrontend(pool, router="round_robin")
    surv0, victim, surv1 = [fe.submit(s) for s in _specs(prompts[:3])]
    assert (surv0.replica, victim.replica, surv1.replica) == (0, 1, 0)
    while len(victim.tokens) < 2 and not victim.done:
        fe.poll()
    assert victim.cancel()
    owner = pool.engines[victim.replica]
    assert victim.done and victim.finish_reason == "cancelled"
    assert victim.req.slot in owner._free, "owning replica's slot not freed"
    for eng in pool.engines:
        assert_residency_invariants(eng.cache)
    fe.drain()
    assert list(surv0.tokens) == refs[0]
    assert list(surv1.tokens) == refs[2]
    assert victim.req.result().finish_reason == "cancelled"


def test_autopilot_tbt_shed_single_engine(setup):
    """The autopilot runs on a PLAIN ServingFrontend (ROADMAP SLO-aware
    cancellation item): a decoding request whose next-token TBT deadline
    has passed is shed with reason='slo_shed'; the SLO-less survivor is
    bit-exact."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0,
                               prefill_budget=3)
    fe = ServingFrontend(eng)
    ap = QosAutopilot(fe)
    assert fe.autopilot is ap
    survivor = fe.submit(_specs([prompts[0]])[0])
    victim = fe.submit(GenerationRequest(
        prompt=prompts[1], params=SamplingParams(max_new_tokens=MAX_NEW),
        tbt_slo=0.5))
    while len(victim.tokens) < 2 and not victim.done:
        fe.poll()
    # fabricated future clock: the next token's deadline is long past (the
    # poll's own decode step may still land one more token before the scan)
    ev = fe.poll(time.perf_counter() + 100.0)
    n_victim_tokens = len(victim.tokens)
    assert victim.done and victim.finish_reason == "slo_shed"
    # the shed termination is visible on the returned event stream too
    assert any(isinstance(e, FinishEvent) and e.reason == "slo_shed"
               and e.rid == victim.rid for e in ev)
    assert victim.status == "cancelled"
    assert ap.n_shed == 1 and ap.by_reason == {"ttft": 0, "tbt": 1}
    assert list(ap.shed) == [victim]
    assert eng.n_slo_shed == 1
    assert victim.req.slot in eng._free
    assert_residency_invariants(eng.cache)
    fe.drain()
    assert not survivor.done or survivor.finish_reason == "length"
    assert list(survivor.tokens) == refs[0], "shed perturbed the survivor"
    r = victim.req.result()
    assert r.finish_reason == "slo_shed"
    assert len(r.tokens) == n_victim_tokens    # partial output retained


def test_autopilot_ttft_shed_mid_prefill(setup):
    """A prefilling request whose predicted remaining prefill overruns its
    TTFT deadline is shed before ever emitting a token."""
    cfg, params, prompts, refs = setup
    pool = _pool(cfg, params, 1, prefill_budget=1)
    fe = ClusterFrontend(pool, router="least_loaded")
    # generous enough to be admitted (optimistic seed model), then blown
    victim = fe.submit(GenerationRequest(
        prompt=prompts[1], params=SamplingParams(max_new_tokens=MAX_NEW),
        ttft_slo=5.0))
    fe.poll()                                 # admit + first 1-token chunk
    assert victim.status == "prefilling" and not victim.tokens
    ap = QosAutopilot(fe)
    shed = ap.scan(time.perf_counter() + 100.0)
    assert shed == [victim]
    assert victim.finish_reason == "slo_shed"
    assert ap.by_reason == {"ttft": 1, "tbt": 0}
    eng = pool.engines[0]
    assert victim.req.slot in eng._free
    assert not eng.prefilling
    assert_residency_invariants(eng.cache)
    assert len(victim.req.result().tokens) == 0


def test_autopilot_sheds_queued_request(setup):
    """A QUEUED request (no KV slot yet) whose deadline passes is shed from
    the arrival queue; the running request is untouched."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=1,
                               max_seq=32, temperature=0.0)
    fe = ServingFrontend(eng)
    runner = fe.submit(_specs([prompts[0]])[0])
    fe.poll()                                 # runner takes the only slot
    queued = fe.submit(GenerationRequest(
        prompt=prompts[1], params=SamplingParams(max_new_tokens=MAX_NEW),
        ttft_slo=5.0))
    fe.poll()
    assert queued.status == "queued"
    ap = QosAutopilot(fe)
    ap.scan(time.perf_counter() + 100.0)
    assert queued.done and queued.finish_reason == "slo_shed"
    assert len(eng.queue) == 0
    fe.drain()
    assert list(runner.tokens) == refs[0]


def test_autopilot_preserves_admission_queue_band(setup):
    """A queued request whose deadline is reachable once the backlog
    drains (admission's QUEUE verdict: immediate-start prediction fits the
    SLO, backlog-inclusive does not) must NOT be shed — the autopilot
    mirrors the REJECT boundary, not the QUEUE one."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=1,
                               max_seq=32, temperature=0.0,
                               prefill_budget=1)
    fe = ServingFrontend(eng)
    runner = fe.submit(_specs([prompts[1]])[0])   # 16 tokens, chunk=1
    fe.poll()                                     # big prefill backlog left
    assert runner.req.state == "prefilling"
    backlog = runner.req.prefill_remaining
    assert backlog >= 10
    queued = fe.submit(GenerationRequest(
        prompt=prompts[2], params=SamplingParams(max_new_tokens=MAX_NEW),
        ttft_slo=5.0))
    # pin the cost model: own work (9 * 0.3s) fits the 5s deadline,
    # backlog-inclusive ((backlog + 9) * 0.3s) does not
    model = eng.queue.admission.model
    model.prefill_per_token, model.decode_step = 0.3, 0.01
    now = time.perf_counter()
    assert model.predict_prefill(queued.req.prompt_len) < 5.0
    assert model.predict_prefill(backlog + queued.req.prompt_len) > 5.0
    ap = QosAutopilot(fe)
    assert ap.scan(now) == []                     # QUEUE band: not shed
    assert queued.status == "queued" and not queued.done
    assert ap.scan(now + 100.0) == [queued]       # truly hopeless: shed
    assert queued.finish_reason == "slo_shed"
    fe.drain()
    assert list(runner.tokens) == refs[1]


# ---------------------------------------------------------------------------
# arrival generators (benchmarks satellite)
# ---------------------------------------------------------------------------


def test_arrival_generators():
    common = pytest.importorskip("benchmarks.common")
    rng = np.random.default_rng(0)
    n, rate = 4000, 2.0
    offs = {k: common.arrival_offsets(k, rate, n, np.random.default_rng(0))
            for k in common.ARRIVALS}
    for k, t in offs.items():
        assert t.shape == (n,)
        assert np.all(np.diff(t) >= 0), f"{k} offsets not monotonic"
        # mean offered rate is honored to ~10%
        assert n / t[-1] == pytest.approx(rate, rel=0.15), k
    # bursty clumps: inter-arrival CV far above the Poisson process's ~1
    def cv(t):
        d = np.diff(np.concatenate([[0.0], t]))
        return d.std() / d.mean()
    assert cv(offs["bursty"]) > 2 * cv(offs["poisson"])
    # ramp accelerates: later gaps are systematically shorter
    gaps = np.diff(np.concatenate([[0.0], offs["ramp"]]))
    assert gaps[: n // 4].mean() > 2 * gaps[-n // 4:].mean()
    with pytest.raises(KeyError):
        common.arrival_offsets("uniform", rate, n, rng)
