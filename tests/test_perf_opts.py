"""Correctness of the §Perf optimization paths — each must be numerically
equivalent (or within capacity-drop semantics) to the baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, get_config, reduced
from repro.models import moe_layer as M
from repro.models.layers import (attention_chunked, attention_chunked_windowed,
                                 attention)


def test_windowed_chunked_matches_masked():
    B, S, H, Hkv, D, W = 2, 96, 4, 2, 32, 24
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    got = attention_chunked_windowed(q, k, v, window=W, q_block=32,
                                     kv_block=16)
    want = attention(q, k, v, q_pos=jnp.arange(S)[None],
                     k_pos=jnp.arange(S)[None], window=W, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_windowed_chunked_window_larger_than_seq():
    B, S, H, D = 1, 40, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    got = attention_chunked_windowed(q, k, v, window=1024, q_block=16,
                                     kv_block=16)
    want = attention(q, k, v, q_pos=jnp.arange(S)[None],
                     k_pos=jnp.arange(S)[None], window=1024, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bf16_tiles_close_to_f32():
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    base = attention_chunked(q, k, v, q_block=32, kv_block=32,
                             bf16_tiles=False)
    opt = attention_chunked(q, k, v, q_block=32, kv_block=32, bf16_tiles=True)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), rtol=3e-2,
                               atol=3e-2)


def _moe_cfg(E=16, k=2, d=32, de=16):
    return ArchConfig(name="t", family="moe", n_layers=1, d_model=d,
                      n_heads=2, n_kv_heads=2, d_ff=de, vocab=64,
                      n_experts=E, top_k=k, d_expert=de)


def test_active_gather_matches_dense_when_a_covers():
    """active_max >= #active experts => identical to dense dispatch."""
    cfg = _moe_cfg()
    p = M.moe_params(jax.random.PRNGKey(0), cfg, n_model=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, cfg.d_model)) * 0.5
    w, ids, _ = M.route(x, p["router"], cfg.n_experts, cfg.top_k)
    dense = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                        capacity=12, e_start=0)
    # 6 tokens x k=2 -> at most 12 active experts; A=12 covers everything
    act = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                      capacity=12, e_start=0, active_max=12)
    np.testing.assert_allclose(np.asarray(act), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_active_gather_drops_only_overflow():
    """With A smaller than active set, output differs only on tokens routed
    to the least-loaded (dropped) experts; finite everywhere."""
    cfg = _moe_cfg(E=8, k=1)
    p = M.moe_params(jax.random.PRNGKey(0), cfg, n_model=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model)) * 0.5
    w, ids, _ = M.route(x, p["router"], cfg.n_experts, cfg.top_k)
    act = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                      capacity=16, e_start=0, active_max=4)
    assert np.isfinite(np.asarray(act)).all()


def test_active_gather_threshold():
    assert M.active_gather_max(4096, 8, 24, 384) is None  # large T: disabled
    import os
    os.environ["REPRO_OPT_ACTIVE_GATHER"] = "1"
    try:
        a = M.active_gather_max(8, 8, 24, 384)
        assert a is not None and 8 <= a <= 24
        assert M.active_gather_max(4096, 8, 24, 384) is None
    finally:
        os.environ["REPRO_OPT_ACTIVE_GATHER"] = "0"


def test_pattern_builder_matches_window_semantics():
    """Pattern-block gemma variant must produce the same logits as the
    standard scanned builder (same weights, different structure)."""
    from repro.models.model import build_dense, build_dense_pattern
    cfg = dataclasses.replace(reduced(get_config("gemma3_1b")), n_layers=4,
                              local_global_pattern=1, sliding_window=8)
    b1, b2 = build_dense(cfg), build_dense_pattern(cfg)
    p2 = b2.init(jax.random.PRNGKey(0))
    # remap pattern params [n_pat, per, ...] -> flat [L, ...]
    blocks = p2["blocks"]
    flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), blocks)
    p1 = {"embed": p2["embed"], "ln_f": p2["ln_f"], "layers": flat}
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    l1, _ = b1.forward(p1, {"tokens": toks})
    l2, _ = b2.forward(p2, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=6e-2,
                               atol=6e-2)


def test_moe_dispatch_pallas_kernel_parity(monkeypatch):
    """The Pallas expert_ffn kernel slot-in (REPRO_MOE_PALLAS) must match the
    einsum dispatch path bit-for-tolerance on the same capacity buffers."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    cfg = _moe_cfg(E=4, k=2, d=64, de=128)
    p = M.moe_params(jax.random.PRNGKey(0), cfg, n_model=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.d_model)) * 0.3
    w, ids, _ = M.route(x, p["router"], cfg.n_experts, cfg.top_k)
    base = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                       capacity=24, e_start=0)
    pk = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                     capacity=24, e_start=0, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(base), rtol=2e-3,
                               atol=2e-3)


def test_ring_cache_wraparound_exact():
    """Windowed ring-buffer decode must equal full-cache windowed attention
    even after the ring wraps several times (slot reuse + masking)."""
    import dataclasses as dc
    from repro.configs.base import get_config, reduced
    from repro.models.model import build, pad_cache
    cfg = dc.replace(reduced(get_config("zamba2_7b")), n_layers=2,
                     hybrid_attn_every=1, sliding_window=6)
    b = build(cfg)
    params = b.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 26), 0, cfg.vocab)
    # full teacher-forced forward (windowed masking, no ring buffer)
    full, _ = b.forward(params, {"tokens": toks})
    # prefill 6 then decode 20 steps through the W=6 ring (wraps 3x)
    _, cache = b.prefill(params, {"tokens": toks[:, :6]})
    logits = []
    for t in range(6, 26):
        lg, cache = b.decode_step(params, {"token": toks[:, t:t + 1]}, cache)
        logits.append(lg)
    got = np.stack([np.asarray(l, np.float32) for l in logits], 1)[0]
    want = np.asarray(full[0, 6:26], np.float32)
    np.testing.assert_allclose(got[:-1], want[:-1], rtol=6e-2, atol=6e-2)
