"""Correctness of the §Perf optimization paths — each must be numerically
equivalent (or within capacity-drop semantics) to the baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, get_config, reduced
from repro.models import moe_layer as M
from repro.models.layers import (attention_chunked, attention_chunked_windowed,
                                 attention)


def test_windowed_chunked_matches_masked():
    B, S, H, Hkv, D, W = 2, 96, 4, 2, 32, 24
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    got = attention_chunked_windowed(q, k, v, window=W, q_block=32,
                                     kv_block=16)
    want = attention(q, k, v, q_pos=jnp.arange(S)[None],
                     k_pos=jnp.arange(S)[None], window=W, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_windowed_chunked_window_larger_than_seq():
    B, S, H, D = 1, 40, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    got = attention_chunked_windowed(q, k, v, window=1024, q_block=16,
                                     kv_block=16)
    want = attention(q, k, v, q_pos=jnp.arange(S)[None],
                     k_pos=jnp.arange(S)[None], window=1024, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bf16_tiles_close_to_f32():
    B, S, H, D = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    base = attention_chunked(q, k, v, q_block=32, kv_block=32,
                             bf16_tiles=False)
    opt = attention_chunked(q, k, v, q_block=32, kv_block=32, bf16_tiles=True)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), rtol=3e-2,
                               atol=3e-2)


def _moe_cfg(E=16, k=2, d=32, de=16):
    return ArchConfig(name="t", family="moe", n_layers=1, d_model=d,
                      n_heads=2, n_kv_heads=2, d_ff=de, vocab=64,
                      n_experts=E, top_k=k, d_expert=de)


def test_active_gather_matches_dense_when_a_covers():
    """active_max >= #active experts => identical to dense dispatch."""
    cfg = _moe_cfg()
    p = M.moe_params(jax.random.PRNGKey(0), cfg, n_model=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, cfg.d_model)) * 0.5
    w, ids, _ = M.route(x, p["router"], cfg.n_experts, cfg.top_k)
    dense = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                        capacity=12, e_start=0)
    # 6 tokens x k=2 -> at most 12 active experts; A=12 covers everything
    act = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                      capacity=12, e_start=0, active_max=12)
    np.testing.assert_allclose(np.asarray(act), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_active_gather_drops_only_overflow():
    """With A smaller than active set, output differs only on tokens routed
    to the least-loaded (dropped) experts; finite everywhere."""
    cfg = _moe_cfg(E=8, k=1)
    p = M.moe_params(jax.random.PRNGKey(0), cfg, n_model=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model)) * 0.5
    w, ids, _ = M.route(x, p["router"], cfg.n_experts, cfg.top_k)
    act = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                      capacity=16, e_start=0, active_max=4)
    assert np.isfinite(np.asarray(act)).all()


def test_active_gather_threshold():
    assert M.active_gather_max(4096, 8, 24, 384) is None  # large T: disabled
    import os
    os.environ["REPRO_OPT_ACTIVE_GATHER"] = "1"
    try:
        a = M.active_gather_max(8, 8, 24, 384)
        assert a is not None and 8 <= a <= 24
        assert M.active_gather_max(4096, 8, 24, 384) is None
    finally:
        os.environ["REPRO_OPT_ACTIVE_GATHER"] = "0"


def test_pattern_builder_matches_window_semantics():
    """Pattern-block gemma variant must produce the same logits as the
    standard scanned builder (same weights, different structure)."""
    from repro.models.model import build_dense, build_dense_pattern
    cfg = dataclasses.replace(reduced(get_config("gemma3_1b")), n_layers=4,
                              local_global_pattern=1, sliding_window=8)
    b1, b2 = build_dense(cfg), build_dense_pattern(cfg)
    p2 = b2.init(jax.random.PRNGKey(0))
    # remap pattern params [n_pat, per, ...] -> flat [L, ...]
    blocks = p2["blocks"]
    flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), blocks)
    p1 = {"embed": p2["embed"], "ln_f": p2["ln_f"], "layers": flat}
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    l1, _ = b1.forward(p1, {"tokens": toks})
    l2, _ = b2.forward(p2, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=6e-2,
                               atol=6e-2)


def test_moe_dispatch_pallas_kernel_parity(monkeypatch):
    """The Pallas expert_ffn kernel slot-in (REPRO_MOE_PALLAS) must match the
    einsum dispatch path bit-for-tolerance on the same capacity buffers."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    cfg = _moe_cfg(E=4, k=2, d=64, de=128)
    p = M.moe_params(jax.random.PRNGKey(0), cfg, n_model=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.d_model)) * 0.3
    w, ids, _ = M.route(x, p["router"], cfg.n_experts, cfg.top_k)
    base = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                       capacity=24, e_start=0)
    pk = M._dispatch_compute_combine(x, w, ids, p["w1"], p["w3"], p["w2"],
                                     capacity=24, e_start=0, use_pallas=True)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(base), rtol=2e-3,
                               atol=2e-3)


# -- sparse grouped-expert execution (serving engines) ---------------------

@pytest.fixture(scope="module")
def moe_serving_setup():
    from repro.models.model import build
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    return cfg, bundle.init(jax.random.PRNGKey(0))


def test_group_by_expert_patterns():
    """Host-side dispatch construction: duplicate-expert rows (all rows the
    same picks), fully disjoint picks, and Cmax bucketing."""
    from repro.serving.engine import group_by_expert
    # all rows pick the same two experts -> two maximal groups of size B
    ids = np.tile(np.array([[2, 5]], np.int32), (4, 1))
    d = group_by_expert(ids, [2, 5], bucket_cap=4)
    assert d.counts == [4, 4] and d.n_rows == 8 and d.n_launched == 8
    np.testing.assert_array_equal(d.row_idx, [[0, 1, 2, 3], [0, 1, 2, 3]])
    np.testing.assert_array_equal(d.u_of, [[0, 1]] * 4)
    np.testing.assert_array_equal(d.c_of, [[0, 0], [1, 1], [2, 2], [3, 3]])
    # fully disjoint picks -> 2B singleton groups, bucketed capacity 1
    ids = np.arange(8, dtype=np.int32).reshape(4, 2)
    d = group_by_expert(ids, list(range(8)), bucket_cap=4)
    assert d.counts == [1] * 8 and d.row_idx.shape == (8, 1)
    assert d.n_rows == 8 and d.n_launched == 8
    # mixed loads: Cmax=3 buckets up to 4 (power of two), padding rows 0
    ids = np.array([[0, 1], [0, 2], [0, 1], [3, 1]], np.int32)
    d = group_by_expert(ids, [0, 1, 2, 3], bucket_cap=4)
    assert d.counts == [3, 3, 1, 1]
    assert d.row_idx.shape == (4, 4) and d.n_rows == 8 and d.n_launched == 16
    # scatter inversion: row t's j-th choice lands at (u_of, c_of)
    for t in range(4):
        for j in range(2):
            u, c = d.u_of[t, j], d.c_of[t, j]
            assert d.row_idx[u, c] == t and c < d.counts[u]


def test_grouped_raw_bitexact_vs_dense(moe_serving_setup):
    """The one-launch grouped einsum must reproduce the dense full-batch
    expert_raw rows BIT-exactly (same dtypes, same per-row contraction) —
    the invariant the grouped decode path's exactness rests on."""
    from repro.serving.batching import BatchedServingEngine
    from repro.serving.engine import group_by_expert
    cfg, params = moe_serving_setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=4,
                               max_seq=32, temperature=0.0)
    B, d, de, cap = 4, cfg.d_model, cfg.d_expert, 6
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    xn = jax.random.normal(ks[0], (B, 1, d), jnp.bfloat16)
    w1p = jax.random.normal(ks[1], (cap, d, de), jnp.bfloat16) * 0.05
    w3p = jax.random.normal(ks[2], (cap, d, de), jnp.bfloat16) * 0.05
    w2p = jax.random.normal(ks[3], (cap, de, d), jnp.bfloat16) * 0.05
    ids = np.array([[0, 1], [0, 1], [2, 0], [1, 2]], np.int32)
    union = [0, 1, 2]   # "expert" e lives in pool slot e here
    disp = group_by_expert(ids, union, bucket_cap=B)
    grouped = np.asarray(eng._grouped_raw(
        xn, jnp.asarray(disp.row_idx), w1p, w3p, w2p,
        jnp.asarray(union, jnp.int32)))
    for u, e in enumerate(union):
        dense = np.asarray(eng._expert_raw(xn, w1p, w3p, w2p, jnp.int32(e)))
        for c in range(disp.counts[u]):
            np.testing.assert_array_equal(
                grouped[u, c], dense[disp.row_idx[u, c]],
                err_msg=f"group {u} row {c} not bit-equal to dense")


@pytest.mark.parametrize("chunk", [None, 3])
def test_fused_prefill_bit_exact_single_launch(moe_serving_setup, chunk):
    """fused_prefill=True: one grouped FFN launch per layer visit, tokens
    and per-layer active-expert sets bit-identical to the per-expert
    sweep — monolithic and chunked."""
    from repro.serving.engine import MoEServingEngine
    cfg, params = moe_serving_setup
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, size=13).astype(np.int32)
    base = MoEServingEngine(cfg, params, policy="duo", temperature=0.0,
                            prefill_chunk=chunk)
    fused = MoEServingEngine(cfg, params, policy="duo", temperature=0.0,
                             prefill_chunk=chunk, fused_prefill=True)
    rb = base.serve(prompt, max_new=4)
    rf = fused.serve(prompt, max_new=4)
    np.testing.assert_array_equal(rf.tokens, rb.tokens)
    assert rf.prefill_active == rb.prefill_active
    assert fused.perf.prefill_ffn_launches == fused.perf.prefill_moe_layers
    assert fused.perf.max_prefill_launches_per_layer == 1
    assert base.perf.max_prefill_launches_per_layer > 1


def test_grouped_ffn_pallas_backend_runs(monkeypatch, moe_serving_setup):
    """REPRO_OPT_GROUPED_FFN=1 routes both grouped sweeps through the
    Pallas pool kernel (interpret mode on CPU) and defaults fused prefill
    on: the engine must run end to end with one FFN launch per decode
    layer and per prefill layer under the expert-HBM bound. Numerics are
    kernel-grade (f32 accumulation — pinned by the interpret parity tests
    in test_kernels.py), so tokens are not compared bit-wise here."""
    monkeypatch.setenv("REPRO_OPT_GROUPED_FFN", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    from repro.serving.batching import BatchedServingEngine
    cfg, params = moe_serving_setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0, prefill_budget=4)
    assert eng.fused_prefill and eng._grouped_pallas
    rng = np.random.default_rng(5)
    for n in (9, 12):
        eng.submit(rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                   max_new=3)
    finished = eng.run_until_drained()
    assert len(finished) == 2
    for r in finished:
        toks = r.result().tokens
        assert toks.shape == (4,) and np.isfinite(toks).all()
    assert eng.perf.decode_ffn_launches == eng.perf.decode_layers
    assert eng.perf.max_prefill_launches_per_layer == 1
    assert eng.cache.hbm_bound_ok


def test_decode_expert_flops_accounting():
    """benchmarks/roofline sparse accounting: grouped = sum of per-expert
    selecting-row counts, dense = distinct experts x full batch."""
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline import decode_expert_flops, expert_flops_per_row
    cfg = _moe_cfg(E=8, k=2, d=32, de=16)
    sel = np.array([[0, 1], [0, 1], [2, 0], [3, 4]], np.int32)
    out = decode_expert_flops(cfg, sel)
    # distinct = {0,1,2,3,4} -> dense 5*4 rows; selecting rows: e0 in 3
    # rows, e1 in 2, e2/e3/e4 in 1 each -> 8 (== B*k, no within-row dups)
    assert out["dense_rows"] == 20 and out["grouped_rows"] == 8
    per = expert_flops_per_row(cfg)
    assert out["dense_flops"] == 20 * per
    assert out["grouped_flops"] == 8 * per
    # duplicate-heavy batch: all rows same picks -> dense == grouped
    sel = np.tile(np.array([[5, 6]], np.int32), (4, 1))
    out = decode_expert_flops(cfg, sel)
    assert out["dense_rows"] == out["grouped_rows"] == 8


def test_ring_cache_wraparound_exact():
    """Windowed ring-buffer decode must equal full-cache windowed attention
    even after the ring wraps several times (slot reuse + masking)."""
    import dataclasses as dc
    from repro.configs.base import get_config, reduced
    from repro.models.model import build, pad_cache
    cfg = dc.replace(reduced(get_config("zamba2_7b")), n_layers=2,
                     hybrid_attn_every=1, sliding_window=6)
    b = build(cfg)
    params = b.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 26), 0, cfg.vocab)
    # full teacher-forced forward (windowed masking, no ring buffer)
    full, _ = b.forward(params, {"tokens": toks})
    # prefill 6 then decode 20 steps through the W=6 ring (wraps 3x)
    _, cache = b.prefill(params, {"tokens": toks[:, :6]})
    logits = []
    for t in range(6, 26):
        lg, cache = b.decode_step(params, {"token": toks[:, t:t + 1]}, cache)
        logits.append(lg)
    got = np.stack([np.asarray(l, np.float32) for l in logits], 1)[0]
    want = np.asarray(full[0, 6:26], np.float32)
    np.testing.assert_allclose(got[:-1], want[:-1], rtol=6e-2, atol=6e-2)


def test_group_by_expert_u_bucketing():
    """u_bucket_cap pads the GROUP dimension to the next power of two
    (clamped to the cap) without disturbing any real group's contents:
    padding rows gather row 0 and are never scattered back (counts, u_of,
    c_of cover only the real groups)."""
    from repro.serving.engine import group_by_expert
    ids = np.array([[0, 1], [0, 2], [0, 1], [2, 1]], np.int32)
    union = [0, 1, 2]  # 3 distinct experts -> pads to 4 groups
    exact = group_by_expert(ids, union, bucket_cap=4)
    padded = group_by_expert(ids, union, bucket_cap=4, u_bucket_cap=8)
    assert exact.row_idx.shape[0] == 3          # None keeps exact U
    assert padded.row_idx.shape[0] == 4         # next pow2 >= 3
    assert padded.counts == exact.counts        # real groups untouched
    np.testing.assert_array_equal(padded.row_idx[:3], exact.row_idx)
    np.testing.assert_array_equal(padded.u_of, exact.u_of)
    np.testing.assert_array_equal(padded.c_of, exact.c_of)
    np.testing.assert_array_equal(padded.row_idx[3], 0)  # pad gathers row 0
    assert padded.n_rows == exact.n_rows        # accounting excludes pads
    # cap clamps below the next power of two
    clamped = group_by_expert(ids, union, bucket_cap=4, u_bucket_cap=3)
    assert clamped.row_idx.shape[0] == 3


def test_decode_recompile_bound_olog(moe_serving_setup):
    """Serving sweep across B in {1..8} x naturally varying U: the grouped
    decode FFN's distinct jit compilations stay within the enumerated
    (B, U_pad, C) key set and the O(log B)*O(log U) bound — the recompile
    discipline repro.analysis audits statically, asserted here against the
    LIVE jit cache-miss counter."""
    from repro.analysis.jaxpr_audit import (compile_key_bound,
                                            enumerate_grouped_keys)
    from repro.serving.batching import BatchedServingEngine
    cfg, params = moe_serving_setup
    MB = 8
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=MB,
                               max_seq=48, temperature=0.0,
                               fused_prefill=False)
    orig = eng._grouped_raw
    sigs = []

    def spy(xn, jrows, *pools_and_slots):
        sigs.append((tuple(xn.shape), tuple(jrows.shape)))
        return orig(xn, jrows, *pools_and_slots)

    eng._grouped_raw = spy
    rng = np.random.default_rng(3)
    for b in range(MB):
        prompt = rng.integers(0, cfg.vocab, size=4 + b).astype(np.int32)
        eng.submit(prompt, max_new=b + 1)   # distinct lifetimes: B walks
    eng.run_until_drained()                 # 8 -> 1 as requests retire
    eng._grouped_raw = orig

    assert sigs, "grouped decode path never ran"
    keys = set()
    for (B, one, _d), (u_pad, c) in sigs:
        assert one == 1, "non-decode launch leaked through _grouped_raw"
        keys.add((B, u_pad, c))
    seen_B = {key[0] for key in keys}
    assert seen_B == set(range(1, MB + 1)), f"sweep missed batch sizes: {seen_B}"
    # every observed key is one the static auditor enumerates, and the
    # distinct-count respects the paper-claim bound
    legal = enumerate_grouped_keys(MB, eng.E, eng.k)
    assert keys <= legal, f"stray compile keys: {sorted(keys - legal)}"
    bound = compile_key_bound(MB, eng.E, eng.k)
    assert len(keys) <= bound
    # pow2-or-clamp discipline on the padded dims
    for B, u_pad, c in keys:
        ucap = min(eng.E, B * eng.k)
        assert u_pad == ucap or (u_pad & (u_pad - 1)) == 0
        assert c == B or (c & (c - 1)) == 0
    # the LIVE cache-miss counter: one compilation per distinct signature
    if hasattr(orig, "_cache_size"):
        assert orig._cache_size() == len(set(sigs)) <= bound
