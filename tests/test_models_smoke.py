"""Per-architecture smoke tests: reduced variant of each assigned family runs
one forward pass + one prefill->decode step on CPU; asserts shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models.layers import vocab_pad_of
from repro.models.model import build, pad_cache

B, S = 2, 16


def make_batch(cfg, key):
    kt, kf = jax.random.split(key)
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            kf, (B, cfg.n_frontend_tokens, cfg.frontend_dim))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, S, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    vp = vocab_pad_of(cfg.vocab)
    logits, aux = jax.jit(bundle.forward)(params, batch)
    assert logits.shape == (B, S, vp)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    last, cache = jax.jit(bundle.prefill)(params, batch)
    assert last.shape == (B, vp)
    assert np.isfinite(np.asarray(last, np.float32)).all()
    # teacher-forced forward and prefill must agree on the last position
    np.testing.assert_allclose(np.asarray(logits[:, -1], np.float32),
                               np.asarray(last, np.float32), rtol=2e-2, atol=2e-2)

    step = {"token": jnp.argmax(last, -1, keepdims=True).astype(jnp.int32)}
    cache = pad_cache(cache, S + 8, bundle.ring_axes)
    lg2, cache2 = jax.jit(bundle.decode_step)(params, step, cache)
    assert lg2.shape == (B, vp)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mixtral_8x7b", "mamba2_2_7b"])
def test_decode_matches_forward(arch):
    """Decode step at position S must equal teacher-forced logits at S given
    the same prefix — the KV/state cache path is exact."""
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)

    full, _ = bundle.forward(params, {"tokens": tokens})
    _, cache = bundle.prefill(params, {"tokens": tokens[:, :S]})
    cache = pad_cache(cache, S + 8, bundle.ring_axes)
    lg, _ = bundle.decode_step(params, {"token": tokens[:, S:S + 1]}, cache)
    np.testing.assert_allclose(np.asarray(full[:, S], np.float32),
                               np.asarray(lg, np.float32), rtol=5e-2, atol=5e-2)
