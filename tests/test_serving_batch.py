"""Continuous-batching correctness: batched decode must be bit-exact vs the
single-request engine at temperature 0, chunked prefill must be bit-exact vs
monolithic for EVERY chunk size (including mid-prefill decode interleaving),
and the shared-cache ledger must count distinct experts per step
(decode-plan union semantics). Also the typed-API layer: SamplingParams
plumbing, stop-token early termination, priority admission order, srf
prefill fairness, per-request tbt_slo admission, and the step() event
stream (streaming equivalence + cancellation live in test_frontend.py)."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.qos import (Admission, AdmissionController, LatencyModel,
                            TBTLedger)
from repro.core.scheduler import union_selection
from repro.models.model import build
from repro.serving.api import (FinishEvent, GenerationRequest, RejectEvent,
                               SamplingParams, TokenEvent)
from repro.serving.batching import (BatchedServingEngine, Request,
                                    RequestQueue)
from repro.serving.engine import MoEServingEngine

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (12, 16, 9, 14)]
    seq = MoEServingEngine(cfg, params, policy="duo", temperature=0.0)
    refs = [seq.serve(p, max_new=MAX_NEW) for p in prompts]
    return cfg, params, prompts, refs


@pytest.mark.parametrize("B", [1, 2, 4])
def test_batched_matches_sequential(setup, B):
    """B concurrent requests produce exactly the tokens B sequential
    single-request serve() calls produce (greedy)."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=B,
                               max_seq=32, temperature=0.0)
    for p in prompts[:B]:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == B
    assert len(eng.decode_batch_hist) == MAX_NEW
    assert max(eng.decode_batch_hist) == B
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens,
                                      err_msg=f"request {i} diverged")


def test_midflight_admission_matches_sequential(setup):
    """More requests than KV slots: later arrivals are admitted as slots
    free up mid-flight, still bit-exact."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0)
    for p in prompts:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == len(prompts)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens)


@pytest.mark.parametrize("policy", ["odf", "lfp", "duo", "duo+"])
def test_policies_identical_tokens_batched(setup, policy):
    """Scheduling policy must never change batched outputs either."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy=policy, max_batch=2,
                               max_seq=32, temperature=0.0)
    for p in prompts[:2]:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens,
                                      err_msg=f"{policy} diverged")


def test_shared_cache_accounting(setup):
    """Per step+layer the scheduler ledger counts each DISTINCT selected
    expert exactly once; per request every selected expert lands in exactly
    one of {hits, misses}."""
    cfg, params, prompts, _ = setup
    B = 4
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=B,
                               max_seq=32, temperature=0.0)
    for p in prompts[:B]:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)

    traces = [r.result().decode_trace for r in finished]  # [T, L, k] each
    expected = 0
    for t in range(MAX_NEW):
        for l in range(cfg.n_layers):
            union = set()
            for tr in traces:
                union.update(int(e) for e in tr[t, l])
            expected += len(union)
    assert eng.sched.decode_hits + eng.sched.decode_misses == expected
    # per-request attribution covers exactly its own selections
    for r in finished:
        assert r.hits + r.misses == MAX_NEW * cfg.n_layers * cfg.top_k
    # attribution can only multiply-count shared experts, never lose them
    assert sum(r.hits + r.misses for r in finished) >= expected
    # the batch-scaled cache capacity absorbs one step's churn without the
    # everything-pinned overflow branch silently growing the cache
    assert eng.sched.cache.capacity >= 2 * B * cfg.top_k
    assert eng.sched.cache.peak_resident <= eng.sched.cache.capacity


@pytest.mark.parametrize("chunk", [1, 3, "S"])
def test_chunked_prefill_bit_exact(setup, chunk):
    """Chunked prefill (any chunk size) yields bit-identical tokens AND
    identical per-layer active-expert sets vs monolithic prefill."""
    cfg, params, prompts, refs = setup
    for p, ref in zip(prompts[:2], refs[:2]):
        size = len(p) if chunk == "S" else chunk
        eng = MoEServingEngine(cfg, params, policy="duo", temperature=0.0,
                               prefill_chunk=size)
        r = eng.serve(p, max_new=MAX_NEW)
        np.testing.assert_array_equal(r.tokens, ref.tokens,
                                      err_msg=f"chunk={size} diverged")
        assert r.prefill_active == ref.prefill_active, \
            f"chunk={size}: per-layer active-expert sets differ"


def test_chunked_batched_bit_exact(setup):
    """The chunked continuous-batching pipeline (prefill_budget) produces
    the monolithic engine's tokens for every request."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=4,
                               max_seq=32, temperature=0.0, prefill_budget=4)
    for p in prompts:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == len(prompts)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens)
        assert r.prefill_active == refs[i].prefill_active


def test_rr_fairness_bit_exact_and_interleaved(setup):
    """Round-robin chunked prefill (default) still emits exactly the
    monolithic tokens/active-sets per request, and overlapping prefills
    make interleaved progress (the per-step budget rotates) instead of
    strict head-of-line."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=4,
                               max_seq=32, temperature=0.0,
                               prefill_budget=4, prefill_fairness="rr")
    for p in prompts:
        eng.submit(p, max_new=MAX_NEW)
    eng.step()          # admit all 4; budget goes to request 0 this step
    assert {r.rid: r.prefill_pos for r in eng.prefilling} == \
        {0: 4, 1: 0, 2: 0, 3: 0}
    eng.step()          # rotation: request 1's turn
    assert {r.rid: r.prefill_pos for r in eng.prefilling} == \
        {0: 4, 1: 4, 2: 0, 3: 0}
    eng.step()          # request 2 (9 tokens remains prefilling at pos 4)
    assert {r.rid: r.prefill_pos for r in eng.prefilling} == \
        {0: 4, 1: 4, 2: 4, 3: 0}
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == len(prompts)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens)
        assert r.prefill_active == refs[i].prefill_active


def test_fifo_fairness_head_of_line(setup):
    """prefill_fairness='fifo' restores the old discipline: the whole
    budget goes to the head request."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=4,
                               max_seq=32, temperature=0.0,
                               prefill_budget=4, prefill_fairness="fifo")
    for p in prompts:
        eng.submit(p, max_new=MAX_NEW)
    eng.step()
    by_rid = {r.rid: r.prefill_pos for r in eng.prefilling}
    assert by_rid[0] == 4 and all(v == 0 for k, v in by_rid.items() if k)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens)


def test_auto_budget_tracks_latency_model(setup):
    """prefill_budget='auto' sizes chunks from the live LatencyModel so one
    chunk + one decode step fits the TBT SLO — and stays bit-exact."""
    from repro.core.qos import LatencyModel
    m = LatencyModel(prefill_per_token=0.01, decode_step=0.05)
    assert m.suggest_chunk(0.25) == 20          # (0.25 - 0.05) / 0.01
    assert m.suggest_chunk(0.04) == 1           # unmeetable -> floor
    assert m.suggest_chunk(1e9, ceiling=64) == 64

    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=4,
                               max_seq=32, temperature=0.0,
                               prefill_budget="auto", tbt_slo=0.5)
    assert eng.chunked
    assert eng._current_budget() >= 1
    for p in prompts:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == len(prompts)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens)
        assert r.prefill_active == refs[i].prefill_active
    with pytest.raises(AssertionError):
        BatchedServingEngine(cfg, params, max_batch=2, max_seq=32,
                             prefill_budget="auto")   # no tbt_slo


def test_finished_window_bounds_retention(setup):
    """finished_window keeps only the most recent N request records."""
    cfg, params, prompts, _ = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0,
                               finished_window=2)
    for p in prompts:
        eng.submit(p, max_new=2)
    eng.run_until_drained()
    assert len(eng.finished) == 2
    assert [r.rid for r in eng.finished] == [2, 3]   # most recent survive


def test_chunked_interleaving_is_stall_free(setup):
    """While a long prompt prefills in chunks, an in-flight decoder keeps
    producing tokens every step — and both stay bit-exact."""
    cfg, params, prompts, refs = setup
    budget = 4
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0,
                               prefill_budget=budget)
    decoder = eng.submit(prompts[2], max_new=MAX_NEW)   # 9 tokens
    while decoder.state != "running":
        eng.step()
    long = eng.submit(prompts[1], max_new=MAX_NEW)      # 16 tokens
    chunk_steps = 0
    decoded_during_prefill = 0
    while long.state in ("queued", "prefilling"):
        before = len(decoder.tokens)
        eng.step()
        chunk_steps += 1
        if not decoder.done:
            assert len(decoder.tokens) == before + 1, \
                "decoder stalled during a prefill chunk step"
            decoded_during_prefill += 1
    # 16 tokens / budget 4 -> 4 chunk iterations, decode advancing in each
    assert chunk_steps == -(-long.prompt_len // budget)
    assert decoded_during_prefill >= 1
    eng.run_until_drained()
    np.testing.assert_array_equal(decoder.result().tokens, refs[2].tokens)
    np.testing.assert_array_equal(long.result().tokens, refs[1].tokens)
    assert long.prefill_active == refs[1].prefill_active


def test_tbt_ledger_gaps():
    led = TBTLedger()
    led.observe(0, 1.0)
    led.observe(0, 1.5)
    led.observe(1, 2.0)
    led.observe(0, 3.0)
    led.observe(1, 2.25)
    assert list(led.by_rid[0]) == [0.5, 1.5]
    assert list(led.by_rid[1]) == [0.25]
    assert led.max_gap() == 1.5
    led.close(0)
    led.observe(0, 9.0)       # fresh baseline after close: no gap recorded
    assert list(led.by_rid[0]) == [0.5, 1.5]
    rep = led.report()
    assert rep["max"] == 1.5 and rep["p50"] <= rep["p99"]
    assert rep["n"] == 3


def test_tbt_ledger_windowed_retention():
    """Raw samples are bounded by the window; lifetime max/count and the
    streaming sketches survive eviction (ROADMAP retention item)."""
    led = TBTLedger(window=8, per_rid_window=4)
    t = 0.0
    for i in range(100):
        t += 0.010 if i != 50 else 5.0    # one huge stall mid-stream
        led.observe(0, t)
    assert len(led.gaps) == 8             # bounded
    assert len(led.by_rid[0]) == 4
    assert led.total_gaps == 99
    assert led.max_gap() == 5.0           # lifetime max survived eviction
    rep = led.report()
    assert rep["n"] == 99
    # the windowed p50 only sees recent 10ms gaps; the stream sketch saw
    # everything and stays in the data's range
    assert rep["p50"] == pytest.approx(0.010, rel=1e-6)
    assert 0.0 < rep["p50_stream"] <= 5.0


def test_tbt_ledger_bounds_closed_request_dict():
    """close() enrolls requests in a bounded FIFO: the by_rid DICT itself
    cannot grow without bound as requests churn (the leak is per-request
    deques accumulating, not just samples within one deque)."""
    led = TBTLedger(closed_window=3)
    for rid in range(10):
        led.observe(rid, 0.0)
        led.observe(rid, 0.1)
        led.close(rid)
    assert len(led.by_rid) == 3
    assert sorted(led.by_rid) == [7, 8, 9]      # most recently closed kept
    assert led.total_gaps == 10                  # lifetime counters intact
    # closed_window=None keeps everything (benchmark mode)
    exact = TBTLedger(closed_window=None)
    for rid in range(5):
        exact.observe(rid, 0.0)
        exact.observe(rid, 0.1)
        exact.close(rid)
    assert len(exact.by_rid) == 5


def test_p2_sketch_tracks_percentiles():
    from repro.core.qos import P2Quantile
    rng = np.random.default_rng(0)
    xs = rng.exponential(1.0, size=20_000)
    p50, p99 = P2Quantile(0.5), P2Quantile(0.99)
    for x in xs:
        p50.update(float(x))
        p99.update(float(x))
    assert p50.value() == pytest.approx(np.percentile(xs, 50), rel=0.05)
    assert p99.value() == pytest.approx(np.percentile(xs, 99), rel=0.10)
    # tiny-sample fallback is the exact empirical percentile
    small = P2Quantile(0.5)
    for x in (3.0, 1.0, 2.0):
        small.update(x)
    assert small.value() == 2.0


def test_union_selection_shapes():
    assert union_selection([3, 1, 2]) == [3, 1, 2]
    assert union_selection([[3, 1], [1, 2]]) == [3, 1, 2]
    assert union_selection([np.array([5, 0]), [0, 5]]) == [5, 0]
    assert union_selection([]) == []


def test_admission_queue_verdict_keeps_fifo():
    """Backlog-only breach -> QUEUE: the request stays at the head instead
    of being shed, and admission stops for the round (FIFO preserved)."""
    from repro.serving.batching import Request
    ctl = AdmissionController(
        LatencyModel(prefill_per_token=0.1, decode_step=0.0),
        default_ttft_slo=2.0)
    assert ctl.decide(0.0, 0.0, 16, 0) is Admission.ADMIT
    assert ctl.decide(0.0, 0.0, 16, 16) is Admission.QUEUE   # backlog breach
    assert ctl.decide(0.0, 0.0, 40, 0) is Admission.REJECT   # hopeless

    q = RequestQueue(ctl)
    sp = SamplingParams(max_new_tokens=2)
    r0 = Request(rid=0, prompt=np.zeros(16, np.int32), params=sp, arrival=0.0)
    r1 = Request(rid=1, prompt=np.zeros(16, np.int32), params=sp, arrival=0.0)
    q.submit(r0)
    q.submit(r1)
    admitted = q.pop_admissible(now=0.0, limit=2)
    assert [r.rid for r in admitted] == [0]
    assert len(q.pending) == 1 and q.pending[0].rid == 1
    assert not q.rejected
    # backlog drained -> the queued request admits on the next round
    assert [r.rid for r in q.pop_admissible(now=0.0, limit=2)] == [1]


def test_admission_folds_decode_load():
    """A chunked engine interleaves one batched decode step per chunk
    iteration, so predicted TTFT charges decode interference per iteration
    when decoders are running — admission no longer under-predicts under
    high decode concurrency. Monolithic prefill runs all same-round admits
    inside ONE iteration, so it keeps the single drain step."""
    ctl = AdmissionController(
        LatencyModel(prefill_per_token=0.1, decode_step=0.5))
    base = ctl.predict_ttft(0.0, 0.0, 10, 0)
    assert base == pytest.approx(0.1 * 10 + 0.5)      # one drain step
    busy = ctl.predict_ttft(0.0, 0.0, 10, 0, running_batch=2, chunk_budget=5)
    assert busy == pytest.approx(0.1 * 10 + 2 * 0.5)  # ceil(10/5) iterations
    assert busy > base
    # monolithic: back-to-back prefills in one iteration — no per-request
    # interference term, whatever is queued ahead or running
    mono = ctl.predict_ttft(0.0, 0.0, 10, 30, running_batch=4)
    assert mono == pytest.approx(0.1 * 40 + 0.5)
    # an idle chunked engine has no decoders to interleave with either
    idle = ctl.predict_ttft(0.0, 0.0, 10, 0, running_batch=0, chunk_budget=5)
    assert idle == pytest.approx(base)
    # interference alone can now (correctly) push a request over its SLO
    tight = AdmissionController(
        LatencyModel(prefill_per_token=0.1, decode_step=1.0),
        default_ttft_slo=2.5)
    assert tight.decide(0.0, 0.0, 10, 0) is Admission.ADMIT
    assert tight.decide(0.0, 0.0, 10, 0, running_batch=1,
                        chunk_budget=5) is Admission.REJECT


def test_admission_controller_slo():
    slow = AdmissionController(LatencyModel(prefill_per_token=1.0),
                               default_ttft_slo=0.5)
    assert slow.decide(0.0, 0.0, 10, 0) is Admission.REJECT
    assert slow.n_rejected == 1
    fast = AdmissionController(LatencyModel(prefill_per_token=1e-6))
    # no SLO -> always admit
    assert fast.decide(0.0, 0.0, 10, 0) is Admission.ADMIT
    assert fast.decide(0.0, 0.0, 10, 10**6, ttft_slo=30.0) is Admission.ADMIT


def test_priority_orders_admission():
    """pop_admissible honors GenerationRequest.priority: candidates are
    considered in stable (priority desc, arrival) order, so a later
    high-priority arrival is admitted ahead of earlier low-priority ones
    and equal priorities keep FIFO."""
    q = RequestQueue(AdmissionController())     # no SLO: always admit
    sp = SamplingParams(max_new_tokens=2)
    for rid, prio in enumerate([0, 5, 0, 5, 1]):
        q.submit(Request(rid=rid, prompt=np.zeros(4, np.int32), params=sp,
                         arrival=float(rid), priority=prio))
    first = q.pop_admissible(now=10.0, limit=2)
    assert [r.rid for r in first] == [1, 3]     # prio 5, arrival order
    rest = q.pop_admissible(now=10.0, limit=5)
    assert [r.rid for r in rest] == [4, 0, 2]   # prio 1, then FIFO zeros
    assert not q.pending and not q.rejected


def test_priority_admission_end_to_end(setup):
    """A high-priority late submission wins the only free KV slot."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=1,
                               max_seq=32, temperature=0.0)
    lo = eng.submit(prompts[0], max_new=2, priority=0)
    hi = eng.submit(prompts[1], max_new=2, priority=3)
    eng.step()
    assert hi.state == "running" and lo.state == "queued"
    eng.run_until_drained()
    assert [r.rid for r in eng.finished] == [hi.rid, lo.rid]


def test_srf_fairness_shortest_first(setup):
    """prefill_fairness='srf' spends the budget on the request with the
    least prefill remaining — a short straggler overtakes long backlogs —
    and stays bit-exact. Prompt lengths: rid0=12, rid1=16, rid2=9, rid3=14.
    """
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=4,
                               max_seq=32, temperature=0.0,
                               prefill_budget=4, prefill_fairness="srf")
    for p in prompts:
        eng.submit(p, max_new=MAX_NEW)
    eng.step()          # shortest (rid2, 9 tokens) gets the whole budget
    assert {r.rid: r.prefill_pos for r in eng.prefilling} == \
        {0: 0, 1: 0, 2: 4, 3: 0}
    eng.step()          # rid2 still shortest remaining (5)
    assert {r.rid: r.prefill_pos for r in eng.prefilling} == \
        {0: 0, 1: 0, 2: 8, 3: 0}
    eng.step()          # rid2 finishes (1 token), 3 spill to rid0 (12)
    assert {r.rid: r.prefill_pos for r in eng.prefilling} == \
        {0: 3, 1: 0, 3: 0}
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == len(prompts)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens)
        assert r.prefill_active == refs[i].prefill_active


def test_stop_token_early_termination(setup):
    """A token in SamplingParams.stop_token_ids terminates the request
    early — the stop token itself is still emitted (prefix bit-exact vs the
    un-stopped reference) — on BOTH the single-request and batched paths."""
    cfg, params, prompts, refs = setup
    stop = int(refs[0].tokens[2])
    sp = SamplingParams(max_new_tokens=MAX_NEW, stop_token_ids=(stop,))

    seq = MoEServingEngine(cfg, params, policy="duo", temperature=0.0)
    r = seq.serve(prompts[0], params=sp)
    assert r.finish_reason == "stop_token"
    np.testing.assert_array_equal(r.tokens, refs[0].tokens[:3])
    assert r.decode_trace.shape[0] == 2      # traces truncated with tokens

    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0)
    req = eng.submit(prompts[0], sp)
    other = eng.submit(prompts[1], max_new=MAX_NEW)
    eng.run_until_drained()
    assert req.finish_reason == "stop_token"
    np.testing.assert_array_equal(req.result().tokens, refs[0].tokens[:3])
    # the surviving row is untouched by its batchmate's early exit
    np.testing.assert_array_equal(other.result().tokens, refs[1].tokens)
    assert other.finish_reason == "length"


def test_step_event_stream(setup):
    """step() returns the per-step event stream: TokenEvents for every
    token (first= marks TTFT), FinishEvents at retirement, and did_work
    distinguishing real work from idle steps. The stream IS the output:
    tokens reassembled from events match the request records exactly."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0)
    r0 = eng.submit(prompts[0], max_new=MAX_NEW)
    r1 = eng.submit(prompts[1], max_new=MAX_NEW)
    ev = eng.step()
    assert ev.did_work
    firsts = [e for e in ev if isinstance(e, TokenEvent) and e.first]
    assert [(e.rid, e.index, e.first) for e in firsts] == \
        [(0, 0, True), (1, 0, True)]
    streams = {0: [], 1: []}
    finishes = {}
    for e in ev:
        if isinstance(e, TokenEvent):
            streams[e.rid].append(e.token)
    while not eng.idle:
        for e in eng.step():
            if isinstance(e, TokenEvent):
                assert not e.first
                streams[e.rid].append(e.token)
            elif isinstance(e, FinishEvent):
                finishes[e.rid] = e
    for rid, req in ((0, r0), (1, r1)):
        assert streams[rid] == req.tokens
        np.testing.assert_array_equal(np.asarray(streams[rid]),
                                      refs[rid].tokens)
        assert finishes[rid].reason == "length"
        assert finishes[rid].n_tokens == MAX_NEW + 1
    idle = eng.step()
    assert not idle.did_work and list(idle) == []


def test_reject_event_emitted(setup):
    """Admission sheds surface as RejectEvents in the step stream."""
    cfg, params, prompts, _ = setup
    queue = RequestQueue(AdmissionController(
        LatencyModel(prefill_per_token=100.0), default_ttft_slo=0.1))
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, queue=queue, temperature=0.0)
    doomed = eng.submit(prompts[0], max_new=2)
    ev = eng.step()
    rejects = [e for e in ev if isinstance(e, RejectEvent)]
    assert [e.rid for e in rejects] == [doomed.rid]
    assert doomed.state == "rejected"


def test_admission_rejects_unmeetable_tbt():
    """A per-request tbt_slo below the structurally achievable per-step gap
    is REJECTED outright (waiting never shrinks the steady-state gap); an
    achievable one admits. The chunk charged is what the engine would run:
    min(current budget, suggest_chunk(tbt_slo))."""
    ctl = AdmissionController(
        LatencyModel(prefill_per_token=0.1, decode_step=0.5))
    assert ctl.decide(0.0, 0.0, 8, 0, tbt_slo=0.4) is Admission.REJECT
    assert ctl.n_rejected == 1
    assert ctl.decide(0.0, 0.0, 8, 0, tbt_slo=0.6) is Admission.ADMIT
    # FIXED budget 10: the engine really runs 10-token chunks, so the gap
    # is 0.5 + 10*0.1 = 1.5s and a 1.0s target is structurally unmeetable
    assert ctl.decide(0.0, 0.0, 8, 0, chunk_budget=10,
                      tbt_slo=1.0) is Admission.REJECT
    # ADAPTIVE budget: the engine will shrink its chunk to this request's
    # tbt_slo (suggest_chunk(1.0) == 5), which fits exactly -> admit
    assert ctl.decide(0.0, 0.0, 8, 0, chunk_budget=10, tbt_slo=1.0,
                      chunk_adaptive=True) is Admission.ADMIT
    assert ctl.decide(0.0, 0.0, 8, 0, chunk_budget=10, tbt_slo=0.50,
                      chunk_adaptive=True) is Admission.REJECT  # floor busts
    assert ctl.model.predict_tbt(5) == pytest.approx(1.0)
    assert ctl.model.predict_tbt(None) == pytest.approx(0.5)


def test_auto_budget_respects_request_tbt_slo(setup):
    """prefill_budget='auto' tightens the chunk to the minimum tbt_slo
    across in-flight requests, not just the engine default."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0,
                               prefill_budget="auto", tbt_slo=100.0)
    m = eng.queue.admission.model
    assert eng._current_budget() == m.suggest_chunk(100.0)
    tight = eng.submit(prompts[0], max_new=2, tbt_slo=0.25)
    eng.step()
    assert tight.state in ("prefilling", "running")
    assert eng._current_budget() == \
        eng.queue.admission.model.suggest_chunk(0.25)
    eng.run_until_drained()
    np.testing.assert_array_equal(tight.result().tokens, refs[0].tokens[:3])


# -- sparse grouped-expert decode/prefill exactness battery ----------------

@pytest.mark.parametrize("B", [1, 2, 4, 8])
@pytest.mark.parametrize("budget", [None, 4])
def test_grouped_decode_exactness_battery(setup, B, budget):
    """Segment-gathered decode + fused prefill are bit-exact vs BOTH the
    dense full-batch discipline and the sequential reference, for every
    batch width x {monolithic, chunked} prefill. Eight requests share B KV
    slots, so every B < 8 exercises mid-flight admission; the request list
    repeats each prompt, so rows with duplicate expert selections coexist
    with divergent ones. The expert-HBM bound is asserted after EVERY
    step on the grouped engine."""
    from test_residency import assert_residency_invariants
    cfg, params, prompts, refs = setup
    reqs = prompts * 2

    def drain(grouped):
        eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=B,
                                   max_seq=32, temperature=0.0,
                                   prefill_budget=budget,
                                   grouped_decode=grouped,
                                   fused_prefill=grouped)
        for p in reqs:
            eng.submit(p, max_new=MAX_NEW)
        for _ in range(10_000):
            eng.step()
            assert_residency_invariants(eng.cache)
            if eng.idle:
                break
        return eng, sorted(eng.finished, key=lambda r: r.rid)

    grp_eng, grp = drain(True)
    dense_eng, dense = drain(False)
    assert len(grp) == len(dense) == len(reqs)
    for i, (g, d) in enumerate(zip(grp, dense)):
        np.testing.assert_array_equal(
            g.result().tokens, refs[i % len(prompts)].tokens,
            err_msg=f"request {i} diverged from sequential")
        np.testing.assert_array_equal(g.result().tokens, d.result().tokens)
        np.testing.assert_array_equal(g.result().decode_trace,
                                      d.result().decode_trace)
        assert g.result().prefill_active == d.result().prefill_active
    # sparse discipline: one FFN launch per decode layer, and never more
    # row evaluations than the dense path
    assert grp_eng.perf.decode_ffn_launches == grp_eng.perf.decode_layers
    assert grp_eng.perf.decode_rows_grouped <= grp_eng.perf.decode_rows_dense
    assert dense_eng.perf.decode_rows_launched == \
        dense_eng.perf.decode_rows_dense
    if budget is not None:
        assert grp_eng.perf.max_prefill_launches_per_layer == 1


def test_grouped_decode_identical_rows(setup):
    """Degenerate grouping: all rows are the SAME prompt, so every decode
    step selects identical experts across the whole batch (one maximal
    group per distinct expert, U == the row's own selection count) — the
    grouped path must still match the sequential reference bit-exactly."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=4,
                               max_seq=32, temperature=0.0)
    for _ in range(4):
        eng.submit(prompts[0], max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == 4
    for r in finished:
        np.testing.assert_array_equal(r.result().tokens, refs[0].tokens)
    # every step's groups cover all B rows per selected expert
    assert eng.perf.decode_rows_grouped == \
        eng.perf.decode_rows_dense


def test_queue_sheds_breached_requests(setup):
    """A pessimistic cost model + tight deadline: the queue rejects instead
    of wasting a KV slot on an unmeetable request."""
    cfg, params, prompts, _ = setup
    queue = RequestQueue(AdmissionController(
        LatencyModel(prefill_per_token=100.0), default_ttft_slo=0.1))
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, queue=queue, temperature=0.0)
    eng.submit(prompts[0], max_new=2)
    eng.submit(prompts[1], max_new=2, ttft_slo=1e6)  # generous deadline
    finished = eng.run_until_drained(max_steps=20)
    assert len(queue.rejected) == 1
    assert queue.rejected[0].state == "rejected"
    assert len(finished) == 1 and finished[0].rid == 1
