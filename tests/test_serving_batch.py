"""Continuous-batching correctness: batched decode must be bit-exact vs the
single-request engine at temperature 0, and the shared-cache ledger must
count distinct experts per step (decode-plan union semantics)."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.qos import (Admission, AdmissionController, LatencyModel)
from repro.core.scheduler import union_selection
from repro.models.model import build
from repro.serving.batching import BatchedServingEngine, RequestQueue
from repro.serving.engine import MoEServingEngine

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (12, 16, 9, 14)]
    seq = MoEServingEngine(cfg, params, policy="duo", temperature=0.0)
    refs = [seq.serve(p, max_new=MAX_NEW) for p in prompts]
    return cfg, params, prompts, refs


@pytest.mark.parametrize("B", [1, 2, 4])
def test_batched_matches_sequential(setup, B):
    """B concurrent requests produce exactly the tokens B sequential
    single-request serve() calls produce (greedy)."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=B,
                               max_seq=32, temperature=0.0)
    for p in prompts[:B]:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == B
    assert len(eng.decode_batch_hist) == MAX_NEW
    assert max(eng.decode_batch_hist) == B
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens,
                                      err_msg=f"request {i} diverged")


def test_midflight_admission_matches_sequential(setup):
    """More requests than KV slots: later arrivals are admitted as slots
    free up mid-flight, still bit-exact."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0)
    for p in prompts:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    assert len(finished) == len(prompts)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens)


@pytest.mark.parametrize("policy", ["odf", "lfp", "duo", "duo+"])
def test_policies_identical_tokens_batched(setup, policy):
    """Scheduling policy must never change batched outputs either."""
    cfg, params, prompts, refs = setup
    eng = BatchedServingEngine(cfg, params, policy=policy, max_batch=2,
                               max_seq=32, temperature=0.0)
    for p in prompts[:2]:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    for i, r in enumerate(finished):
        np.testing.assert_array_equal(r.result().tokens, refs[i].tokens,
                                      err_msg=f"{policy} diverged")


def test_shared_cache_accounting(setup):
    """Per step+layer the scheduler ledger counts each DISTINCT selected
    expert exactly once; per request every selected expert lands in exactly
    one of {hits, misses}."""
    cfg, params, prompts, _ = setup
    B = 4
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=B,
                               max_seq=32, temperature=0.0)
    for p in prompts[:B]:
        eng.submit(p, max_new=MAX_NEW)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)

    traces = [r.result().decode_trace for r in finished]  # [T, L, k] each
    expected = 0
    for t in range(MAX_NEW):
        for l in range(cfg.n_layers):
            union = set()
            for tr in traces:
                union.update(int(e) for e in tr[t, l])
            expected += len(union)
    assert eng.sched.decode_hits + eng.sched.decode_misses == expected
    # per-request attribution covers exactly its own selections
    for r in finished:
        assert r.hits + r.misses == MAX_NEW * cfg.n_layers * cfg.top_k
    # attribution can only multiply-count shared experts, never lose them
    assert sum(r.hits + r.misses for r in finished) >= expected
    # the batch-scaled cache capacity absorbs one step's churn without the
    # everything-pinned overflow branch silently growing the cache
    assert eng.sched.cache.capacity >= 2 * B * cfg.top_k
    assert eng.sched.cache.peak_resident <= eng.sched.cache.capacity


def test_union_selection_shapes():
    assert union_selection([3, 1, 2]) == [3, 1, 2]
    assert union_selection([[3, 1], [1, 2]]) == [3, 1, 2]
    assert union_selection([np.array([5, 0]), [0, 5]]) == [5, 0]
    assert union_selection([]) == []


def test_admission_queue_verdict_keeps_fifo():
    """Backlog-only breach -> QUEUE: the request stays at the head instead
    of being shed, and admission stops for the round (FIFO preserved)."""
    from repro.serving.batching import Request
    ctl = AdmissionController(
        LatencyModel(prefill_per_token=0.1, decode_step=0.0),
        default_ttft_slo=2.0)
    assert ctl.decide(0.0, 0.0, 16, 0) is Admission.ADMIT
    assert ctl.decide(0.0, 0.0, 16, 16) is Admission.QUEUE   # backlog breach
    assert ctl.decide(0.0, 0.0, 40, 0) is Admission.REJECT   # hopeless

    q = RequestQueue(ctl)
    r0 = Request(rid=0, prompt=np.zeros(16, np.int32), max_new=2, arrival=0.0)
    r1 = Request(rid=1, prompt=np.zeros(16, np.int32), max_new=2, arrival=0.0)
    q.submit(r0)
    q.submit(r1)
    admitted = q.pop_admissible(now=0.0, limit=2)
    assert [r.rid for r in admitted] == [0]
    assert len(q.pending) == 1 and q.pending[0].rid == 1
    assert not q.rejected
    # backlog drained -> the queued request admits on the next round
    assert [r.rid for r in q.pop_admissible(now=0.0, limit=2)] == [1]


def test_admission_controller_slo():
    slow = AdmissionController(LatencyModel(prefill_per_token=1.0),
                               default_ttft_slo=0.5)
    assert slow.decide(0.0, 0.0, 10, 0) is Admission.REJECT
    assert slow.n_rejected == 1
    fast = AdmissionController(LatencyModel(prefill_per_token=1e-6))
    # no SLO -> always admit
    assert fast.decide(0.0, 0.0, 10, 0) is Admission.ADMIT
    assert fast.decide(0.0, 0.0, 10, 10**6, ttft_slo=30.0) is Admission.ADMIT


def test_queue_sheds_breached_requests(setup):
    """A pessimistic cost model + tight deadline: the queue rejects instead
    of wasting a KV slot on an unmeetable request."""
    cfg, params, prompts, _ = setup
    queue = RequestQueue(AdmissionController(
        LatencyModel(prefill_per_token=100.0), default_ttft_slo=0.1))
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, queue=queue, temperature=0.0)
    eng.submit(prompts[0], max_new=2)
    eng.submit(prompts[1], max_new=2, ttft_slo=1e6)  # generous deadline
    finished = eng.run_until_drained(max_steps=20)
    assert len(queue.rejected) == 1
    assert queue.rejected[0].state == "rejected"
    assert len(finished) == 1 and finished[0].rid == 1
