"""Partitioner + HLO cost analyzer + dry-run smoke tests."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.launch import hlo_cost, partition
from repro.models.model import build

N_MODEL = 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_tree_and_divide(arch):
    cfg = get_config(arch)
    bundle = build(cfg)
    params_abs = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    specs = partition.param_specs(cfg, params_abs, n_model=N_MODEL)
    # same tree structure
    assert jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, params_abs)) == \
        jax.tree_util.tree_structure(
            jax.tree.map(lambda _: 0, specs,
                         is_leaf=lambda x: isinstance(x, P)))
    # every sharded dim divides the axis
    flat_p = jax.tree_util.tree_leaves_with_path(params_abs)
    flat_s = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = 0
    for (pp, leaf), (sp, spec) in zip(flat_p, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            assert leaf.shape[dim] % N_MODEL == 0, (pp, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, "nothing sharded at all"


def test_cache_specs_decode():
    cfg = get_config("qwen2_moe_a2_7b")
    bundle = build(cfg)
    cache_abs = jax.eval_shape(lambda: bundle.init_cache(128, 1024))
    specs = partition.cache_specs(cfg, cache_abs, dp="data",
                                  n_model=16, n_dp=16)
    # kv=16 heads shard over model; batch over data
    assert specs["k"] == P(None, "data", None, "model", None)
    assert specs["pos"] == P()


def test_hlo_cost_scan_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    r = hlo_cost.analyze(txt)
    dot_flops = 6 * 2 * 64 * 128 * 128
    assert dot_flops <= r["flops"] <= dot_flops * 1.2
    assert r["bytes"] > 0


def test_hlo_cost_nested_loops():
    def inner(c, w):
        return c @ w, None

    def outer(c, ws):
        c, _ = jax.lax.scan(inner, c, ws)
        return c, None

    def f(x, ws):
        return jax.lax.scan(outer, x, ws)[0]

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 3, 32, 32), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    r = hlo_cost.analyze(txt)
    want = 4 * 3 * 2 * 32 * 32 * 32
    assert want <= r["flops"] <= want * 1.3


@pytest.mark.slow
def test_dryrun_subprocess_one_pair():
    """Full dry-run path in a subprocess (needs its own 512-device env)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen2-moe-a2.7b", "--shape", "decode_32k", "--multi-pod",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(
        "/tmp/dryrun_test/qwen2-moe-a2_7b__decode_32k__multi.json"))
    assert rec["ok"] and rec["hlo_cost"]["flops"] > 0


def test_dryrun_artifacts_complete():
    """The committed dry-run sweep must cover every applicable pair on both
    meshes with ok=True."""
    from repro.configs.base import pairs
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run sweep not yet executed")
    missing, failed = [], []
    for cfg, shape in pairs():
        for mesh in ("single", "multi"):
            tag = f"{cfg.name.replace('.', '_')}__{shape.name}__{mesh}.json"
            path = os.path.join(d, tag)
            if not os.path.exists(path):
                missing.append(tag)
                continue
            rec = json.load(open(path))
            if not rec.get("ok"):
                failed.append(tag)
    assert not missing, f"missing dry-runs: {missing[:5]}..."
    assert not failed, f"failed dry-runs: {failed}"
