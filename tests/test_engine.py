"""Serving-engine integration tests: the central correctness invariant is
that the scheduling policy NEVER changes model outputs — only when/what
weights move."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.predictor import train_predictor
from repro.core.state import StateConstructor
from repro.data.pipeline import PromptWorkload, squad_like
from repro.models.model import build
from repro.serving.engine import MoEServingEngine, collect_traces


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    wl = PromptWorkload(squad_like(cfg.vocab), seed=2)
    prompts = [p[:24] for p, _ in wl.prompts(6)]
    tracer, _ = collect_traces(cfg, params, prompts[:4], max_new=4)
    stats = tracer.stats()
    sc = StateConstructor(stats)
    X, Y = sc.build_dataset(tracer.as_array())
    pred, _ = train_predictor(jax.random.PRNGKey(1), X, Y, cfg.top_k,
                              width_scale=0.1, epochs=3, batch=32)
    return cfg, params, stats, pred, prompts


def test_policies_identical_tokens(setup):
    cfg, params, stats, pred, prompts = setup
    outs = {}
    for pol in ("odf", "lfp", "mif", "duo", "duo+"):
        eng = MoEServingEngine(cfg, params, policy=pol, stats=stats,
                               predictor=pred, sample_seed=123)
        outs[pol] = eng.serve(prompts[5], max_new=5)
    ref = outs["odf"].tokens
    for pol, r in outs.items():
        np.testing.assert_array_equal(r.tokens, ref,
                                      err_msg=f"{pol} diverged")


def test_trace_shapes_and_bounds(setup):
    cfg, params, stats, pred, prompts = setup
    eng = MoEServingEngine(cfg, params, policy="duo", stats=stats,
                           predictor=pred)
    r = eng.serve(prompts[4], max_new=5)
    assert r.decode_trace.shape == (5, cfg.n_layers, cfg.top_k)
    assert (r.decode_trace >= 0).all()
    assert (r.decode_trace < cfg.n_experts).all()
    assert len(r.prefill_active) == cfg.n_layers
    # DuoServe predicted something for layers >= 1 of every step
    assert (r.pred_trace[:, 1:] >= 0).any()


def test_engine_greedy_matches_bundle(setup):
    """temperature=0 engine decode must equal the scan-model greedy path."""
    cfg, params, stats, pred, prompts = setup
    import jax.numpy as jnp
    from repro.models.model import pad_cache
    from repro.models.layers import vocab_pad_of
    bundle = build(cfg)
    prompt = prompts[0][:16]
    eng = MoEServingEngine(cfg, params, policy="lfp", temperature=0.0)
    r = eng.serve(prompt, max_new=3)

    toks = jnp.asarray(prompt, jnp.int32)[None]
    last, cache = bundle.prefill(params, {"tokens": toks})
    cache = pad_cache(cache, len(prompt) + 5, bundle.ring_axes)
    vocab_mask = jnp.where(jnp.arange(vocab_pad_of(cfg.vocab)) < cfg.vocab,
                           0.0, -1e9)
    seq = [int(jnp.argmax(last + vocab_mask))]
    for _ in range(3):
        lg, cache = bundle.decode_step(
            params, {"token": jnp.asarray([[seq[-1]]], jnp.int32)}, cache)
        seq.append(int(jnp.argmax(lg + vocab_mask)))
    np.testing.assert_array_equal(r.tokens[:4], np.asarray(seq[:4]))


def test_decode_hit_rate_bounds(setup):
    cfg, params, stats, pred, prompts = setup
    eng = MoEServingEngine(cfg, params, policy="duo", stats=stats,
                           predictor=pred)
    eng.serve(prompts[3], max_new=4)
    hr = eng.sched.decode_hit_rate
    assert 0.0 <= hr <= 1.0


def test_host_store_bytes(setup):
    cfg, params, stats, pred, prompts = setup
    eng = MoEServingEngine(cfg, params, policy="odf")
    want = 3 * cfg.d_model * cfg.d_expert * 2  # bf16
    assert eng.store.bytes_per_expert == want
    assert len(eng.store.weights) == cfg.n_layers * cfg.n_experts
