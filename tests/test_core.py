"""Unit tests for the paper-core modules: tracer, state constructor,
predictor, schedulers, simulator."""
import numpy as np
import jax
import pytest

from repro.core.predictor import (accuracy_metrics, bce_loss, forward,
                                  init_predictor, train_predictor)
from repro.core.scheduler import (DuoServeScheduler, LFPScheduler,
                                  MIFScheduler, ODFScheduler, make_scheduler)
from repro.core.simulator import HW, ModelCosts, StreamSim, simulate_request
from repro.core.state import StateConstructor
from repro.core.tracer import ExpertsTracer, TraceStats
from repro.configs.base import get_config, reduced

L, E, K = 4, 8, 2


def make_tracer(n_paths=50, seed=0):
    rng = np.random.default_rng(seed)
    tr = ExpertsTracer(L, E, K)
    for _ in range(n_paths):
        # biased routing: expert e prefers e and e+1 next layer
        path = np.zeros((L, K), np.int32)
        path[0] = rng.choice(E, K, replace=False)
        for l in range(1, L):
            prev = path[l - 1][0]
            path[l] = [(prev + 1) % E, rng.integers(0, E)]
            if path[l][0] == path[l][1]:
                path[l][1] = (path[l][1] + 1) % E
        tr.add_path(path)
    return tr


def test_tracer_stats_normalized():
    stats = make_tracer().stats()
    np.testing.assert_allclose(stats.popularity.sum(1), 1.0, rtol=1e-5)
    rowsums = stats.affinity.sum(2)
    nz = rowsums > 0
    np.testing.assert_allclose(rowsums[nz], 1.0, rtol=1e-5)
    assert stats.popularity.shape == (L, E)
    assert stats.affinity.shape == (L - 1, E, E)


def test_tracer_roundtrip(tmp_path):
    stats = make_tracer().stats()
    p = str(tmp_path / "stats.npz")
    stats.save(p)
    loaded = TraceStats.load(p)
    np.testing.assert_array_equal(loaded.popularity, stats.popularity)
    assert loaded.top_k == K


def test_state_constructor_features():
    stats = make_tracer().stats()
    sc = StateConstructor(stats)
    f = sc.features([np.array([0, 1]), np.array([2, 3])], layer=2)
    assert f.shape == (sc.feature_dim,)
    assert np.isfinite(f).all()
    X, Y = sc.build_dataset(make_tracer(10).as_array())
    assert X.shape == (10 * (L - 1), sc.feature_dim)
    assert Y.shape == (10 * (L - 1), E)
    assert (Y.sum(1) == K).all()


def test_predictor_learns_affinity():
    """The structured traces (expert e -> e+1) must be learnable well above
    the popularity baseline."""
    tr = make_tracer(300)
    stats = tr.stats()
    sc = StateConstructor(stats)
    X, Y = sc.build_dataset(tr.as_array())
    pred, hist = train_predictor(jax.random.PRNGKey(0), X, Y, K,
                                 width_scale=0.25, epochs=12, batch=64)
    assert hist["val_half"][-1] > 0.7
    assert hist["val_loss"][-1] < hist["val_loss"][0]


def test_predictor_bn_and_dropout_modes():
    params, bn = init_predictor(jax.random.PRNGKey(0), 16, E, width_scale=0.05)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    lg1, bn1 = forward(params, bn, x, train=True, rng=jax.random.PRNGKey(2))
    lg2, _ = forward(params, bn1, x, train=False)
    assert lg1.shape == (4, E) and lg2.shape == (4, E)
    # eval mode is deterministic
    lg3, _ = forward(params, bn1, x, train=False)
    np.testing.assert_array_equal(np.asarray(lg2), np.asarray(lg3))


def test_accuracy_metrics():
    logits = np.array([[5, 4, 0, 0], [5, 0, 0, 4]], float)
    targets = np.array([[1, 1, 0, 0], [0, 1, 1, 0]], float)
    exact, half = accuracy_metrics(logits, targets, 2)
    assert exact == 0.5 and half == 0.5


BYTES = 1000


def test_odf_stateless():
    s = ODFScheduler(L, E, K, BYTES)
    s.begin_request()
    p1 = s.decode_plan(0, [1, 2])
    assert p1.misses == [1, 2] and not p1.hits
    s.decode_plan(1, [1, 2])
    p3 = s.decode_plan(0, [1, 2])  # next step: accelerate re-fetches
    assert p3.misses == [1, 2]


def test_lfp_full_prefetch():
    s = LFPScheduler(L, E, K, BYTES)
    s.begin_request()
    plan = s.prefill_plan(0, [0, 3])
    assert len(plan.fetches) == E and plan.prefetch_all_first
    d0 = s.decode_plan(0, [1, 2])          # staged layer 1
    d1 = s.decode_plan(1, [4, 5])
    assert not d1.misses                   # everything prefetched


def test_mif_cache_and_prior():
    stats = make_tracer().stats()
    s = MIFScheduler(L, E, K, BYTES, stats)
    s.begin_request()
    d0 = s.decode_plan(0, [0, 1])
    assert len(d0.predicted) == K
    # after touching layer 1's prior, those become hits
    top1 = list(np.argsort(-stats.popularity[1])[:K])
    d1 = s.decode_plan(1, top1)
    assert set(d1.hits) == set(top1)


class _OraclePredictor:
    def __init__(self, nxt):
        self.nxt = nxt
        self.top_k = K

    def predict_topk(self, x, k=None):
        return np.asarray([self.nxt])


def test_duoserve_prediction_hits():
    stats = make_tracer().stats()
    sc = StateConstructor(stats)
    s = DuoServeScheduler(L, E, K, BYTES, predictor=_OraclePredictor([3, 4]),
                          state_constructor=sc)
    s.begin_request()
    s.begin_decode_step()
    d0 = s.decode_plan(0, [0, 1])
    assert d0.prefetch_next == [3, 4]
    d1 = s.decode_plan(1, [3, 4])     # perfectly predicted
    assert set(d1.hits) == {3, 4} and not d1.misses
    d2 = s.decode_plan(2, [0, 5])     # fully mispredicted
    assert len(d2.misses) == 2
    # cache bounded at 2k
    assert s.cache.peak_resident <= 2 * K + K


def _sim(policy, seed=0):
    stats = make_tracer().stats()
    cfg = reduced(get_config("mixtral_8x7b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=L, n_experts=E, top_k=K)
    costs = ModelCosts(cfg)
    rng = np.random.default_rng(seed)
    prefill_active = [sorted(rng.choice(E, 5, replace=False).tolist())
                      for _ in range(L)]
    trace = rng.integers(0, E, size=(6, L, K))
    sched = make_scheduler(policy, L, E, K, int(costs.expert_bytes),
                           stats=stats,
                           predictor=_OraclePredictor([0, 1]),
                           state_constructor=StateConstructor(stats))
    return simulate_request(sched, costs, HW(), prefill_active, trace,
                            seq_len=64)


@pytest.mark.parametrize("policy", ["odf", "lfp", "mif", "duo"])
def test_simulator_sanity(policy):
    r = _sim(policy)
    assert r.e2e >= r.ttft > 0
    assert (r.step_latencies > 0).all()
    assert r.peak_bytes > 0


def test_simulator_policy_ordering():
    """Structural invariants: LFP moves the most bytes in decode; DuoServe
    peak memory stays at the k-slot scale (well under LFP/MIF)."""
    rs = {p: _sim(p) for p in ("odf", "lfp", "mif", "duo")}
    assert rs["duo"].peak_bytes < rs["lfp"].peak_bytes
    assert rs["duo"].peak_bytes < rs["mif"].peak_bytes
    # at this toy scale absolute latencies are dominated by fixed overheads;
    # latency ordering is asserted at full scale in the benchmarks instead


def test_stream_sim_fifo_and_deps():
    sim = StreamSim()
    a = sim.issue("comp", 1.0)
    b = sim.issue("comm", 0.5, [a])   # waits for dep a
    c = sim.issue("comm", 0.5)        # FIFO behind b on the comm stream
    assert a == 1.0 and b == 1.5 and c == 2.0
