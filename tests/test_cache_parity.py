"""Engine-vs-simulator CacheState ledger parity (all four policies).

The repo's central measurement claim is that the discrete-event simulator
replays *exactly* the cache behaviour of the live engine, because both drive
the same policy objects (core/scheduler.py). This test pins that contract:
running one request through `MoEServingEngine` and then replaying its traces
through `core/simulator.simulate_request` with a fresh scheduler must produce
identical hit/miss(fetch)/evict event sequences and identical peak residency.

Also includes deterministic (non-hypothesis) CacheState/union_selection
invariant checks so tier-1 exercises them even where hypothesis is absent
(the property-based versions live in tests/test_property.py).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.core.cache import CacheState
from repro.core.scheduler import make_scheduler, union_selection
from repro.core.simulator import HW, ModelCosts, simulate_request
from repro.core.tracer import ExpertsTracer
from repro.models.model import build
from repro.serving.batching import BatchedServingEngine
from repro.serving.engine import MoEServingEngine

POLICIES = ["odf", "lfp", "mif", "duo"]


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=12).astype(np.int32)
    # uniform-ish stats for MIF (identical object drives engine + sim)
    tracer = ExpertsTracer(cfg.n_layers, cfg.n_experts, cfg.top_k)
    for _ in range(8):
        tracer.add_path(np.stack([
            rng.choice(cfg.n_experts, cfg.top_k, replace=False)
            for _ in range(cfg.n_layers)]))
    return cfg, params, prompt, tracer.stats()


def _events(state: CacheState):
    return [(ev.kind, ev.key) for ev in state.events]


@pytest.mark.parametrize("policy", POLICIES)
def test_engine_sim_ledger_parity(setup, policy):
    cfg, params, prompt, stats = setup
    eng = MoEServingEngine(cfg, params, policy=policy, stats=stats,
                           temperature=0.0)
    res = eng.serve(prompt, max_new=3)

    # the engine sizes its residency for the worst-case prefill pin set
    # (max(policy default, E)); replay with the same bound so the ledgers
    # see identical capacity pressure
    sim_sched = make_scheduler(policy, cfg.n_layers, cfg.n_experts,
                               cfg.top_k, eng.store.bytes_per_expert,
                               stats=stats,
                               capacity=eng.sched.cache.capacity)
    simulate_request(sim_sched, ModelCosts(cfg), HW(), res.prefill_active,
                     res.decode_trace, seq_len=len(prompt))

    assert _events(sim_sched.cache) == _events(eng.sched.cache), \
        f"{policy}: simulator replays a different cache event sequence"
    assert sim_sched.cache.peak_resident == eng.sched.cache.peak_resident
    assert sim_sched.cache.hits == eng.sched.cache.hits
    assert sim_sched.cache.misses == eng.sched.cache.misses
    assert (sim_sched.decode_hits, sim_sched.decode_misses) == \
        (eng.sched.decode_hits, eng.sched.decode_misses)


@pytest.mark.parametrize("policy", POLICIES)
def test_chunked_prefill_same_decode_ledger(setup, policy):
    """Chunked prefill changes the *prefill* plan stream (one plan per
    chunk-layer) but not what decode selects: the decode ledger still
    covers exactly the selected experts. For policies whose decode-start
    residency is chunking-invariant (odf resets per layer, lfp stages whole
    layers, mif's cache is large enough to hold prefill's whole working
    set) the hit/miss split itself is identical; duo's k-slot cache keeps a
    different residue of the (chunked) prefill, so only the total is pinned
    there — token-level equivalence is covered by the bit-exactness tests.
    """
    cfg, params, prompt, stats = setup
    mono = MoEServingEngine(cfg, params, policy=policy, stats=stats,
                            temperature=0.0)
    mono.serve(prompt, max_new=3)
    chk = MoEServingEngine(cfg, params, policy=policy, stats=stats,
                           temperature=0.0, prefill_chunk=5)
    chk.serve(prompt, max_new=3)
    assert chk.sched.decode_hits + chk.sched.decode_misses == \
        mono.sched.decode_hits + mono.sched.decode_misses
    if policy != "duo":
        assert (chk.sched.decode_hits, chk.sched.decode_misses) == \
            (mono.sched.decode_hits, mono.sched.decode_misses)


def test_no_pin_accumulation_across_steps(setup):
    """Decode unpins the successor-less LAST layer at the end of every step
    (the policies only end_layer(l) while planning l+1). Without that, a
    continuously batching engine — which never calls begin_request — would
    accumulate pinned (L-1, e) entries forever and push the ledger through
    its all-pinned growth branch in steady state."""
    cfg, params, prompt, stats = setup
    rng = np.random.default_rng(3)
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, temperature=0.0)
    for _ in range(6):
        eng.submit(rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                   max_new=2)
    eng.run_until_drained()
    assert sum(eng.sched.cache.resident.values()) == 0, \
        "pinned entries survived the drain"
    assert eng.sched.cache.peak_resident <= eng.sched.cache.capacity


# ---------------------------------------------------------------------------
# deterministic CacheState / union_selection invariants (tier-1 everywhere)
# ---------------------------------------------------------------------------


def test_cache_lru_victim_is_oldest_unpinned():
    c = CacheState(capacity=2, bytes_per_expert=1)
    c.admit((0, 0), pinned=False)
    c.admit((0, 1), pinned=False)
    c.lookup((0, 0))               # refresh (0,0): now (0,1) is LRU
    evicted = c.admit((0, 2), pinned=False)
    assert evicted == [(0, 1)]
    assert list(c.resident) == [(0, 0), (0, 2)]


def test_cache_pin_survives_eviction_pressure():
    c = CacheState(capacity=2, bytes_per_expert=1)
    c.admit((0, 0), pinned=True)
    for e in range(1, 6):
        c.admit((0, e), pinned=False)
        assert (0, 0) in c.resident
        assert len(c.resident) <= 2


def test_cache_grows_only_when_all_pinned():
    c = CacheState(capacity=2, bytes_per_expert=1)
    c.admit((0, 0), pinned=True)
    c.admit((0, 1), pinned=True)
    c.admit((0, 2), pinned=True)   # must-have into all-pinned: grows
    assert len(c.resident) == 3
    c.admit((0, 3), pinned=False)  # speculative into all-pinned: declined
    assert not c.contains((0, 3))
    assert len(c.resident) == 3
    evicted = c.unpin((0, 0))      # shrink-on-unpin restores the bound
    assert evicted == [(0, 0)]
    assert len(c.resident) == 2


def test_union_selection_nested_and_ndarray():
    assert union_selection([np.array([[3, 1], [1, 2]])]) == [3, 1, 2]
    assert union_selection([(5,), [np.int32(5), 0]]) == [5, 0]
    assert union_selection([[], [], [7]]) == [7]
