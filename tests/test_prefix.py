"""Cross-request prefix/KV reuse battery (ISSUE 7 tentpole).

  * Exactness — a prefix-hit request's tokens are bit-identical to the
    cold-prefill reference at temperature 0, across {monolithic,
    chunked[1, 3, whole-prompt]} engines x {full hit, partial hit landing
    mid-chunk, zero hit} x mid-flight admission; expert-residency
    invariants (`assert_residency_invariants`) and the tree's structural
    invariants hold after every step (KV reuse must not touch the expert
    HBM bound).
  * Slot lifecycle — donor-evicted-then-hit (eviction falls back to cold
    prefill, still bit-exact) and hit-after-slot-reuse (a reclaimed slot's
    NEW contents are matched, never the stale donor rows).
  * Accounting — `prefilled_tokens` charges only the un-hit suffix, and
    TTFT is measured from ARRIVAL, not from hit-seeding (a full hit does
    not fabricate a negative/zero TTFT).
  * Cluster — the `prefix_affinity` router lands matching requests on the
    warm replica (overload-gated, like `expert_affinity`).
  * PrefixTree properties — deterministic random-walk driver (hypothesis
    mirror per the test_cache_parity.py convention) checking longest-match
    vs a brute-force reference, refcounts never negative, eviction never
    freeing a pinned (live-request) path, and referenced rows staying
    within the pool.
"""
import jax
import numpy as np
import pytest

from test_residency import assert_residency_invariants

from repro.configs.base import get_config, reduced
from repro.core.prefix import PrefixTree
from repro.models.model import build
from repro.serving.api import GenerationRequest, SamplingParams
from repro.serving.batching import BatchedServingEngine
from repro.serving.cluster import ClusterFrontend, ReplicaPool
from repro.serving.frontend import ServingFrontend

MAX_NEW = 4
SHARED = 10          # tokens of shared head between donor and partial probe
BUDGETS = [None, 1, 3, 16]   # monolithic, tiny, mid-prompt, whole-prompt


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab, size=SHARED).astype(np.int32)

    def mk(n):
        return np.concatenate(
            [shared, rng.integers(0, cfg.vocab, size=n).astype(np.int32)])

    prompts = {
        "donor": mk(4),                          # S=14, seeds the cache
        "partial": mk(5),                        # S=15, hit == SHARED
        "zero": rng.integers(0, cfg.vocab, size=12).astype(np.int32),
    }
    prompts["full"] = prompts["donor"].copy()    # identical -> hit S-1
    # force the intended hit shapes whatever the rng drew: the partial
    # probe diverges AT position SHARED, the zero probe at position 0
    prompts["partial"][SHARED] = (prompts["donor"][SHARED] + 1) % cfg.vocab
    prompts["zero"][0] = (prompts["donor"][0] + 1) % cfg.vocab
    prompts["zero_ext"] = np.concatenate(        # extends "zero" by 4
        [prompts["zero"], rng.integers(0, cfg.vocab, size=4).astype(np.int32)])
    # SOLO cold references on fresh tree-less frontends (row-wise
    # determinism makes these equal to any batched run's tokens)
    refs = {}
    for name, p in prompts.items():
        fe = _fe(cfg, params)
        h = fe.submit(_spec(p))
        fe.drain()
        refs[name] = list(h.tokens)
    return cfg, params, prompts, refs


def _fe(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("prefill_budget", 3)
    return ServingFrontend(BatchedServingEngine(
        cfg, params, policy="duo", max_seq=32, temperature=0.0, **kw))


def _spec(p, max_new=MAX_NEW, **kw):
    return GenerationRequest(prompt=p,
                             params=SamplingParams(max_new_tokens=max_new),
                             **kw)


def _drain(fe, limit=2000):
    """Drive to idle, checking residency + tree invariants EVERY step."""
    eng = fe.engine
    for _ in range(limit):
        if fe.idle:
            return
        fe.poll()
        assert_residency_invariants(eng.cache)
        if eng.prefix is not None:
            eng.prefix.check_invariants(eng.W)
    raise AssertionError("engine did not drain")


EXPECTED_HIT = {"full": 13, "partial": SHARED, "zero": 0}  # donor S=14


# ---------------------------------------------------------------------------
# exactness battery: engines x probes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("budget", BUDGETS)
@pytest.mark.parametrize("probe", ["full", "partial", "zero"])
def test_prefix_hit_bit_exact(setup, budget, probe):
    """Warm the tree with the donor, then replay each probe: tokens must be
    bit-identical to the cold solo reference, the hit length exact, and
    `prefilled_tokens` must charge only the un-hit suffix."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, prefill_budget=budget, prefix_cache=True)
    eng = fe.engine
    hd = fe.submit(_spec(prompts["donor"]))
    _drain(fe)
    assert list(hd.tokens) == refs["donor"]
    assert eng.prefix.hit_tokens == 0        # cold cache: donor missed
    base = eng.prefilled_tokens
    assert base == len(prompts["donor"])     # donor fully charged

    hp = fe.submit(_spec(prompts[probe]))
    _drain(fe)
    assert list(hp.tokens) == refs[probe], \
        f"prefix-hit tokens diverged (budget={budget}, probe={probe})"
    hit = EXPECTED_HIT[probe]
    assert eng.prefix.hit_tokens == hit
    assert eng.prefilled_tokens - base == len(prompts[probe]) - hit
    eng.prefix.check_invariants(eng.W)


def test_mid_flight_admission_hit(setup):
    """A probe arriving while another request is mid-chunked-prefill still
    hits the tree and reproduces its solo tokens."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, prefill_budget=3, max_batch=3, prefix_cache=True)
    eng = fe.engine
    hd = fe.submit(_spec(prompts["donor"]))
    _drain(fe)
    hz = fe.submit(_spec(prompts["zero"]))
    fe.poll()                                 # zero-hit req mid-prefill
    assert eng.prefilling, "expected an in-flight chunked prefill"
    hp = fe.submit(_spec(prompts["partial"]))
    _drain(fe)
    assert list(hd.tokens) == refs["donor"]
    assert list(hz.tokens) == refs["zero"]
    assert list(hp.tokens) == refs["partial"]
    assert eng.prefix.hit_tokens == SHARED


# ---------------------------------------------------------------------------
# slot lifecycle: eviction + slot reuse
# ---------------------------------------------------------------------------


def test_donor_evicted_then_probe_still_exact(setup):
    """max_batch=1: the retained donor slot is the ONLY admission slack, so
    a non-matching arrival must reclaim it (LRU eviction); a later probe
    that WOULD have hit falls back to cold prefill, still bit-exact."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, max_batch=1, prefix_cache=True)
    eng = fe.engine
    fe.submit(_spec(prompts["donor"]))
    _drain(fe)
    # slot retained by the tree, not returned to the free list
    assert not eng._free and eng.prefix.n_reclaimable() == 1
    assert eng.slot_available and eng.load().free_slots == 1

    fe.submit(_spec(prompts["zero"]))
    _drain(fe)                                # forced donor eviction
    assert eng.prefix.reclaimed_slots == 1

    h = fe.submit(_spec(prompts["full"]))
    _drain(fe)
    assert list(h.tokens) == refs["full"]
    assert eng.prefix.hit_tokens == 0         # donor cache was gone


def test_hit_after_slot_reuse(setup):
    """A reclaimed slot refilled by a NEW request must serve hits for the
    NEW prompt — and seeding must survive the reused slot being the very
    slot the new request evicts (copy-then-evict at max_batch=1)."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, max_batch=1, prefix_cache=True)
    eng = fe.engine
    fe.submit(_spec(prompts["donor"]))
    _drain(fe)
    fe.submit(_spec(prompts["zero"]))         # evicts donor, reuses slot
    _drain(fe)
    h = fe.submit(_spec(prompts["zero_ext"]))  # must hit zero's NEW rows
    _drain(fe)
    assert list(h.tokens) == refs["zero_ext"]
    assert eng.prefix.hit_tokens == len(prompts["zero"])
    assert eng.prefix.reclaimed_slots == 2    # donor slot, then zero's


# ---------------------------------------------------------------------------
# accounting: TTFT from arrival, not hit-seeding
# ---------------------------------------------------------------------------


def test_ttft_measured_from_arrival(setup):
    """A full-hit request still pays TTFT from its ARRIVAL stamp: seeding
    the head from cache must not fabricate a TTFT near zero (or negative)
    relative to a backdated arrival."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, prefix_cache=True)
    fe.submit(_spec(prompts["donor"]))
    _drain(fe)
    import time
    back = time.perf_counter() - 5.0          # arrived "5 seconds ago"
    h = fe.submit(_spec(prompts["full"], arrival=back))
    _drain(fe)
    assert list(h.tokens) == refs["full"]
    res = h.req.result()
    assert res.ttft_wall >= 5.0               # queue wait counted
    assert fe.engine.prefix.hit_tokens == EXPECTED_HIT["full"]


# ---------------------------------------------------------------------------
# cluster: prefix_affinity routing
# ---------------------------------------------------------------------------


def test_prefix_affinity_routes_to_warm_replica(setup):
    """Matching requests land on the replica whose tree holds the prefix;
    tokens stay bit-exact and the cold replica records zero hits."""
    cfg, params, prompts, refs = setup
    pool = ReplicaPool.build(cfg, params, 2, policy="duo", max_batch=2,
                             max_seq=32, temperature=0.0, prefill_budget=3,
                             prefix_cache=True)
    cf = ClusterFrontend(pool, router="prefix_affinity")
    hd = cf.submit(_spec(prompts["donor"]))
    warm = hd.replica
    while not cf.idle:
        cf.poll()
    hp = cf.submit(_spec(prompts["partial"]))
    assert hp.replica == warm, "probe should follow the warm prefix"
    while not cf.idle:
        cf.poll()
        for eng in pool.engines:
            assert_residency_invariants(eng.cache)
            eng.prefix.check_invariants(eng.W)
    assert list(hd.tokens) == refs["donor"]
    assert list(hp.tokens) == refs["partial"]
    assert pool.engines[warm].prefix.hit_tokens == SHARED
    assert pool.engines[1 - warm].prefix.hits == 0


def test_prefix_affinity_overload_gate(setup):
    """The warm replica stops attracting traffic once its backlog exceeds
    the overload gate — the feedback loop cannot pile unbounded load."""
    cfg, params, prompts, refs = setup
    pool = ReplicaPool.build(cfg, params, 2, policy="duo", max_batch=4,
                             max_seq=32, temperature=0.0, prefill_budget=1,
                             prefix_cache=True)
    cf = ClusterFrontend(pool, router="prefix_affinity")
    hd = cf.submit(_spec(prompts["donor"]))
    warm = hd.replica
    while not cf.idle:
        cf.poll()
    # pile matching requests WITHOUT polling: all of them prefer `warm`,
    # but the gate must spill some to the cold replica once warm's backlog
    # exceeds overload_factor x their own prompt length
    handles = [cf.submit(_spec(prompts["partial"])) for _ in range(8)]
    assert {h.replica for h in handles} == {0, 1}, \
        "overload gate never spilled to the cold replica"
    while not cf.idle:
        cf.poll()
    for h in handles:
        assert list(h.tokens) == refs["partial"]


# ---------------------------------------------------------------------------
# PrefixTree properties: random-walk driver vs brute-force reference
# ---------------------------------------------------------------------------


class _RefModel:
    """Brute-force mirror of the tree's VISIBLE state: the set of cached
    token sequences (per backing slot), with whole-slot eviction."""

    def __init__(self):
        self.seqs = {}            # slot -> tuple(tokens)

    def insert(self, toks, slot):
        self.seqs[slot] = tuple(toks)

    def evict(self, slots):
        for s in slots:
            self.seqs.pop(s, None)

    def longest(self, toks, limit=None):
        n_max = len(toks) if limit is None else min(limit, len(toks))
        best = 0
        for s in self.seqs.values():
            m = 0
            while m < min(len(s), n_max) and s[m] == toks[m]:
                m += 1
            best = max(best, m)
        return best


def _rand_tokens(rng, model, vocab=4, max_len=12):
    """Random query/insert sequence, biased to share prefixes with the
    cached population so splits/partial matches are exercised hard."""
    if model.seqs and rng.random() < 0.7:
        base = list(model.seqs.values())[
            int(rng.integers(len(model.seqs)))]
        cut = int(rng.integers(0, len(base) + 1))
        tail_n = int(rng.integers(0, max_len))
        tail = rng.integers(0, vocab, size=tail_n)
        return tuple(base[:cut]) + tuple(int(t) for t in tail)
    n = int(rng.integers(1, max_len + 1))
    return tuple(int(t) for t in rng.integers(0, vocab, size=n))


def _tree_walk(seed, n_ops=150, n_slots=6, n_rows=16):
    """One randomized lifecycle: insert/match/release/retire/evict against
    the brute-force mirror, asserting after EVERY op that
      * longest-match agrees with the reference (exact: the mirror tracks
        evictions, so no slack is needed),
      * no eviction ever shortens a PINNED (held) path,
      * structural invariants hold (refs >= 0, per-slot rows disjoint and
        within the ring, by-slot index in sync),
      * referenced rows never exceed the pool (n_slots * n_rows)."""
    rng = np.random.default_rng(seed)
    tree = PrefixTree()
    ref = _RefModel()
    free = list(range(n_slots))
    live = {}                  # slot -> tokens (donor request still live)
    held = []                  # (tokens, n_hit) pins awaiting release
    for _ in range(n_ops):
        op = rng.choice(["insert", "match", "release", "retire", "evict"])
        if op == "insert" and free:
            toks = _rand_tokens(rng, ref)
            toks = toks[:n_rows]            # ring bound, like the engine
            slot = free.pop(int(rng.integers(len(free))))
            if tree.insert(toks, slot):
                ref.insert(toks, slot)
            # a fully-covered insert creates NO node: the sequence's
            # matchability is tied to the covering slots, so the mirror
            # must not credit it to this one
            live[slot] = toks
        elif op == "match":
            q = _rand_tokens(rng, ref)
            limit = (None if rng.random() < 0.5
                     else int(rng.integers(0, len(q) + 1)))
            n_hit, blocks = tree.match(q, limit)
            assert n_hit == ref.longest(q, limit), \
                f"longest-match diverged from brute force (seed={seed})"
            # blocks tile [0, n_hit) in order, each within the ring
            pos = 0
            for s, a, b in blocks:
                assert a == pos and b > a and b <= n_rows
                pos = b
            assert pos == n_hit
            if n_hit:
                held.append((q, n_hit))
            tree.check_invariants(n_rows)
        elif op == "release" and held:
            q, n_hit = held.pop(int(rng.integers(len(held))))
            tree.release(q, n_hit)
        elif op == "retire" and live:
            slot = list(live)[int(rng.integers(len(live)))]
            del live[slot]
            if not tree.slot_released(slot):
                free.append(slot)
                ref.evict([slot])           # no nodes left -> gone
        elif op == "evict":
            freed = tree.evict_for(int(rng.integers(1, 3)))
            assert not set(freed) & set(live), \
                "evicted a live request's slot"
            free.extend(freed)
            ref.evict(freed)
        tree.check_invariants(n_rows)
        # eviction never frees a node on a held (pinned) path
        for q, n_hit in held:
            assert tree.peek(q, limit=n_hit) == n_hit, \
                "a pinned path was evicted"
        assert tree.cached_rows() <= n_slots * n_rows
        assert set(tree.nodes_by_slot) <= set(range(n_slots))
    # drain: release every pin, retire every live slot, evict everything
    for q, n_hit in held:
        tree.release(q, n_hit)
    for slot in list(live):
        tree.slot_released(slot)
    tree.evict_for(n_slots)
    tree.check_invariants(n_rows)
    assert tree.n_reclaimable() == 0


@pytest.mark.parametrize("seed", range(8))
def test_tree_walk_deterministic(seed):
    """Deterministic mirror of the hypothesis property (always runs)."""
    _tree_walk(seed)


def test_tree_walk_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    settings.register_profile("prefix", max_examples=25, deadline=None)
    settings.load_profile("prefix")

    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def run(seed):
        _tree_walk(seed, n_ops=60)

    run()


def test_tree_refcount_underflow_rejected():
    """Releasing more than was matched trips the refcount assertion."""
    tree = PrefixTree()
    tree.insert((1, 2, 3), 0)
    n, _ = tree.match((1, 2, 3))
    assert n == 3
    tree.release((1, 2, 3), 3)
    with pytest.raises(AssertionError):
        tree.release((1, 2, 3), 3)          # double release


def test_tree_split_preserves_pins():
    """Splitting a held edge (a shorter second match) keeps both release
    walks balanced — the split tail inherits the refcount."""
    tree = PrefixTree()
    tree.insert((1, 2, 3, 4), 0)
    n_a, _ = tree.match((1, 2, 3, 4))       # pins the whole edge
    n_b, _ = tree.match((1, 2), limit=2)    # splits it mid-span
    assert (n_a, n_b) == (4, 2)
    tree.check_invariants()
    tree.release((1, 2, 3, 4), 4)
    tree.release((1, 2), 2)
    tree.check_invariants()
    assert all(n.refs == 0 for n in tree.nodes())
