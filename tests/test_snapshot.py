"""Snapshot/restore primitive + its three consumers (ISSUE 6 tentpole).

  * Round-trip exactness — pausing a request at EVERY decode depth and at
    several prefill-chunk depths, then resuming (same engine or another),
    reproduces the uninterrupted run's tokens bit-exactly at temperature 0.
  * Resource accounting — the KV slot is freed on pause (reusable by other
    requests in between) and reacquired on resume; expert-residency
    invariants (`assert_residency_invariants`) hold after every step; a
    paused request vanishes from `engine.load()`.
  * TBT ledger — host-paused time is never charged as an inter-token gap:
    the entry closes on pause and reopens WITHOUT a baseline on resume
    (gap counts around the pause are checked exactly).
  * Disaggregated cluster — a 1-prefill + 1-decode pool behind the disagg
    router is bit-exact vs the plain ServingFrontend, with every request
    handed off (handle follows it; per-role HBM bound holds with zero
    regrows).
  * Autopilot preemption — a higher-priority arrival pauses the
    lowest-priority in-flight request; both the winner and the
    resumed victim reproduce their solo token streams.
  * Replica draining — `ReplicaPool.drain(i)` migrates in-flight requests
    to the survivors; everything completes bit-exactly and the drained
    replica ends idle and unroutable.
"""
import jax
import numpy as np
import pytest

from test_residency import assert_residency_invariants

from repro.configs.base import get_config, reduced
from repro.core.qos import TBTLedger
from repro.models.model import build
from repro.serving.api import GenerationRequest, SamplingParams
from repro.serving.batching import BatchedServingEngine, kv_row_bytes
from repro.serving.cluster import ClusterFrontend, QosAutopilot, ReplicaPool
from repro.serving.frontend import ServingFrontend

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (12, 16, 9, 14)]
    # per-prompt SOLO references (each request alone on a fresh frontend —
    # row-wise determinism makes these equal to any batched run's tokens)
    refs = []
    for p in prompts:
        fe = _fe(cfg, params)
        h = fe.submit(_spec(p))
        fe.drain()
        refs.append(list(h.tokens))
    return cfg, params, prompts, refs


def _fe(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("prefill_budget", 3)
    return ServingFrontend(BatchedServingEngine(
        cfg, params, policy="duo", max_seq=32, temperature=0.0, **kw))


def _spec(p, max_new=MAX_NEW, **kw):
    return GenerationRequest(prompt=p,
                             params=SamplingParams(max_new_tokens=max_new),
                             **kw)


def _poll_until(fe, pred, limit=500):
    for _ in range(limit):
        if pred():
            return
        fe.poll()
    raise AssertionError("condition not reached")


# ---------------------------------------------------------------------------
# round-trip exactness + slot/residency/TBT accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", range(1, MAX_NEW + 1))
def test_pause_resume_every_decode_depth(setup, depth):
    """Pause after `depth` tokens, let ANOTHER request reuse the freed
    slot, resume: tokens bit-identical to the uninterrupted run, and the
    TBT ledger never charges the pause as a gap."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params)
    eng = fe.engine
    h = fe.submit(_spec(prompts[0]))
    _poll_until(fe, lambda: len(h.tokens) >= depth)
    assert not h.done
    d = len(h.tokens)   # the step finishing prefill also decodes, so the
                        # count can overshoot `depth` by one — anchor on it

    snap = fe.pause(h)
    assert h.status == "paused"
    assert snap.state == "running" and snap.n_tokens == d
    assert snap.kv_bytes > 0
    # slot freed on pause; the request contributes NOTHING to load
    assert len(eng._free) == eng.max_batch
    assert_residency_invariants(eng.cache)
    ld = eng.load()
    assert ld.running == 0 and ld.decode_backlog == 0 and ld.held == 0
    assert ld.free_slots == eng.max_batch

    # another request runs to completion in between, reusing the pool
    other = fe.submit(_spec(prompts[2]))
    fe.drain()
    assert other.done and list(other.tokens) == refs[2]
    assert_residency_invariants(eng.cache)

    gaps_before = len(snap.tbt_gaps)
    assert gaps_before == d - 1   # one gap per token after the first
    fe.resume(snap, handle=h)
    assert h.status in ("running", "done")
    assert len(eng._free) == eng.max_batch - 1   # slot reacquired
    new_rid = h.rid
    assert len(eng.tbt.by_rid.get(new_rid, ())) == gaps_before
    if d < MAX_NEW + 1:
        # first post-resume token: NO new gap (no baseline -> the pause
        # interval is never billed); later ones record normally
        _poll_until(fe, lambda: len(h.tokens) >= d + 1)
        if len(h.tokens) == d + 1:
            assert len(eng.tbt.by_rid.get(new_rid, ())) == gaps_before
    fe.drain()
    assert h.done and h.finish_reason == "length"
    assert list(h.tokens) == refs[0], f"diverged at depth {depth}"
    assert len(h.handoffs) == 1
    assert_residency_invariants(eng.cache)


@pytest.mark.parametrize("polls", [1, 2, 3])
def test_pause_resume_mid_prefill(setup, polls):
    """Pause while the request is still CHUNK-prefilling (several chunk
    depths), resume, and the tokens still match the uninterrupted run."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params)
    h = fe.submit(_spec(prompts[1]))   # 16 tokens / budget 3 -> 6 chunks
    for _ in range(polls):
        fe.poll()
    assert h.status == "prefilling"
    snap = fe.pause(h)
    assert snap.state == "prefilling"
    assert 0 < snap.prefill_pos < prompts[1].shape[0]
    assert snap.n_tokens == 0
    assert len(fe.engine._free) == fe.engine.max_batch
    assert_residency_invariants(fe.engine.cache)
    fe.resume(snap, handle=h)
    fe.drain()
    assert h.done and list(h.tokens) == refs[1], \
        f"diverged pausing at prefill_pos={snap.prefill_pos}"
    assert_residency_invariants(fe.engine.cache)


def test_pause_resume_queued_and_restore_guards(setup):
    """A still-queued request snapshots without touching any slot, and
    `can_restore`/`restore` refuse when no free slot exists."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params)
    eng = fe.engine
    h1 = fe.submit(_spec(prompts[0]))
    h2 = fe.submit(_spec(prompts[2]))
    h3 = fe.submit(_spec(prompts[3]))
    fe.poll()   # both slots taken; h3 still queued
    assert h3.status == "queued"
    snap = fe.pause(h3)
    assert snap.state == "queued" and snap.kv_bytes == 0
    assert len(eng.queue) == 0
    # a slot-holding snapshot cannot restore while the pool is full
    run_snap = fe.pause(h1)
    assert run_snap.state == "prefilling"   # 12-token prompt, budget 3
    h_fill = fe.submit(_spec(prompts[1]))
    fe.poll()
    assert not eng._free
    assert not eng.can_restore(run_snap)
    with pytest.raises(AssertionError):
        eng.restore(run_snap)
    # queued snapshots need no slot: restore re-enqueues immediately
    assert eng.can_restore(snap)
    fe.resume(snap, handle=h3)
    assert h3.status == "queued"
    fe.drain()
    assert not eng._free or eng.idle
    fe.resume(run_snap, handle=h1)
    fe.drain()
    for h, ref in zip((h1, h2, h3, h_fill),
                      (refs[0], refs[2], refs[3], refs[1])):
        assert h.done and list(h.tokens) == ref


def test_tbt_ledger_reopen_unit():
    """close()+reopen() semantics in isolation: the reopened request has
    no baseline (first observe records nothing), carried gaps seed only
    the per-request history, and aggregates are not double-counted."""
    led = TBTLedger()
    led.observe(7, 1.0)
    led.observe(7, 1.5)
    led.observe(7, 2.0)
    assert list(led.by_rid[7]) == [0.5, 0.5] and led.total_gaps == 2
    carried = list(led.by_rid[7])
    led.close(7)
    led.reopen(9, carried)
    assert list(led.by_rid[9]) == [0.5, 0.5]
    assert led.total_gaps == 2          # aggregates NOT re-fed
    led.observe(9, 100.0)               # resume after a long pause...
    assert list(led.by_rid[9]) == [0.5, 0.5]   # ...charges NO gap
    assert led.max_gap() == 0.5
    led.observe(9, 100.25)
    assert list(led.by_rid[9]) == [0.5, 0.5, 0.25]
    assert led.total_gaps == 3


# ---------------------------------------------------------------------------
# consumer 1: disaggregated prefill/decode cluster
# ---------------------------------------------------------------------------


def test_disagg_cluster_bit_exact(setup):
    """1 prefill + 1 decode replica behind the disagg router: every
    request prefills on replica 0, hands its KV snapshot to replica 1,
    decodes there — and the tokens match the plain frontend bit-exactly.
    Per-role expert HBM stays at each replica's fixed bound throughout."""
    cfg, params, prompts, refs = setup
    pool = ReplicaPool.build(
        cfg, params, policy="duo", max_batch=2, max_seq=32,
        temperature=0.0, prefill_budget=3,
        overrides=[{"role": "prefill"}, {"role": "decode"}])
    assert pool.roles == ["prefill", "decode"] and pool.disagg
    fe = ClusterFrontend(pool, router="disagg")
    handles = [fe.submit(_spec(p)) for p in prompts]
    assert all(h.replica == 0 for h in handles)   # new work -> prefill
    for _ in range(500):
        if fe.idle:
            break
        fe.poll()
        for eng in pool.engines:
            assert_residency_invariants(eng.cache)
    assert fe.idle
    for h, ref in zip(handles, refs):
        assert h.done and h.finish_reason == "length"
        assert list(h.tokens) == ref
        assert h.replica == 1                      # finished on decode
        assert len(h.handoffs) == 1
        hop = h.handoffs[0]
        assert hop["src"] == 0 and hop["dst"] == 1
        assert hop["t_restore"] >= hop["t_snapshot"]
    assert pool.n_handoffs == len(prompts)
    # role split is real: prefill replica produced ONLY first tokens
    assert len(pool.engines[0].finished) == 0
    assert len(pool.engines[1].finished) == len(prompts)
    assert pool.engines[0].decode_batch_hist == []
    for eng in pool.engines:
        assert eng.cache.hbm_bound_ok and eng.cache.regrow_events == 0


def test_disagg_handoff_waits_for_decode_slot(setup):
    """With a 1-slot decode replica, handoffs serialize: a held request
    waits on the prefill replica until the decode slot frees — and the
    token streams still match the references."""
    cfg, params, prompts, refs = setup
    pool = ReplicaPool.build(
        cfg, params, policy="duo", max_seq=32, temperature=0.0,
        prefill_budget=3,
        overrides=[{"role": "prefill", "max_batch": 4},
                   {"role": "decode", "max_batch": 1}])
    fe = ClusterFrontend(pool, router="disagg")
    handles = [fe.submit(_spec(p)) for p in prompts]
    saw_held_backlog = False
    for _ in range(800):
        if fe.idle:
            break
        fe.poll()
        saw_held_backlog |= len(pool.engines[0].held) >= 2
    assert fe.idle and saw_held_backlog
    for h, ref in zip(handles, refs):
        assert h.done and list(h.tokens) == ref


# ---------------------------------------------------------------------------
# consumer 2: autopilot preemption
# ---------------------------------------------------------------------------


def test_preempt_pauses_victim_and_both_streams_exact(setup):
    """A priority-5 arrival behind a full 1-slot pool preempts the
    priority-0 victim; the winner runs, the victim resumes — both token
    streams match their solo references, and paused state is visible on
    the autopilot (count + host KV bytes) while it lasts."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, max_batch=1)
    ap = QosAutopilot(fe, preempt=True)
    lo = fe.submit(_spec(prompts[0], priority=0))
    _poll_until(fe, lambda: len(lo.tokens) >= 2)
    hi = fe.submit(_spec(prompts[2], priority=5))
    fe.poll()   # scan preempts lo to make room
    assert lo.status == "paused"
    assert ap.n_preempted == 1 and len(ap.paused) == 1
    assert ap.paused_kv_bytes > 0
    assert not fe.idle               # paused work keeps the frontend live
    ld = fe.engine.load()
    assert ld.running + ld.held <= 1   # victim contributes nothing
    fe.drain()
    assert ap.n_resumed == 1 and not ap.paused
    assert hi.done and list(hi.tokens) == refs[2]
    assert lo.done and list(lo.tokens) == refs[0]
    assert lo.finish_reason == "length" and len(lo.handoffs) == 1
    assert_residency_invariants(fe.engine.cache)


def test_preempt_requires_strictly_higher_priority(setup):
    """Equal-priority arrivals never preempt: the newcomer waits for a
    slot like always and n_preempted stays 0."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, max_batch=1)
    ap = QosAutopilot(fe, preempt=True)
    first = fe.submit(_spec(prompts[0], priority=3))
    _poll_until(fe, lambda: len(first.tokens) >= 1)
    second = fe.submit(_spec(prompts[2], priority=3))
    fe.poll()
    assert first.status != "paused" and second.status == "queued"
    fe.drain()
    assert ap.n_preempted == 0 and ap.n_resumed == 0
    assert list(first.tokens) == refs[0]
    assert list(second.tokens) == refs[2]


def test_cancel_while_paused(setup):
    """Cancelling a paused handle drops its snapshot and finishes the
    handle without ever touching an engine again."""
    cfg, params, prompts, refs = setup
    fe = _fe(cfg, params, max_batch=1)
    ap = QosAutopilot(fe, preempt=True)
    lo = fe.submit(_spec(prompts[0], priority=0))
    _poll_until(fe, lambda: len(lo.tokens) >= 1)
    hi = fe.submit(_spec(prompts[2], priority=5))
    fe.poll()
    assert lo.status == "paused" and ap.paused
    assert lo.cancel()
    assert lo.done and lo.finish_reason == "cancelled"
    assert not ap.paused and ap.paused_kv_bytes == 0
    fe.drain()
    assert fe.idle and hi.done and list(hi.tokens) == refs[2]


# ---------------------------------------------------------------------------
# consumer 3: replica draining
# ---------------------------------------------------------------------------


def test_drain_migrates_in_flight_bit_exact(setup):
    """drain(0) mid-flight moves replica 0's requests to replica 1 (what
    fits immediately, the rest retried per poll); every stream matches its
    reference, replica 0 ends idle, and new work routes around it until
    undrain()."""
    cfg, params, prompts, refs = setup
    pool = ReplicaPool.build(cfg, params, 2, policy="duo", max_batch=4,
                             max_seq=32, temperature=0.0, prefill_budget=3)
    fe = ClusterFrontend(pool, router="round_robin")
    handles = [fe.submit(_spec(p)) for p in prompts]
    for _ in range(3):
        fe.poll()
    pool.drain(0)
    assert 0 not in pool.routable()
    rerouted = fe.submit(_spec(prompts[2], max_new=2))
    assert rerouted.replica == 1
    fe.drain()
    assert fe.idle and pool.engines[0].idle
    assert pool.n_migrated >= 1
    for h, ref in zip(handles, refs):
        assert h.done and h.finish_reason == "length"
        assert list(h.tokens) == ref
        assert h.replica == 1
    for eng in pool.engines:
        assert_residency_invariants(eng.cache)
    pool.undrain(0)
    assert pool.routable() == [0, 1]
    back = fe.submit(_spec(prompts[0], max_new=1))
    assert back.replica == 1   # global cursor at 5 -> 5 % 2 candidates
    fe.drain()
    assert back.done


# ---------------------------------------------------------------------------
# tail-only handoff (cross-request prefix reuse, ISSUE 7)
# ---------------------------------------------------------------------------


def test_disagg_tail_handoff_bit_exact_and_cheaper(setup):
    """Disagg handoff with a warm shared head on the decode replica ships
    only the unique tail: bit-exact vs the full-prefix handoff, and the
    bytes moved drop by exactly head * kv_row_bytes."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(11)
    head = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    shared = [np.concatenate([head, rng.integers(0, cfg.vocab, size=n)
                              .astype(np.int32)]) for n in (5, 6)]
    shared[1][8] = (shared[0][8] + 1) % cfg.vocab  # diverge right after head
    refs = []
    for p in shared:
        fe = _fe(cfg, params)
        h = fe.submit(_spec(p))
        fe.drain()
        refs.append(list(h.tokens))

    def run(prefix_cache):
        pool = ReplicaPool.build(
            cfg, params, policy="duo", max_batch=2, max_seq=32,
            temperature=0.0, prefill_budget=3, prefix_cache=prefix_cache,
            overrides=[{"role": "prefill"}, {"role": "decode"}])
        fe = ClusterFrontend(pool, router="disagg")
        toks = []
        for p in shared:          # sequential: the 2nd finds a warm head
            h = fe.submit(_spec(p))
            fe.drain()
            toks.append(list(h.tokens))
        return pool, toks

    cold_pool, cold_toks = run(prefix_cache=False)
    warm_pool, warm_toks = run(prefix_cache=True)
    assert cold_toks == refs and warm_toks == refs
    assert cold_pool.n_tail_handoffs == 0
    assert cold_pool.handoff_bytes_saved == 0
    # the 2nd warm handoff shipped only the tail...
    assert warm_pool.n_tail_handoffs == 1
    assert warm_pool.handoff_bytes_saved == 8 * kv_row_bytes(
        warm_pool.engines[0])
    # ...so total bytes moved strictly dropped, by exactly the head
    assert warm_pool.handoff_bytes < cold_pool.handoff_bytes
    assert warm_pool.handoff_bytes + warm_pool.handoff_bytes_saved \
        == cold_pool.handoff_bytes


def test_preempt_resume_prefix_reusing_request(setup):
    """A request that itself seeded its KV from the prefix tree pauses and
    resumes bit-exactly — both mid-decode and mid-(seeded)-prefill."""
    cfg, params, _, _ = setup
    rng = np.random.default_rng(12)
    donor = rng.integers(0, cfg.vocab, size=14).astype(np.int32)
    probe = np.concatenate([donor[:9],
                            rng.integers(0, cfg.vocab, size=6)
                            .astype(np.int32)])
    probe[9] = (donor[9] + 1) % cfg.vocab
    fe0 = _fe(cfg, params)
    h0 = fe0.submit(_spec(probe))
    fe0.drain()
    ref = list(h0.tokens)

    # mid-decode pause/resume of a prefix-hit request
    fe = _fe(cfg, params, prefix_cache=True)
    eng = fe.engine
    fe.submit(_spec(donor))
    fe.drain()
    h = fe.submit(_spec(probe))
    _poll_until(fe, lambda: len(h.tokens) >= 2)
    assert eng.prefix.hit_tokens == 9
    snap = fe.pause(h)
    fe.resume(snap, h)
    fe.drain()
    assert list(h.tokens) == ref
    eng.prefix.check_invariants(eng.W)

    # mid-prefill pause/resume: pause while the seeded request is still
    # chunking its un-hit suffix (prefill_pos starts AT the hit length)
    fe2 = _fe(cfg, params, prefix_cache=True)
    eng2 = fe2.engine
    fe2.submit(_spec(donor))
    fe2.drain()
    h2 = fe2.submit(_spec(probe))
    fe2.poll()                       # admit + first 3-token chunk
    assert h2.status == "prefilling"
    snap2 = fe2.pause(h2)
    assert snap2.state == "prefilling" and snap2.prefill_pos >= 9
    fe2.resume(snap2, h2)
    fe2.drain()
    assert list(h2.tokens) == ref
    assert_residency_invariants(eng2.cache)
    eng2.prefix.check_invariants(eng2.W)


def test_tbt_reopen_aggregates_not_double_fed():
    """Regression pin for the windowed/P^2 aggregates on reopen: carried
    gaps seed ONLY the per-request history — the shared window, both
    sketches, the lifetime count, and the max are untouched, and the next
    observe after resume feeds each aggregate exactly once."""
    led = TBTLedger()
    for t in (1.0, 1.5, 2.1, 2.4):
        led.observe(3, t)
    carried = list(led.by_rid[3])
    before = (list(led.gaps), {q: sk.count for q, sk in led.sketches.items()},
              led.total_gaps, led.max_gap())
    led.close(3)
    led.reopen(8, carried)
    after = (list(led.gaps), {q: sk.count for q, sk in led.sketches.items()},
             led.total_gaps, led.max_gap())
    assert after == before, "reopen re-fed the aggregates"
    assert list(led.by_rid[8]) == carried
    led.observe(8, 50.0)             # resume baseline: no gap anywhere
    assert (led.total_gaps, list(led.gaps)) == (before[2], before[0])
    led.observe(8, 50.2)             # first real post-resume gap...
    assert led.total_gaps == before[2] + 1   # ...feeds each aggregate once
    assert all(sk.count == before[1][q] + 1
               for q, sk in led.sketches.items())
    assert len(led.gaps) == len(before[0]) + 1
