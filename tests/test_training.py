"""Training substrate tests: optimizer, loss descent, checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticLM
from repro.models.model import build
from repro.training import checkpoint
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train_loop import make_train_step, lm_loss


def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    st = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, st, gn = opt.update(grads, st, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert int(st.step) == 150


def test_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    st = opt.init(params)
    _, _, gn = opt.update({"w": jnp.full(3, 100.0)}, st, params)
    assert float(gn) > 1.0  # raw norm reported; update was clipped


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) <= 0.11


def test_moe_train_loss_decreases():
    cfg = reduced(get_config("qwen2_moe_a2_7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=2e-3, weight_decay=0.01)
    st = opt.init(params)
    step = jax.jit(make_train_step(bundle, opt))
    data = SyntheticLM(cfg.vocab, seed=0)
    it = data.batches(4, 32)
    losses = []
    for i in range(25):
        batch = {"tokens": jnp.asarray(next(it))}
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]
    assert np.isfinite(losses).all()


def test_microbatch_equivalence():
    """Grad accumulation over microbatches ~= full-batch step (same loss)."""
    cfg = reduced(get_config("qwen3_1_7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab)}
    s1 = make_train_step(bundle, opt, microbatches=1)
    s2 = make_train_step(bundle, opt, microbatches=2)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 0.05


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_config("gemma3_1b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, params, extra={"step": 7})
    loaded, extra = checkpoint.load(path, like=params)
    assert extra["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), params, loaded)


def test_lm_loss_shift():
    logits = jnp.zeros((1, 4, 8))
    tokens = jnp.array([[1, 2, 3, 4]])
    l = lm_loss(logits, tokens)
    np.testing.assert_allclose(float(l), np.log(8), rtol=1e-5)
