"""Streaming request-handle front-end invariants (ISSUE 4 tentpole):

  * Streaming equivalence — for ANY schedule of poll() calls, the token
    sequence each RequestHandle yields at temperature 0 is bit-identical to
    run_until_drained() output for the same prompts (chunked prefill +
    mid-flight admission included).
  * Cancellation safety — a cancelled request never emits further events,
    its KV slot and expert-residency/TBT-ledger resources are reclaimed
    synchronously (within the cancel call, i.e. well within one step), the
    freed slot is reused, expert HBM stays at the fixed
    capacity * bytes_per_expert bound after every step (the
    test_residency.py assertion), and surviving requests' tokens are
    bit-exact vs a never-cancelled run.
"""
import jax
import numpy as np
import pytest

from test_residency import assert_residency_invariants

from repro.configs.base import get_config, reduced
from repro.models.model import build
from repro.serving.api import (FinishEvent, GenerationRequest,
                               SamplingParams)
from repro.serving.batching import BatchedServingEngine
from repro.serving.engine import MoEServingEngine
from repro.serving.frontend import ServingFrontend

MAX_NEW = 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (12, 16, 9, 14)]
    seq = MoEServingEngine(cfg, params, policy="duo", temperature=0.0)
    refs = [seq.serve(p, max_new=MAX_NEW) for p in prompts]
    return cfg, params, prompts, refs


def _make(cfg, params, *, max_batch=2, prefill_budget=None):
    eng = BatchedServingEngine(cfg, params, policy="duo",
                               max_batch=max_batch, max_seq=32,
                               temperature=0.0,
                               prefill_budget=prefill_budget)
    return eng, ServingFrontend(eng)


def _submit_all(fe, prompts):
    return [fe.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=MAX_NEW)))
        for p in prompts]


# three very different poll()/read interleavings ---------------------------
def _drive_exhaust_each(fe, handles):
    """Fully stream handle 0 to completion, then handle 1, ..."""
    return [list(h) for h in handles]


def _drive_round_robin(fe, handles):
    """One token from each live handle in turn (max interleaving)."""
    outs = [[] for _ in handles]
    iters = [iter(h) for h in handles]
    live = list(range(len(handles)))
    while live:
        for i in list(live):
            try:
                outs[i].append(next(iters[i]))
            except StopIteration:
                live.remove(i)
    return outs


def _drive_drain_then_read(fe, handles):
    """Poll everything to completion first, read token buffers after."""
    fe.drain()
    return [h.tokens for h in handles]


DRIVERS = [_drive_exhaust_each, _drive_round_robin, _drive_drain_then_read]


@pytest.mark.parametrize("budget", [None, 3])
@pytest.mark.parametrize("driver", DRIVERS, ids=lambda d: d.__name__[7:])
def test_streaming_equivalence_any_poll_schedule(setup, budget, driver):
    """Every poll/read schedule yields run_until_drained()'s exact tokens —
    monolithic AND chunked prefill, with mid-flight admission (4 requests
    through 2 KV slots)."""
    cfg, params, prompts, refs = setup
    eng, fe = _make(cfg, params, max_batch=2, prefill_budget=budget)
    handles = _submit_all(fe, prompts)
    outs = driver(fe, handles)
    for i, (h, out) in enumerate(zip(handles, outs)):
        np.testing.assert_array_equal(np.asarray(out), refs[i].tokens,
                                      err_msg=f"handle {i} diverged")
        assert h.finish_reason == "length"
        assert h.status == "done"
        r = h.result()
        np.testing.assert_array_equal(r.tokens, refs[i].tokens)
    assert fe.idle


@pytest.mark.parametrize("cancel_at", [1, 3])
def test_cancel_mid_decode(setup, cancel_at):
    """Cancel a decoding request after `cancel_at` tokens: synchronous
    reclamation, slot reuse, per-step residency/HBM invariants, survivors
    bit-exact, and silence after the FinishEvent."""
    cfg, params, prompts, refs = setup
    eng, fe = _make(cfg, params, max_batch=4)
    handles = _submit_all(fe, prompts[:3])
    victim = handles[1]
    while len(victim.tokens) < cancel_at:
        fe.poll()
        assert_residency_invariants(eng.cache)
    vslot = victim.req.slot
    # a step can emit two tokens for a request (first + one decode), so
    # record the actual prefix length at the instant of cancellation
    n_cancel = len(victim.tokens)
    assert n_cancel >= cancel_at
    assert victim.cancel()
    # terminal the moment cancel() returns; resources already reclaimed
    assert victim.done and victim.finish_reason == "cancelled"
    assert victim.status == "cancelled"
    assert isinstance(victim.events[-1], FinishEvent)
    assert vslot in eng._free
    assert victim.req.pf_k is None and victim.req.active_sets is None
    assert victim.req.rid not in eng.tbt._last        # ledger entry closed
    assert_residency_invariants(eng.cache)
    assert not victim.cancel()                        # idempotent
    # freed slot is immediately reusable by a new submission
    fresh = fe.submit(GenerationRequest(
        prompt=prompts[3], params=SamplingParams(max_new_tokens=MAX_NEW)))
    fe.poll()
    assert fresh.req.slot == vslot
    n_ev = len(victim.events)
    while not fe.idle:
        ev = fe.poll()
        assert not [e for e in ev if e.rid == victim.rid], \
            "cancelled request emitted after its FinishEvent"
        assert_residency_invariants(eng.cache)
    assert len(victim.events) == n_ev
    # survivors and the slot-reuser are bit-exact vs never-cancelled runs
    for i, h in ((0, handles[0]), (2, handles[2]), (3, fresh)):
        np.testing.assert_array_equal(h.result().tokens, refs[i].tokens,
                                      err_msg=f"survivor {i} perturbed")
    # cancelled partial result: exactly the tokens emitted before cancel
    r = victim.result()
    assert r.finish_reason == "cancelled"
    np.testing.assert_array_equal(r.tokens, refs[1].tokens[:n_cancel])


@pytest.mark.parametrize("polls_before_cancel", [1, 2])
def test_cancel_mid_prefill(setup, polls_before_cancel):
    """Cancel while a request is still prefilling in chunks: its KV slot
    and chunk buffers are freed, its accumulated expert contributions leave
    the shared ledger, and everything else stays bit-exact."""
    cfg, params, prompts, refs = setup
    eng, fe = _make(cfg, params, max_batch=4, prefill_budget=2)
    handles = _submit_all(fe, prompts[:3])
    # rr rotation: rid1 (16 tokens, budget 2/step) stays prefilling longest
    victim = handles[1]
    for _ in range(polls_before_cancel):
        fe.poll()
        assert_residency_invariants(eng.cache)
    assert victim.status == "prefilling"
    assert victim.req.prefill_remaining > 0
    vslot = victim.req.slot
    assert victim.cancel()
    assert victim.done and victim.finish_reason == "cancelled"
    assert vslot in eng._free
    assert victim.req.pf_k is None and victim.req.pf_v is None
    assert victim.req.active_sets is None
    assert_residency_invariants(eng.cache)
    assert victim.tokens == []                 # never produced a token
    fresh = fe.submit(GenerationRequest(
        prompt=prompts[3], params=SamplingParams(max_new_tokens=MAX_NEW)))
    fe.poll()
    assert fresh.req.slot == vslot             # freed KV slot reused
    while not fe.idle:
        ev = fe.poll()
        assert not [e for e in ev if e.rid == victim.rid]
        assert_residency_invariants(eng.cache)
    for i, h in ((0, handles[0]), (2, handles[2]), (3, fresh)):
        np.testing.assert_array_equal(h.result().tokens, refs[i].tokens,
                                      err_msg=f"survivor {i} perturbed")
    # cancelled before any token: the partial result has no TTFT
    r = victim.result()
    assert r.tokens.size == 0 and np.isnan(r.ttft_wall)


def test_cancel_queued_request(setup):
    """Cancelling before admission just dequeues: no slot was ever held,
    the request never runs, later submissions are unaffected."""
    cfg, params, prompts, refs = setup
    eng, fe = _make(cfg, params, max_batch=1)
    h0 = fe.submit(GenerationRequest(
        prompt=prompts[0], params=SamplingParams(max_new_tokens=MAX_NEW)))
    h1 = fe.submit(GenerationRequest(
        prompt=prompts[1], params=SamplingParams(max_new_tokens=MAX_NEW)))
    fe.poll()                                   # h0 takes the only slot
    assert h1.status == "queued"
    assert h1.cancel()
    assert h1.status == "cancelled" and len(eng.queue) == 0
    assert h1.req.slot == -1
    fe.drain()
    np.testing.assert_array_equal(h0.result().tokens, refs[0].tokens)
    assert h1.tokens == []


def test_rejected_handle(setup):
    """An admission-shed request's handle turns terminal with
    finish_reason='rejected'; result() raises (it never ran)."""
    from repro.core.qos import AdmissionController, LatencyModel
    from repro.serving.batching import RequestQueue
    cfg, params, prompts, _ = setup
    queue = RequestQueue(AdmissionController(
        LatencyModel(prefill_per_token=100.0), default_ttft_slo=0.1))
    eng = BatchedServingEngine(cfg, params, policy="duo", max_batch=2,
                               max_seq=32, queue=queue, temperature=0.0)
    fe = ServingFrontend(eng)
    h = fe.submit(GenerationRequest(
        prompt=prompts[0], params=SamplingParams(max_new_tokens=2)))
    fe.poll()
    assert h.done and h.finish_reason == "rejected"
    assert h.status == "rejected" and h.tokens == []
    with pytest.raises(RuntimeError, match="rejected"):
        h.result()


def test_handle_streams_stop_token(setup):
    """Stop-token termination streams exactly the stopped prefix and the
    handle reports finish_reason='stop_token'."""
    cfg, params, prompts, refs = setup
    stop = int(refs[0].tokens[2])
    eng, fe = _make(cfg, params, max_batch=2)
    h = fe.submit(GenerationRequest(
        prompt=prompts[0],
        params=SamplingParams(max_new_tokens=MAX_NEW,
                              stop_token_ids=(stop,))))
    assert list(h) == refs[0].tokens[:3].tolist()
    assert h.finish_reason == "stop_token"
