"""repro.analysis: rule-by-rule lint tests (known-bad snippets each rule
must flag, known-good dispatch-point code it must pass), allowlist
semantics, seeded-violation detection on a copy of the real package, CLI
exit codes, and the jaxpr auditor (clean run + seeded callback / captured
const / broken donation)."""
import shutil
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ALL_RULES, AllowEntry, load_allowlist, run_lint
from repro.analysis.lint import _parse_toml_minimal


def lint_snippet(tmp_path, relpath, code, allowlist=()):
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    return run_lint(tmp_path, ALL_RULES, allowlist)


def rules_of(report):
    return [f.rule for f in report.findings]


# --------------------------------------------------------------------------
# sync-point
# --------------------------------------------------------------------------


def test_sync_point_flags_asarray_in_decode_step(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        import numpy as np
        class BatchedServingEngine:
            def _decode_step(self, batch):
                ids_np = np.asarray(self.ids)
                return ids_np
    """)
    assert rules_of(rep) == ["sync-point"]
    f = rep.findings[0]
    assert f.path == "serving/batching.py" and f.line == 5
    assert f.scope == "BatchedServingEngine._decode_step"
    assert f.call == "np.asarray"


@pytest.mark.parametrize("call", [
    "x.item()", "x.block_until_ready()", "x.tolist()",
    "jax.device_get(x)", "float(x)",
])
def test_sync_point_flags_every_sync_form(tmp_path, call):
    rep = lint_snippet(tmp_path, "serving/engine.py", f"""
        import jax
        class MoEServingEngine:
            def decode(self, x):
                return {call}
    """)
    assert rules_of(rep) == ["sync-point"]


def test_sync_point_ignores_cold_scopes(tmp_path):
    # same sync call, but in a scope that is not on the per-token path
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        import numpy as np
        class BatchedServingEngine:
            def snapshot(self, req):
                return np.asarray(self.thing)
        def helper(x):
            return np.asarray(x)
    """)
    assert rep.findings == []


def test_sync_point_ignores_host_literals(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        import numpy as np
        class BatchedServingEngine:
            def _decode_step(self, batch):
                a = np.asarray([r.pos for r in batch], np.int32)
                b = np.asarray([1, 2, 3])
                c = float("nan")
                return a, b, c
    """)
    assert rep.findings == []


def test_sync_point_scans_kernels_everywhere(tmp_path):
    rep = lint_snippet(tmp_path, "kernels/custom.py", """
        import numpy as np
        def my_kernel(x):
            return np.asarray(x)
    """)
    assert rules_of(rep) == ["sync-point"]


# --------------------------------------------------------------------------
# emit-discipline
# --------------------------------------------------------------------------


def test_emit_flags_token_event_outside_sink(tmp_path):
    rep = lint_snippet(tmp_path, "serving/frontend.py", """
        from repro.serving.api import TokenEvent
        class ServingFrontend:
            def poll(self, rid, tok):
                self.engine._emit(TokenEvent(rid=rid, token=tok, index=0,
                                             t=0.0))
    """)
    assert rules_of(rep) == ["emit-discipline"]
    assert rep.findings[0].call == "TokenEvent"


def test_emit_flags_raw_buffer_append(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def _retire(self, ev):
                self._events.append(ev)
    """)
    assert rules_of(rep) == ["emit-discipline"]


def test_emit_allows_the_sinks(tmp_path):
    rep = lint_snippet(tmp_path, "serving/engine.py", """
        from repro.serving.api import TokenEvent
        class EngineCore:
            def _emit(self, ev):
                self._events.append(ev)
        class MoEServingEngine:
            def _emit_token(self, rid, token, index):
                self._emit(TokenEvent(rid=rid, token=token, index=index,
                                      t=0.0))
    """)
    assert rep.findings == []


# --------------------------------------------------------------------------
# residency-discipline
# --------------------------------------------------------------------------


def test_residency_flags_kv_write_outside_writers(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def evil_helper(self, l, ck):
                self._K[l] = self._K[l].at[0].set(ck)
    """)
    assert rules_of(rep) == ["residency-discipline"]
    assert rep.findings[0].call == "_K"


def test_residency_flags_pool_write_outside_residency(tmp_path):
    rep = lint_snippet(tmp_path, "core/cache.py", """
        class SomethingElse:
            def poke(self, w):
                self._pools["w1"] = w
    """)
    assert rules_of(rep) == ["residency-discipline"]


def test_residency_allows_declared_writers(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def _decode_step(self, l, ck, jidx):
                self._K[l] = self._K[l].at[jidx].set(ck)
            def restore(self, l, K, V):
                self._K[l], self._V[l] = K, V
            def _release_slot(self, req):
                self._slot_pos[req.slot, :] = -1
    """)
    assert rep.findings == []
    rep2 = lint_snippet(tmp_path / "b", "core/cache.py", """
        class ExpertResidency:
            def prefetch(self, name, val):
                self._pools[name] = val
    """)
    assert rep2.findings == []


# --------------------------------------------------------------------------
# jit-hygiene
# --------------------------------------------------------------------------


def test_jit_flags_jit_in_loop_body(tmp_path):
    rep = lint_snippet(tmp_path, "core/predictor.py", """
        import jax
        def train(xs):
            for x in xs:
                f = jax.jit(lambda a: a + 1)
                f(x)
    """)
    assert "jit-hygiene" in rules_of(rep)


def test_jit_flags_inline_invocation(tmp_path):
    rep = lint_snippet(tmp_path, "core/util.py", """
        import jax
        def apply(x):
            return jax.jit(lambda a: a * 2)(x)
    """)
    assert "jit-hygiene" in rules_of(rep)


def test_jit_flags_serving_method_jit(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        import jax
        class BatchedServingEngine:
            def step(self):
                self._f = jax.jit(self._raw)
    """)
    assert "jit-hygiene" in rules_of(rep)


def test_jit_flags_mutable_closure_capture(tmp_path):
    rep = lint_snippet(tmp_path, "serving/engine.py", """
        import jax
        class EngineCore:
            def _jit_fns(self):
                @jax.jit
                def bad(x):
                    return x + self.cache.capacity
                self._bad = bad
    """)
    assert "jit-hygiene" in rules_of(rep)
    assert "self.cache" in rep.findings[0].message


def test_jit_allows_setup_scope_and_module_level(tmp_path):
    rep = lint_snippet(tmp_path, "serving/engine.py", """
        import jax
        class EngineCore:
            def _jit_fns(self):
                @jax.jit
                def good(x):
                    return x * self.cfg.scale + self.E
                self._good = good
    """)
    assert rep.findings == []
    rep2 = lint_snippet(tmp_path / "b", "core/cache.py", """
        import functools, jax
        @functools.partial(jax.jit, donate_argnums=(0,))
        def _pool_write(pool, slot, slab):
            return pool.at[slot].set(slab)
    """)
    assert rep2.findings == []


def test_jit_allows_jit_before_loop(tmp_path):
    # the train_predictor pattern: define once, THEN loop
    rep = lint_snippet(tmp_path, "core/predictor.py", """
        import jax
        def train(xs):
            f = jax.jit(lambda a: a + 1)
            for x in xs:
                f(x)
    """)
    assert rep.findings == []


# --------------------------------------------------------------------------
# recompile-hazard
# --------------------------------------------------------------------------


def test_recompile_flags_raw_slice_bound(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def _decode_step(self, xn, rows, n_live):
                return self._grouped_raw(xn[:n_live], rows)
    """)
    assert "recompile-hazard" in rules_of(rep)
    assert "n_live" in rep.findings[0].message


def test_recompile_flags_runtime_shape_ctor(tmp_path):
    rep = lint_snippet(tmp_path, "serving/engine.py", """
        import jax.numpy as jnp
        class EngineCore:
            def _run(self, xn, n):
                return self._expert_raw(jnp.zeros((n, 4)), xn)
    """)
    assert "recompile-hazard" in rules_of(rep)


def test_recompile_allows_bucketed_shapes(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def _decode_step(self, xn, ids_np, union, B):
                C = _bucket(len(union), B)
                disp = group_by_expert(ids_np, union, bucket_cap=B)
                a = self._grouped_raw(xn[:C], disp.row_idx)
                b = self._grouped_raw(xn, disp.row_idx[:, :C])
                return a, b
    """)
    assert rep.findings == []


def test_recompile_allows_static_and_config_bounds(tmp_path):
    rep = lint_snippet(tmp_path, "serving/engine.py", """
        class EngineCore:
            def _run(self, xn):
                return self._expert_raw(xn[:, :4], xn[:, -1], xn[:, :self.k])
    """)
    assert rep.findings == []


# --------------------------------------------------------------------------
# obs-discipline
# --------------------------------------------------------------------------


def test_obs_flags_migrated_metric_write(tmp_path):
    rep = lint_snippet(tmp_path, "serving/cluster.py", """
        class ReplicaPool:
            def migrate(self, rid, src, dst):
                self.n_handoffs += 1
                return rid
    """)
    assert rules_of(rep) == ["obs-discipline"]
    f = rep.findings[0]
    assert f.call == "n_handoffs"
    assert "read-only view" in f.message


def test_obs_flags_subscript_write_to_migrated_metric(tmp_path):
    rep = lint_snippet(tmp_path, "serving/cluster.py", """
        class QosAutopilot:
            def scan(self, now, reason):
                self.by_reason[reason] += 1
    """)
    assert rules_of(rep) == ["obs-discipline"]
    assert rep.findings[0].call == "by_reason"


def test_obs_flags_perf_field_write(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def _retire(self, r):
                self.perf.decode_layers = 0
    """)
    assert rules_of(rep) == ["obs-discipline"]
    f = rep.findings[0]
    assert f.call == "perf.decode_layers"
    assert "perf.inc" in f.message


def test_obs_flags_span_call_outside_declared_scope(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def _some_helper(self, r):
                self.obs.instant("request.peeked", "lifecycle", rid=r.rid)
    """)
    assert "obs-discipline" in rules_of(rep)
    f = next(f for f in rep.findings if f.rule == "obs-discipline")
    assert f.call == "obs.instant"
    assert "SPAN_SCOPES" in f.message


def test_obs_allows_span_calls_in_declared_scopes(tmp_path):
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            def _retire(self, r):
                self.obs.terminal(r.rid, r.finish_reason)

            def submit_request(self, r):
                self.obs.instant("request.queued", "lifecycle", rid=r.rid)
    """)
    assert [f for f in rep.findings if f.rule == "obs-discipline"] == []


def test_obs_allows_registry_mutation_and_view_reads(tmp_path):
    rep = lint_snippet(tmp_path, "serving/cluster.py", """
        class ReplicaPool:
            def migrate(self, rid, nbytes):
                self._c_handoffs.inc()
                self._c_handoff_bytes.inc(nbytes)
                total = self.n_handoffs + self.handoff_bytes  # reads are fine
                return total
    """)
    assert rep.findings == []


# --------------------------------------------------------------------------
# allowlist mechanics
# --------------------------------------------------------------------------


BAD_DECODE = """
    import numpy as np
    class BatchedServingEngine:
        def _decode_step(self, batch):
            ids_np = np.asarray(self.ids)
            extra = np.asarray(self.other)
            return ids_np, extra
"""


def test_allowlist_suppresses_only_the_pinned_arg(tmp_path):
    allow = [AllowEntry(rule="sync-point", reason="declared dispatch point",
                        path="serving/batching.py",
                        scope="BatchedServingEngine._decode_step",
                        call="np.asarray", arg="self.ids")]
    rep = lint_snippet(tmp_path, "serving/batching.py", BAD_DECODE, allow)
    # the pinned arg is suppressed; the NEW sync in the same scope is not
    assert len(rep.findings) == 1
    assert rep.findings[0].arg == "self.other"
    assert len(rep.suppressed) == 1
    assert rep.unused_allows == []


def test_allowlist_reports_unused_entries(tmp_path):
    allow = [AllowEntry(rule="sync-point", reason="stale",
                        path="serving/engine.py", scope="Gone.method")]
    rep = lint_snippet(tmp_path, "serving/batching.py", """
        class BatchedServingEngine:
            pass
    """, allow)
    assert rep.unused_allows == allow


def test_minimal_toml_parser_roundtrip(tmp_path):
    text = """
    # comment
    [[allow]]
    rule = "sync-point"
    path = "serving/engine.py"
    scope = "EngineCore._sample"
    call = "np.asarray"
    arg = "logits"
    reason = "has \\"quotes\\" and a # hash"
    [[allow]]
    rule = "emit-discipline"
    reason = "second entry"
    """
    data = _parse_toml_minimal(textwrap.dedent(text))
    assert len(data["allow"]) == 2
    assert data["allow"][0]["scope"] == "EngineCore._sample"
    assert "# hash" in data["allow"][0]["reason"]
    p = tmp_path / "a.toml"
    p.write_text(textwrap.dedent(text))
    entries = load_allowlist(p)
    assert entries[0].arg == "logits" and entries[1].rule == "emit-discipline"


def test_allowlist_rejects_unknown_keys(tmp_path):
    p = tmp_path / "a.toml"
    p.write_text('[[allow]]\nrule = "sync-point"\nreason = "x"\nfile = "y"\n')
    with pytest.raises(ValueError, match="unknown keys"):
        load_allowlist(p)


# --------------------------------------------------------------------------
# the real package: clean baseline + seeded violations
# --------------------------------------------------------------------------


def _package_root() -> Path:
    import repro

    return Path(next(iter(repro.__path__)))


def _real_allowlist():
    return load_allowlist(_package_root() / "analysis" / "allowlist.toml")


def test_repo_lints_clean():
    rep = run_lint(_package_root(), ALL_RULES, _real_allowlist())
    assert rep.findings == [], "\n".join(f.format() for f in rep.findings)
    assert rep.unused_allows == []


def _copy_package(tmp_path) -> Path:
    dst = tmp_path / "repro"
    shutil.copytree(_package_root(), dst)
    return dst


def test_seeded_sync_in_decode_step_is_caught(tmp_path):
    root = _copy_package(tmp_path)
    f = root / "serving" / "batching.py"
    src = f.read_text()
    anchor = "ids_np = np.asarray(ids).reshape(B, self.k)"
    assert anchor in src
    f.write_text(src.replace(
        anchor, anchor + "\n            _stall = np.asarray(x)", 1))
    rep = run_lint(root, ALL_RULES, _real_allowlist())
    assert len(rep.findings) == 1
    found = rep.findings[0]
    assert found.rule == "sync-point"
    assert found.path == "serving/batching.py"
    assert found.scope == "BatchedServingEngine._decode_step"
    assert found.arg == "x" and found.line > 0


def test_seeded_unbucketed_shape_is_caught(tmp_path):
    root = _copy_package(tmp_path)
    f = root / "serving" / "engine.py"
    src = f.read_text()
    anchor = "return self._grouped_raw(xn, jrows, *self.cache.pools, jslots)"
    assert anchor in src
    f.write_text(src.replace(
        anchor,
        "return self._grouped_raw(xn, jrows[:len(union)], "
        "*self.cache.pools, jslots)", 1))
    rep = run_lint(root, ALL_RULES, _real_allowlist())
    assert [f.rule for f in rep.findings] == ["recompile-hazard"]
    assert rep.findings[0].path == "serving/engine.py"


def test_seeded_kv_write_is_caught(tmp_path):
    root = _copy_package(tmp_path)
    f = root / "serving" / "batching.py"
    src = f.read_text()
    anchor = "def _sample_req(self, r"
    assert anchor in src
    idx = src.index(anchor)
    line_end = src.index("\n", src.index(":", idx))
    body_insert = "\n        self._K[0] = None"
    f.write_text(src[:line_end] + body_insert + src[line_end:])
    rep = run_lint(root, ALL_RULES, _real_allowlist())
    assert "residency-discipline" in [x.rule for x in rep.findings]


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def test_cli_exit_codes(tmp_path, capsys):
    from repro.analysis.__main__ import main

    assert main(["--list-rules"]) == 0
    # real package, lint only: clean
    assert main(["--no-jaxpr"]) == 0
    # seeded violation: nonzero + rule id + file:line in output
    root = _copy_package(tmp_path)
    f = root / "serving" / "batching.py"
    src = f.read_text()
    anchor = "ids_np = np.asarray(ids).reshape(B, self.k)"
    f.write_text(src.replace(
        anchor, anchor + "\n            _stall = np.asarray(x)", 1))
    capsys.readouterr()
    code = main(["--no-jaxpr", "--root", str(root),
                 "--allowlist", str(root / "analysis" / "allowlist.toml")])
    out = capsys.readouterr().out
    assert code == 1
    assert "sync-point" in out and "serving/batching.py:" in out


# --------------------------------------------------------------------------
# jaxpr audit
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def audit_engine():
    from repro.analysis.jaxpr_audit import build_audit_engine

    return build_audit_engine()


def test_jaxpr_audit_clean(audit_engine):
    from repro.analysis.jaxpr_audit import run_audit

    rep = run_audit(eng=audit_engine)
    assert rep.ok, "\n".join(f.format() for f in rep.findings)
    assert rep.compile_keys <= rep.compile_key_bound
    assert len(rep.kernels) >= 14


def test_jaxpr_audit_flags_host_callback(audit_engine):
    from repro.analysis.jaxpr_audit import KernelSpec, audit_kernel

    def leaky(x):
        return jax.pure_callback(
            lambda a: np.asarray(a), jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    spec = KernelSpec("leaky", jax.jit(leaky),
                      (jax.ShapeDtypeStruct((4,), jnp.float32),))
    findings = audit_kernel(spec)
    assert any(f.rule == "jaxpr-callback" for f in findings)


def test_jaxpr_audit_flags_captured_weight(audit_engine):
    from repro.analysis.jaxpr_audit import KernelSpec, audit_kernel

    big = jnp.ones((256, 256), jnp.float32)  # 256 KiB > limit

    spec = KernelSpec("capturer", jax.jit(lambda x: x @ big),
                      (jax.ShapeDtypeStruct((2, 256), jnp.float32),))
    findings = audit_kernel(spec)
    assert any(f.rule == "jaxpr-const" for f in findings)


def test_jaxpr_audit_flags_missing_donation(audit_engine):
    from repro.analysis.jaxpr_audit import KernelSpec, audit_kernel

    copying = jax.jit(lambda pool, v: pool.at[0].set(v))  # no donate_argnums
    spec = KernelSpec(
        "copying_write", copying,
        (jax.ShapeDtypeStruct((64, 1024), jnp.float32),
         jax.ShapeDtypeStruct((1024,), jnp.float32)),
        donate=(0,))
    findings = audit_kernel(spec)
    assert any(f.rule == "jaxpr-donation" for f in findings)


def test_compile_key_enumeration_matches_measurement(audit_engine):
    from repro.analysis.jaxpr_audit import (compile_key_bound,
                                            enumerate_grouped_keys,
                                            measure_grouped_keys)

    eng = audit_engine
    keys = enumerate_grouped_keys(eng.max_batch, eng.E, eng.k)
    measured = measure_grouped_keys(eng.max_batch, eng.E, eng.k)
    assert measured <= keys
    assert len(keys) <= compile_key_bound(eng.max_batch, eng.E, eng.k)
    # every padded dim the buckets produce is a power of two (or the clamp)
    for (B, U, C) in keys:
        assert U & (U - 1) == 0 or U == min(eng.E, B * eng.k)
        assert C & (C - 1) == 0 or C == B
