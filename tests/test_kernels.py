"""Per-kernel shape/dtype sweeps asserting allclose vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.expert_ffn import expert_ffn, expert_ffn_from_pool
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("E,C,d,f,bf", [
    (2, 16, 64, 128, 64),
    (4, 32, 128, 256, 128),
    (3, 8, 96, 192, 192),     # f == block (single tile)
    (1, 64, 256, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_ffn(E, C, d, f, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    w1 = jax.random.normal(ks[1], (E, d, f), dtype) * 0.05
    w3 = jax.random.normal(ks[2], (E, d, f), dtype) * 0.05
    w2 = jax.random.normal(ks[3], (E, f, d), dtype) * 0.05
    got = expert_ffn(x, w1, w3, w2, block_f=bf, interpret=True)
    want = ref.expert_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_expert_ffn_from_pool_matches_direct():
    """Slot-pool weight access convention: gathering the active experts'
    slabs out of oversized [pool_capacity, ...] residency buffers is
    bit-identical to running the kernel on directly stacked weights."""
    E, C, d, f, cap = 3, 8, 64, 128, 6
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (E, C, d), jnp.bfloat16)
    w1p = jax.random.normal(ks[1], (cap, d, f), jnp.bfloat16) * 0.05
    w3p = jax.random.normal(ks[2], (cap, d, f), jnp.bfloat16) * 0.05
    w2p = jax.random.normal(ks[3], (cap, f, d), jnp.bfloat16) * 0.05
    slots = [5, 0, 2]
    got = expert_ffn_from_pool(x, w1p, w3p, w2p, slots, block_f=64,
                               interpret=True)
    want = expert_ffn(x, w1p[jnp.asarray(slots)], w3p[jnp.asarray(slots)],
                      w2p[jnp.asarray(slots)], block_f=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_expert_ffn_from_pool_fused_prefill_parity():
    """Fused-prefill shape regime: segment-gathered [U, Cmax, d] rows (rows
    repeated across groups, zero-padded tails) through the pool kernel vs
    the grouped-einsum oracle the engine's default backend uses."""
    U, C, d, f, cap, T = 3, 8, 64, 128, 7, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    xt = jax.random.normal(ks[0], (T, d), jnp.bfloat16)
    w1p = jax.random.normal(ks[1], (cap, d, f), jnp.bfloat16) * 0.05
    w3p = jax.random.normal(ks[2], (cap, d, f), jnp.bfloat16) * 0.05
    w2p = jax.random.normal(ks[3], (cap, f, d), jnp.bfloat16) * 0.05
    row_idx = jax.random.randint(ks[4], (U, C), 0, T)   # dup + padded rows
    slots = [4, 0, 6]
    xg = xt[row_idx]                                    # [U, C, d]
    got = expert_ffn_from_pool(xg, w1p, w3p, w2p, slots, block_f=64,
                               interpret=True)
    sl = jnp.asarray(slots)
    w1, w3, w2 = w1p[sl], w3p[sl], w2p[sl]
    want = jnp.einsum(
        "ucf,ufd->ucd",
        jax.nn.silu(jnp.einsum("ucd,udf->ucf", xg, w1))
        * jnp.einsum("ucd,udf->ucf", xg, w3), w2).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(jnp.bfloat16))


def test_expert_ffn_block_f_fallback():
    """A block_f that does not divide d_expert degrades to the largest
    dividing tile instead of asserting out (96 % 64 != 0 -> bf=48)."""
    E, C, d, f = 2, 8, 32, 96
    ks = jax.random.split(jax.random.PRNGKey(10), 4)
    x = jax.random.normal(ks[0], (E, C, d), jnp.float32)
    w1 = jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.05
    w3 = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.05
    w2 = jax.random.normal(ks[3], (E, f, d), jnp.float32) * 0.05
    got = expert_ffn(x, w1, w3, w2, block_f=64, interpret=True)
    want = ref.expert_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,H,Hkv,S,D,bq,bk", [
    (1, 2, 2, 64, 32, 32, 32),
    (2, 4, 2, 128, 64, 64, 32),    # GQA
    (1, 4, 1, 96, 32, 64, 64),     # MQA, ragged seq vs block
])
@pytest.mark.parametrize("window", [-1, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, Hkv, S, D, bq, bk, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("BH,S,P,N,cl", [
    (2, 32, 16, 8, 8),
    (4, 64, 32, 16, 16),
    (1, 48, 16, 8, 32),    # ragged: S not multiple of chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(BH, S, P, N, cl, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (BH, S, P), dtype)
    b = jax.random.normal(ks[1], (BH, S, N), dtype) * 0.5
    c = jax.random.normal(ks[2], (BH, S, N), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (BH, S))) * 0.5
    da = -dt * jnp.exp(jax.random.normal(ks[4], (BH, S)) * 0.2)
    got = ssd_scan(x, b, c, da, dt, chunk=cl, interpret=True)
    want, _ = ref.ssd_scan_ref(x, b, c, da, dt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-3,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-3)


def test_expert_ffn_matches_model_moe():
    """The kernel computes the same grouped GEMMs the model's capacity path
    feeds — wire-level agreement with the dispatch buffers."""
    from repro.models import moe_layer as M
    from repro.configs.base import get_config, reduced
    cfg = reduced(get_config("mixtral_8x7b"))
    p = M.moe_params(jax.random.PRNGKey(0), cfg, n_model=1)
    T, d = 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d), jnp.bfloat16)
    w, ids, _ = M.route(x, p["router"], cfg.n_experts, cfg.top_k)
    # build the capacity buffer exactly like the dispatch path, then compare
    # kernel vs ref on it
    got = expert_ffn(
        jnp.broadcast_to(x, (cfg.n_experts, T, d)), p["w1"], p["w3"], p["w2"],
        block_f=cfg.d_expert, interpret=True)
    want = ref.expert_ffn_ref(
        jnp.broadcast_to(x, (cfg.n_experts, T, d)), p["w1"], p["w3"], p["w2"])
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2,
                               atol=3e-2)


@pytest.mark.parametrize("B,H,Hkv,S,D,bk", [
    (2, 4, 2, 64, 32, 32),
    (1, 8, 1, 96, 64, 64),    # MQA + ragged
])
@pytest.mark.parametrize("window", [-1, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, H, Hkv, S, D, bk, window, dtype):
    from repro.kernels.flash_decode import flash_decode
    from repro.models.layers import attention
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    pos = S - 10
    # ring semantics: some slots empty (-1), some beyond pos
    slot_pos = jnp.where(jnp.arange(S) < S - 4, jnp.arange(S), -1)
    got = flash_decode(q, k, v, slot_pos, jnp.int32(pos), window=window,
                       block_k=bk, interpret=True)
    want = attention(q[:, None].transpose(0, 1, 2, 3),
                     k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                     q_pos=jnp.full((B, 1), pos), k_pos=slot_pos[None],
                     window=window, causal=True)[:, 0]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
