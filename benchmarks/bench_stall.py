"""Inter-token stall benchmark: chunked vs monolithic prefill (DUO engine).

The paper's phase-disparity argument says a uniform prefill policy inflates
tail latency for everyone else; the sharpest symptom in a continuous-batching
engine is the inter-token gap (TBT) of in-flight decoders while a long prompt
prefills. Monolithic prefill freezes every decoder for the full prefill wall
time; chunked prefill (``prefill_budget``) bounds the freeze to one chunk +
one batched decode step.

Protocol: N short-prompt decoders are submitted and warmed into steady-state
decode; one sacrificial long prompt is driven through first so both modes'
prefill kernels are compiled outside the measurement window; then the gap
ledger position is snapshotted and the measured long prompts arrive. We
report p50/p99/max inter-token gap over the decoders' tokens plus the long
prompts' TTFT, for monolithic (prefill_budget=None) vs chunked runs of the
same workload.

  PYTHONPATH=src python benchmarks/bench_stall.py \
      --budgets 4,8 --long-len 48 --n-long 2 [--policy duo]
"""
import argparse
import json
import os

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.qos import TBTLedger, percentile_report
from repro.models.model import build
from repro.serving.batching import BatchedServingEngine

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def run_stall(cfg, params, *, policy: str, prefill_budget, n_decoders: int,
              decoder_len: int, long_len: int, n_long: int,
              warm_steps: int, seed: int = 0) -> dict:
    """One workload pass; returns decoder-gap percentiles + long TTFTs."""
    rng = np.random.default_rng(seed)
    max_new = warm_steps + (n_long + 1) * (long_len + 4) + 12
    eng = BatchedServingEngine(
        cfg, params, policy=policy, max_batch=n_decoders + n_long + 1,
        max_seq=long_len + max_new + 2, prefill_budget=prefill_budget,
        temperature=0.0)
    decoders = [eng.submit(rng.integers(0, cfg.vocab, size=decoder_len)
                           .astype(np.int32), max_new=max_new)
                for _ in range(n_decoders)]
    for _ in range(warm_steps):
        eng.step()
    # sacrificial long prompt: compiles the (monolithic or chunked) prefill
    # kernels for long_len OUTSIDE the measurement window
    warm_long = eng.submit(rng.integers(0, cfg.vocab, size=long_len)
                           .astype(np.int32), max_new=2)
    while not warm_long.done:
        eng.step()
    assert all(r.state == "running" for r in decoders), \
        "decoders must be in steady-state decode before the long arrivals"
    # snapshot ledger position (NOT a reset: per-request baselines survive,
    # so the stall step itself still yields a gap sample)
    mark = {r.rid: len(eng.tbt.by_rid.get(r.rid, [])) for r in decoders}

    longs = [eng.submit(rng.integers(0, cfg.vocab, size=long_len)
                        .astype(np.int32), max_new=2)
             for _ in range(n_long)]
    while any(not r.done for r in longs):
        eng.step()
    for _ in range(2):  # a couple of post-storm decode steps
        eng.step()

    gaps = [g for r in decoders
            for g in eng.tbt.by_rid.get(r.rid, [])[mark[r.rid]:]]
    rep = percentile_report(gaps)
    rep["max"] = max(gaps) if gaps else float("nan")
    return {
        "mode": ("monolithic" if prefill_budget is None
                 else f"chunked[{prefill_budget}]"),
        "policy": policy,
        "decoder_gap": rep,
        "n_gaps": len(gaps),
        "long_ttft": [r.t_first - r.arrival for r in longs],
        "steps": eng.step_count,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--policy", default="duo")
    ap.add_argument("--budgets", default="4,8",
                    help="comma list of chunk budgets (tokens/step)")
    ap.add_argument("--decoders", type=int, default=2)
    ap.add_argument("--decoder-len", type=int, default=8)
    ap.add_argument("--long-len", type=int, default=48)
    ap.add_argument("--n-long", type=int, default=2)
    ap.add_argument("--warm-steps", type=int, default=6)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    budgets = [None] + [int(b) for b in args.budgets.split(",")]
    print(f"{'mode':>14s} {'gap_p50':>9s} {'gap_p99':>9s} {'gap_max':>9s} "
          f"{'ttft_long':>10s}")
    records = []
    for budget in budgets:
        rec = run_stall(cfg, params, policy=args.policy,
                        prefill_budget=budget, n_decoders=args.decoders,
                        decoder_len=args.decoder_len, long_len=args.long_len,
                        n_long=args.n_long, warm_steps=args.warm_steps)
        records.append(rec)
        g = rec["decoder_gap"]
        print(f"{rec['mode']:>14s} {g['p50']*1e3:8.1f}m {g['p99']*1e3:8.1f}m "
              f"{g['max']*1e3:8.1f}m {np.mean(rec['long_ttft']):9.2f}s")

    mono = records[0]["decoder_gap"]["max"]
    for rec in records[1:]:
        verdict = "LOWER" if rec["decoder_gap"]["max"] < mono else "NOT lower"
        print(f"{rec['mode']}: max gap {verdict} than monolithic "
              f"({rec['decoder_gap']['max']*1e3:.1f}ms vs {mono*1e3:.1f}ms)")

    out = args.out
    if out is None:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(RESULTS, "stall.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
