"""Inter-token stall benchmark: chunked vs monolithic prefill (DUO engine).

The paper's phase-disparity argument says a uniform prefill policy inflates
tail latency for everyone else; the sharpest symptom in a continuous-batching
engine is the inter-token gap (TBT) of in-flight decoders while a long prompt
prefills. Monolithic prefill freezes every decoder for the full prefill wall
time; chunked prefill (``prefill_budget``) bounds the freeze to one chunk +
one batched decode step.

Protocol: N short-prompt decoders are submitted and warmed into steady-state
decode; one sacrificial long prompt is driven through first so both modes'
prefill kernels are compiled outside the measurement window; then the gap
ledger position is snapshotted and the measured long prompts arrive. We
report p50/p99/max inter-token gap over the decoders' tokens plus the long
prompts' TTFT (p50/p99 tails across the long arrivals), for monolithic
(prefill_budget=None) vs chunked runs of the same workload.

``--fairness both`` runs every chunked budget under head-of-line ("fifo")
AND round-robin ("rr") budget rotation; ``--fairness all`` adds
shortest-remaining-first ("srf"). The TTFT-tail story is the *straggler*:
a short prompt submitted right after the long ones. Under FIFO it waits
for every long prefill ahead of it to finish completely (TTFT ~ sum of
long prefills); under RR the per-step budget rotates, so the straggler
finishes after ~n_prefilling turns; under SRF the straggler — by
construction the shortest remaining — overtakes every long prefill
immediately, the best straggler TTFT of the three, while the LONG
prompts' TTFT tail pays for everyone that overtook them. For EQUAL-length
overlapping prompts RR is processor sharing — everyone finishes late
together — so the trade is reported, not assumed: per mode we print the
long prompts' TTFT p50/p99 AND the straggler's TTFT.

Every record also carries the expert-HBM accounting of the unified
ExpertResidency (device bytes vs the capacity bound); ``--smoke`` runs a
tiny workload and exits nonzero if the bound is ever exceeded (CI).

  PYTHONPATH=src python benchmarks/bench_stall.py \
      --budgets 4,8 --long-len 48 --n-long 2 [--policy duo] \
      [--fairness fifo|rr|both] [--smoke]
"""
import argparse
import json
import os
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import emit_bench_json  # noqa: E402

from repro.configs.base import get_config, reduced  # noqa: E402
from repro.core.qos import TBTLedger, percentile_report
from repro.models.model import build
from repro.serving.batching import BatchedServingEngine

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def run_stall(cfg, params, *, policy: str, prefill_budget, n_decoders: int,
              decoder_len: int, long_len: int, n_long: int,
              warm_steps: int, seed: int = 0,
              fairness: str = "rr") -> dict:
    """One workload pass; returns decoder-gap percentiles + long TTFTs."""
    rng = np.random.default_rng(seed)
    max_new = warm_steps + (n_long + 1) * (long_len + 4) + 12
    eng = BatchedServingEngine(
        cfg, params, policy=policy, max_batch=n_decoders + n_long + 1,
        max_seq=long_len + max_new + 2, prefill_budget=prefill_budget,
        prefill_fairness=fairness, temperature=0.0)
    # exact-measurement ledger: the serving default bounds by_rid at 1024
    # gaps per request, which would left-evict warm-phase samples under
    # long runs and silently shift the absolute `mark` indices below
    eng.tbt = TBTLedger(window=None, per_rid_window=None,
                        closed_window=None)
    decoders = [eng.submit(rng.integers(0, cfg.vocab, size=decoder_len)
                           .astype(np.int32), max_new=max_new)
                for _ in range(n_decoders)]
    for _ in range(warm_steps):
        eng.step()
    # sacrificial warm burst OUTSIDE the measurement window: compiles the
    # (monolithic or chunked) prefill kernels for both prompt lengths AND —
    # via staggered max_new retirement — every decode batch size the storm
    # can reach (each jitted decode step is shape-specialized on B; without
    # this, whichever mode ramps the batch higher eats multi-second compile
    # stalls inside the measurement and the gap comparison is meaningless)
    warms = [eng.submit(rng.integers(0, cfg.vocab, size=long_len)
                        .astype(np.int32), max_new=2 + i)
             for i in range(n_long)]
    warms.append(eng.submit(rng.integers(0, cfg.vocab, size=decoder_len)
                            .astype(np.int32), max_new=2 + n_long))
    while any(not r.done for r in warms):
        eng.step()
    assert all(r.state == "running" for r in decoders), \
        "decoders must be in steady-state decode before the long arrivals"
    # snapshot ledger position (NOT a reset: per-request baselines survive,
    # so the stall step itself still yields a gap sample)
    mark = {r.rid: len(eng.tbt.by_rid.get(r.rid, [])) for r in decoders}

    longs = [eng.submit(rng.integers(0, cfg.vocab, size=long_len)
                        .astype(np.int32), max_new=2)
             for _ in range(n_long)]
    # the straggler: a short prompt stuck behind the long arrivals — the
    # request whose TTFT fairness is supposed to rescue
    straggler = eng.submit(rng.integers(0, cfg.vocab, size=decoder_len)
                           .astype(np.int32), max_new=2)
    while any(not r.done for r in longs + [straggler]):
        eng.step()
    for _ in range(2):  # a couple of post-storm decode steps
        eng.step()

    gaps = [g for r in decoders
            for g in list(eng.tbt.by_rid.get(r.rid, []))[mark[r.rid]:]]
    rep = percentile_report(gaps)
    rep["max"] = max(gaps) if gaps else float("nan")
    res = eng.cache
    ttfts = [r.t_first - r.arrival for r in longs]
    return {
        "mode": ("monolithic" if prefill_budget is None
                 else f"chunked[{prefill_budget}]/{fairness}"),
        "policy": policy,
        "decoder_gap": rep,
        "n_gaps": len(gaps),
        "long_ttft": ttfts,
        "long_ttft_tail": percentile_report(ttfts),
        "straggler_ttft": straggler.t_first - straggler.arrival,
        "steps": eng.step_count,
        # unified-residency accounting: the fixed pool IS the footprint
        "expert_hbm_bytes": res.device_bytes,
        "expert_hbm_bound": res.capacity * res.bytes_per_expert,
        "expert_pool_regrows": res.regrow_events,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--policy", default="duo")
    ap.add_argument("--budgets", default="4,8",
                    help="comma list of chunk budgets (tokens/step)")
    ap.add_argument("--fairness", default="rr",
                    choices=["fifo", "rr", "srf", "both", "all"],
                    help="budget sharing across prefilling requests; "
                         "'both' compares fifo vs rr TTFT tails, 'all' "
                         "adds shortest-remaining-first")
    ap.add_argument("--decoders", type=int, default=2)
    ap.add_argument("--decoder-len", type=int, default=8)
    ap.add_argument("--long-len", type=int, default=48)
    ap.add_argument("--n-long", type=int, default=2)
    ap.add_argument("--warm-steps", type=int, default=6)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run; assert the expert-HBM bound and the "
                         "stall bound, exit nonzero on violation")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        args.budgets, args.decoders, args.n_long = "2", 1, 1
        args.long_len, args.decoder_len, args.warm_steps = 12, 6, 2

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    budgets = [None] + [int(b) for b in args.budgets.split(",")]
    fair_modes = {"both": ["fifo", "rr"],
                  "all": ["fifo", "rr", "srf"]}.get(args.fairness,
                                                    [args.fairness])
    print(f"{'mode':>18s} {'gap_p50':>9s} {'gap_p99':>9s} {'gap_max':>9s} "
          f"{'ttft_p50':>9s} {'ttft_p99':>9s} {'straggler':>10s}")
    records = []
    for budget in budgets:
        for fair in (fair_modes if budget is not None else fair_modes[:1]):
            rec = run_stall(cfg, params, policy=args.policy,
                            prefill_budget=budget,
                            n_decoders=args.decoders,
                            decoder_len=args.decoder_len,
                            long_len=args.long_len, n_long=args.n_long,
                            warm_steps=args.warm_steps, fairness=fair)
            records.append(rec)
            g, t = rec["decoder_gap"], rec["long_ttft_tail"]
            print(f"{rec['mode']:>18s} {g['p50']*1e3:8.1f}m "
                  f"{g['p99']*1e3:8.1f}m {g['max']*1e3:8.1f}m "
                  f"{t['p50']:8.2f}s {t['p99']:8.2f}s "
                  f"{rec['straggler_ttft']:9.2f}s")

    mono = records[0]["decoder_gap"]["max"]
    for rec in records[1:]:
        verdict = "LOWER" if rec["decoder_gap"]["max"] < mono else "NOT lower"
        print(f"{rec['mode']}: max gap {verdict} than monolithic "
              f"({rec['decoder_gap']['max']*1e3:.1f}ms vs {mono*1e3:.1f}ms)")

    ok = True
    for rec in records:
        if rec["expert_hbm_bytes"] > rec["expert_hbm_bound"] \
                or rec["expert_pool_regrows"]:
            ok = False
            print(f"HBM BOUND VIOLATED in {rec['mode']}: "
                  f"{rec['expert_hbm_bytes']} > {rec['expert_hbm_bound']} "
                  f"(regrows={rec['expert_pool_regrows']})")
    if ok:
        print("expert-HBM bound held for every mode "
              f"(<= capacity x bytes_per_expert = "
              f"{records[0]['expert_hbm_bound']} B)")

    if args.smoke:
        # the CI contract is the expert-HBM bound (deterministic); the gap
        # comparison is printed above but not asserted — at smoke sizes a
        # warm monolithic prefill is fast enough that wall-clock ordering
        # is noise on a shared runner (the real bound is measured by the
        # full bench and pinned structurally by
        # tests/test_serving_batch.py::test_chunked_interleaving_is_stall_free)
        assert ok, "expert-HBM bound violated"
        assert all(r["n_gaps"] > 0 for r in records), "no gaps measured"
        emit_bench_json("stall", {
            r["mode"].replace("/", "_"): {
                "gap_p50_ms": r["decoder_gap"]["p50"] * 1e3,
                "gap_max_ms": r["decoder_gap"]["max"] * 1e3,
                "n_gaps": r["n_gaps"],
                "long_ttft_p99_s": r["long_ttft_tail"]["p99"],
                "straggler_ttft_s": r["straggler_ttft"],
            } for r in records})
        print("bench_stall smoke OK")
        return

    out = args.out
    if out is None:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(RESULTS, "stall.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
