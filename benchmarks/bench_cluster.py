"""Cluster-layer benchmark: replicas x router policy under non-stationary
arrivals (serving/cluster.py).

Sweeps a ReplicaPool of N BatchedServingEngine replicas behind each router
policy (round_robin / least_loaded / slo_headroom / expert_affinity) and
offers the same workload — alternating LONG and SHORT prompts, arriving by
the chosen process (default: bursty Gamma-renewal clumps, the regime where
load-oblivious routing falls over; see benchmarks.common.arrival_offsets).
Alternating lengths are round-robin's blind spot: with 2 replicas it sends
every long prompt to the same replica while the other idles through shorts,
so the long-prompt TTFT tail measures exactly what load/SLO-aware dispatch
buys. Per (replicas, router) run it reports:

  * TTFT p50/p99 and TPOT p50/p99 over completed requests
  * SLO attainment: fraction of OFFERED requests that completed with
    TTFT <= --ttft-slo (sheds count as misses)
  * shed rate, split by source: router rejections (slo_headroom found no
    capable replica), per-replica admission rejections, autopilot sheds
  * per-replica request balance and expert-HBM accounting — device bytes
    must equal ``pool_capacity * bytes_per_expert`` with zero regrows on
    EVERY replica (the PR-3 bound, now per replica)

``--disagg`` switches to the phase-disaggregation sweep instead: for each
replica count N it compares the symmetric pool (N interchangeable
replicas, least_loaded) against every prefill:decode split (1p:(N-1)d ...
(N-1)p:1d) under the disagg router — same bursty workload — and
additionally reports, per run, the handoff count, the snapshot->first-
post-handoff-token latency (p50/p99), the host-side KV bytes moved by
migrations, the peak host KV bytes parked by autopilot preemption, and
the per-ROLE expert-HBM bound. Handles follow their requests across the
prefill->decode hop, so TTFT/TPOT are end-to-end as the client sees them.

``--smoke`` (CI) runs a tiny sweep and asserts the acceptance criteria:
a 1-replica cluster is bit-exact vs a plain ServingFrontend at temperature
0, every replica's expert HBM stays at the fixed bound, and slo_headroom
or expert_affinity beats round_robin on p99 TTFT or SLO attainment at 2
replicas under bursty arrivals. ``--smoke --disagg`` instead asserts the
disagg acceptance criteria: a 1-prefill + 1-decode pool is bit-exact vs
the plain frontend, every completed request took exactly one handoff, and
the per-role HBM bound holds with zero regrows.

  PYTHONPATH=src python -m benchmarks.bench_cluster \
      --replicas 1,2 --routers round_robin,slo_headroom \
      --arrival bursty --requests 12 [--autopilot] [--disagg] [--smoke]
"""
import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import (ARRIVALS, arrival_offsets,  # noqa: E402
                               emit_bench_json)

from repro.configs.base import get_config, reduced  # noqa: E402
from repro.core.qos import percentile_report  # noqa: E402
from repro.core.scheduler import default_capacity  # noqa: E402
from repro.obs import (validate_metrics_snapshot, validate_trace,  # noqa: E402
                       write_trace)
from repro.serving.api import (GenerationRequest,  # noqa: E402
                               SamplingParams, TokenEvent)
from repro.serving.batching import (BatchedServingEngine,  # noqa: E402
                                    parse_prefill_budget)
from repro.serving.cluster import (ClusterFrontend, QosAutopilot,  # noqa: E402
                                   ReplicaPool, ROUTERS)
from repro.serving.frontend import ServingFrontend  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def make_prompts(n: int, long_len: int, short_len: int, vocab: int,
                 seed: int = 11):
    """Alternating long/short prompts — the workload shape that exposes
    size-oblivious routing."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab,
                         size=(long_len if i % 2 == 0 else short_len))
            .astype(np.int32) for i in range(n)]


def warm_pool(pool: ReplicaPool, prompts) -> None:
    """Compile each replica's kernels outside the measurement window: one
    long + one short prompt per replica (both final-chunk shapes, decode
    batch sizes 1-2) — and seed every replica's EWMA LatencyModel with real
    costs so slo_headroom predictions are honest from the first request.
    On a role='prefill' replica a direct submission parks in `held` forever
    (no decode replica is wired to a raw frontend), so warm-up there polls
    until both requests are held and cancels them — prefill shapes are
    exactly what that role executes in steady state."""
    longest = max(prompts, key=len)
    shortest = min(prompts, key=len)
    for i, fe in enumerate(pool.frontends):
        hs = [fe.submit(GenerationRequest(
                  prompt=p, params=SamplingParams(max_new_tokens=1)))
              for p in (longest, shortest)]
        if pool.roles[i] == "prefill":
            for _ in range(1000):
                if all(h.req.state == "held" or h.done for h in hs):
                    break
                fe.poll()
            for h in hs:
                h.cancel()
        else:
            fe.drain()


def hbm_report(pool: ReplicaPool) -> list:
    out = []
    for eng in pool.engines:
        res = eng.cache
        bound = res.pool_capacity * res.bytes_per_expert
        out.append({
            "device_bytes": int(res.device_bytes),
            "bound_bytes": int(bound),
            "regrow_events": int(res.regrow_events),
            "ok": bool(res.hbm_bound_ok),
        })
    return out


def run_cluster(cfg, params, prompts, *, n_replicas: int, router: str,
                rate: float, arrival: str, max_new: int, max_batch: int,
                policy: str, prefill_budget, ttft_slo, tbt_slo,
                autopilot: bool, seed: int = 0, warm: bool = True,
                overrides=None, preempt: bool = False, spans: bool = False,
                pool_sink=None) -> dict:
    pool = ReplicaPool.build(
        cfg, params, n_replicas, policy=policy, max_batch=max_batch,
        max_seq=max(len(p) for p in prompts) + max_new + 2,
        prefill_budget=prefill_budget, tbt_slo=tbt_slo, temperature=0.0,
        overrides=overrides, spans=spans)
    if pool_sink is not None:
        pool_sink.append(pool)
    if warm:
        warm_pool(pool, prompts)
    fe = ClusterFrontend(pool, router=router)
    ap = QosAutopilot(fe, preempt=preempt) if autopilot else None

    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    arrivals = t0 + arrival_offsets(arrival, rate, len(prompts), rng)
    pending = list(zip(arrivals, prompts))
    handles = []
    paused_kv_peak = 0
    while pending or not fe.idle:
        now = time.perf_counter()
        while pending and pending[0][0] <= now:
            arr, p = pending.pop(0)
            handles.append(fe.submit(GenerationRequest(
                prompt=p, params=SamplingParams(max_new_tokens=max_new),
                ttft_slo=ttft_slo, tbt_slo=tbt_slo, arrival=arr)))
        ev = fe.poll(now)
        if ap is not None:
            paused_kv_peak = max(paused_kv_peak, ap.paused_kv_bytes)
        if not ev.did_work and pending:
            time.sleep(max(pending[0][0] - time.perf_counter(), 0.0))
    wall = time.perf_counter() - t0

    done = [h for h in handles
            if h.finish_reason in ("length", "stop_token")]
    results = [h.req.result() for h in done]
    ttfts = [r.ttft_wall for r in results]
    tpots = [(r.e2e_wall - r.ttft_wall) / max(len(r.tokens) - 1, 1)
             for r in results]
    n_adm_rej = sum(len(e.queue.rejected) for e in pool.engines)
    n_router_rej = fe.n_router_rejected
    n_shed = ap.n_shed if ap else 0
    offered = len(prompts)
    # snapshot -> first post-handoff token (the client-visible cost of the
    # prefill->decode hop; the first-ever token lands BEFORE the handoff)
    handoff_lat = []
    for h in done:
        if h.handoffs:
            t_s = h.handoffs[0]["t_snapshot"]
            after = [ev.t for ev in h.events
                     if isinstance(ev, TokenEvent) and ev.t >= t_s]
            if after:
                handoff_lat.append(min(after) - t_s)
    rec = {
        "replicas": n_replicas,
        "roles": list(pool.roles),
        "router": router,
        "arrival": arrival,
        "rate_req_s": rate,
        "offered": offered,
        "completed": len(done),
        "router_rejected": n_router_rej,
        "admission_rejected": n_adm_rej,
        "autopilot_shed": n_shed,
        "shed_rate": (n_router_rej + n_adm_rej + n_shed) / offered,
        "ttft": percentile_report(ttfts),
        "tpot": percentile_report(tpots),
        "tokens_per_s": sum(len(r.tokens) for r in results) / max(wall, 1e-9),
        "balance": [sum(1 for h in handles if h.replica == i)
                    for i in range(n_replicas)],
        "per_replica_hbm": hbm_report(pool),
        # snapshot-primitive traffic + host-side memory accounting: KV bytes
        # in flight during migrations and parked by autopilot preemption
        # live on the HOST, outside every replica's device bound above
        "handoffs": int(pool.n_handoffs),
        "migrated": int(pool.n_migrated),
        "handoff_kv_bytes": int(pool.handoff_bytes),
        "handoff_latency": (percentile_report(handoff_lat)
                            if handoff_lat else None),
        "preempted": int(ap.n_preempted) if ap else 0,
        "paused_kv_bytes_peak": int(paused_kv_peak),
        "wall_s": wall,
    }
    if ttft_slo is not None:
        rec["slo_attainment"] = sum(
            1 for r in results if r.ttft_wall <= ttft_slo) / offered
    return rec


def parity_check(cfg, params, prompts, *, max_new: int, max_batch: int,
                 policy: str, prefill_budget, routers) -> None:
    """1-replica cluster == plain ServingFrontend, bit-exact at temp 0,
    for every router policy (no SLOs: tokens must not depend on wall
    time)."""
    max_seq = max(len(p) for p in prompts) + max_new + 2
    eng = BatchedServingEngine(cfg, params, policy=policy,
                               max_batch=max_batch, max_seq=max_seq,
                               prefill_budget=prefill_budget,
                               temperature=0.0)
    base = ServingFrontend(eng)
    ref = [base.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=max_new)))
        for p in prompts]
    base.drain()
    for router in routers:
        pool = ReplicaPool.build(cfg, params, 1, policy=policy,
                                 max_batch=max_batch, max_seq=max_seq,
                                 prefill_budget=prefill_budget,
                                 temperature=0.0)
        fe = ClusterFrontend(pool, router=router)
        got = [fe.submit(GenerationRequest(
            prompt=p, params=SamplingParams(max_new_tokens=max_new)))
            for p in prompts]
        fe.drain()
        for r, g in zip(ref, got):
            assert list(r.tokens) == list(g.tokens), \
                f"1-replica cluster diverged under {router}"
        for h in hbm_report(pool):
            assert h["ok"], f"expert-HBM bound violated: {h}"
        print(f"  parity OK: 1-replica {router} == ServingFrontend "
              f"({len(prompts)} requests)")


def disagg_parity_check(cfg, params, prompts, *, max_new: int,
                        max_batch: int, policy: str, prefill_budget) -> None:
    """1-prefill + 1-decode pool == plain ServingFrontend, bit-exact at
    temp 0 — the KV snapshot handed across the hop must reproduce the
    uninterrupted computation exactly. Also asserts every request actually
    took the hop and both ROLES kept their expert-HBM bound."""
    max_seq = max(len(p) for p in prompts) + max_new + 2
    base = ServingFrontend(BatchedServingEngine(
        cfg, params, policy=policy, max_batch=max_batch, max_seq=max_seq,
        prefill_budget=prefill_budget, temperature=0.0))
    ref = [base.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=max_new)))
        for p in prompts]
    base.drain()
    pool = ReplicaPool.build(
        cfg, params, policy=policy, max_batch=max_batch, max_seq=max_seq,
        prefill_budget=prefill_budget, temperature=0.0,
        overrides=[{"role": "prefill"}, {"role": "decode"}])
    fe = ClusterFrontend(pool, router="disagg")
    got = [fe.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=max_new)))
        for p in prompts]
    fe.drain()
    for r, g in zip(ref, got):
        assert list(r.tokens) == list(g.tokens), \
            "disagg 1p+1d cluster diverged from plain frontend"
        assert len(g.handoffs) == 1 and g.replica == 1, \
            "request did not take the prefill->decode hop"
    assert pool.n_handoffs == len(prompts)
    for h in hbm_report(pool):
        assert h["ok"], f"per-role expert-HBM bound violated: {h}"
    print(f"  disagg parity OK: 1p+1d == ServingFrontend "
          f"({len(prompts)} requests, {pool.n_handoffs} handoffs, "
          f"{pool.handoff_bytes} host KV bytes moved)")


def check_disagg_trace(trace: dict) -> None:
    """The --trace-out acceptance criteria on a disagg run's Perfetto
    export: prefill-chunk / batched-decode / expert-prefetch spans land on
    DISTINCT lanes (tids) across the replica tracks, and at least one
    handoff flow pair links a source track to a different destination
    track (the arrow Perfetto draws for the prefill->decode hop)."""
    errs = validate_trace(trace)
    assert not errs, f"trace failed schema validation: {errs[:5]}"
    lane_tids = {}      # cat -> set of (pid, tid)
    for ev in trace["traceEvents"]:
        if ev["ph"] in ("X", "i") and ev.get("cat") in ("prefill", "decode",
                                                        "prefetch"):
            lane_tids.setdefault(ev["cat"], set()).add((ev["pid"], ev["tid"]))
    missing = {"prefill", "decode", "prefetch"} - set(lane_tids)
    assert not missing, f"no spans on lane(s) {sorted(missing)}"
    tids = {cat: {t for _, t in pts} for cat, pts in lane_tids.items()}
    assert tids["prefill"].isdisjoint(tids["decode"]) \
        and tids["decode"].isdisjoint(tids["prefetch"]), \
        f"lanes share a tid: {tids}"
    flows = {}          # id -> {ph: pid}
    for ev in trace["traceEvents"]:
        if ev["ph"] in ("s", "f"):
            flows.setdefault(ev["id"], {})[ev["ph"]] = ev["pid"]
    linked = [fid for fid, d in flows.items()
              if "s" in d and "f" in d and d["s"] != d["f"]]
    assert linked, "no handoff flow links two distinct replica tracks"
    print(f"  trace OK: {len(trace['traceEvents'])} events, "
          f"{len(linked)} cross-replica handoff flow(s), "
          "prefill/decode/prefetch on distinct lanes")


def run_disagg_sweep(cfg, params, prompts, args, budget) -> None:
    """--disagg mode: per replica count N, symmetric pool (least_loaded)
    vs every prefill:decode split under the disagg router; asserts the
    smoke acceptance criteria when --smoke is also set."""
    print("disagg parity check:")
    disagg_parity_check(cfg, params, prompts[:4], max_new=args.max_new,
                        max_batch=args.max_batch, policy=args.policy,
                        prefill_budget=budget)

    print(f"\n{'repl':>4s} {'split':>8s} {'done':>4s} "
          f"{'ttft_p99':>9s} {'tpot_p99':>9s} {'attain':>6s} "
          f"{'hoffs':>5s} {'hoff_p99':>9s} {'hoff_MB':>8s} "
          f"{'paused_KB':>9s} {'hbm':>4s}")
    records = []
    want_obs = args.trace_out is not None or args.metrics_out is not None
    obs_pools = []      # first disagg-router pool, spans enabled
    for n_rep in [int(r) for r in args.replicas.split(",")]:
        if n_rep < 2:
            print(f"{n_rep:4d}    (skip: disagg needs >= 2 replicas)")
            continue
        runs = [("sym", "least_loaded", None)]
        for p in range(1, n_rep):
            runs.append((f"{p}p:{n_rep - p}d", "disagg",
                         [{"role": "prefill"}] * p
                         + [{"role": "decode"}] * (n_rep - p)))
        for split, router, overrides in runs:
            capture = want_obs and router == "disagg" and not obs_pools
            ov = overrides
            if capture and ov is not None:
                # Tiny smoke grids fit every (layer, expert) inside the
                # policy-default capacity, which silences the prefetch
                # stream entirely (everything is resident after the first
                # pass). Cap the captured decode replicas just below the
                # full grid so the timeline shows the dual-phase
                # prefetch/correction traffic it exists to visualise.
                grid = cfg.n_layers * cfg.n_experts
                cap = default_capacity(args.policy, cfg.n_layers,
                                       cfg.n_experts, cfg.top_k,
                                       batch=args.max_batch)
                if cap >= grid:
                    ov = [dict(o, cache_capacity=max(cfg.n_experts,
                                                     grid - 2))
                          if o.get("role") == "decode" else o for o in ov]
            rec = run_cluster(
                cfg, params, prompts, n_replicas=n_rep, router=router,
                rate=args.rate, arrival=args.arrival, max_new=args.max_new,
                max_batch=args.max_batch, policy=args.policy,
                prefill_budget=budget, ttft_slo=args.ttft_slo,
                tbt_slo=args.tbt_slo, overrides=ov,
                autopilot=args.autopilot or args.smoke,
                preempt=args.autopilot, spans=capture,
                pool_sink=obs_pools if capture else None)
            rec["split"] = split
            records.append(rec)
            hbm_ok = all(h["ok"] for h in rec["per_replica_hbm"])
            hl = rec["handoff_latency"]
            print(f"{n_rep:4d} {split:>8s} {rec['completed']:4d} "
                  f"{rec['ttft']['p99']:8.3f}s {rec['tpot']['p99']:8.3f}s "
                  f"{rec.get('slo_attainment', float('nan')):6.2f} "
                  f"{rec['handoffs']:5d} "
                  f"{(hl['p99'] if hl else float('nan')):8.3f}s "
                  f"{rec['handoff_kv_bytes'] / 2**20:8.2f} "
                  f"{rec['paused_kv_bytes_peak'] / 2**10:9.1f} "
                  f"{'ok' if hbm_ok else 'VIOLATED':>4s}")
            assert hbm_ok, ("per-role expert-HBM bound violated: "
                            f"{rec['per_replica_hbm']}")
            if router == "disagg":
                assert rec["handoffs"] >= rec["completed"], \
                    "a completed request never took the prefill->decode hop"

    if obs_pools:
        pool = obs_pools[0]
        for p in (args.trace_out, args.metrics_out):
            if p and os.path.dirname(p):
                os.makedirs(os.path.dirname(p), exist_ok=True)
        if args.trace_out:
            trace = write_trace(args.trace_out, pool.recorders())
            print(f"wrote {args.trace_out} "
                  f"({len(trace['traceEvents'])} events)")
            check_disagg_trace(trace)
        if args.metrics_out:
            snap = pool.metrics_snapshot()
            errs = validate_metrics_snapshot(snap)
            assert not errs, f"metrics snapshot invalid: {errs[:5]}"
            with open(args.metrics_out, "w") as f:
                json.dump(snap, f, indent=1, sort_keys=True)
            print(f"wrote {args.metrics_out}")

    if args.smoke:
        d = next(r for r in records if r["router"] == "disagg")
        emit_bench_json("cluster_disagg", {
            "offered": d["offered"], "completed": d["completed"],
            "handoffs": d["handoffs"],
            "handoff_kv_bytes": d["handoff_kv_bytes"],
            "ttft_p99_s": d["ttft"]["p99"], "tpot_p99_s": d["tpot"]["p99"],
            "tokens_per_s": d["tokens_per_s"], "wall_s": d["wall_s"],
        })
        print("\nbench_cluster --disagg smoke OK: 1p+1d bit-exact vs plain "
              "frontend; every completed request took the handoff; "
              "per-role expert HBM bounded")

    out = args.out
    if out is None:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(RESULTS, "cluster_disagg.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--replicas", default="1,2")
    ap.add_argument("--routers", default=",".join(ROUTERS))
    ap.add_argument("--arrival", default="bursty", choices=list(ARRIVALS))
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean offered load (req/s); bursty clumps it")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--long-len", type=int, default=40)
    ap.add_argument("--short-len", type=int, default=6)
    ap.add_argument("--policy", default="duo+")
    ap.add_argument("--prefill-budget", default="4")
    ap.add_argument("--ttft-slo", type=float, default=2.0)
    ap.add_argument("--tbt-slo", type=float, default=None)
    ap.add_argument("--autopilot", action="store_true",
                    help="attach the QosAutopilot (mid-flight SLO shedding)")
    ap.add_argument("--disagg", action="store_true",
                    help="prefill:decode split sweep (vs symmetric pool) "
                         "instead of the router sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI sweep asserting 1-replica parity, the "
                         "per-replica expert-HBM bound, and an SLO/"
                         "affinity-router win over round_robin")
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="(--disagg) write a Perfetto JSON timeline of the "
                         "first disagg run (spans on) and assert the "
                         "prefill/decode/prefetch lanes + handoff flows")
    ap.add_argument("--metrics-out", default=None,
                    help="(--disagg) dump the captured pool's "
                         "cluster+replica metrics snapshot as JSON")
    args = ap.parse_args()

    if args.smoke:
        args.replicas, args.requests, args.max_new = "2", 10, 3
        args.routers = "round_robin,slo_headroom,expert_affinity"

    cfg = reduced(get_config(args.arch))
    from repro.models.model import build
    params = build(cfg).init(jax.random.PRNGKey(0))
    prompts = make_prompts(args.requests, args.long_len, args.short_len,
                           cfg.vocab)
    budget = parse_prefill_budget(args.prefill_budget)
    routers = args.routers.split(",")

    if args.disagg:
        run_disagg_sweep(cfg, params, prompts, args, budget)
        return

    print("1-replica parity check:")
    parity_check(cfg, params, prompts[:4], max_new=args.max_new,
                 max_batch=args.max_batch, policy=args.policy,
                 prefill_budget=budget,
                 routers=routers if args.smoke else routers[:1])

    print(f"\n{'repl':>4s} {'router':>16s} {'done':>4s} {'shed':>4s} "
          f"{'ttft_p50':>9s} {'ttft_p99':>9s} {'tpot_p99':>9s} "
          f"{'attain':>6s} {'balance':>12s} {'hbm':>4s}")
    records = []
    for n_rep in [int(r) for r in args.replicas.split(",")]:
        for router in routers:
            rec = run_cluster(
                cfg, params, prompts, n_replicas=n_rep, router=router,
                rate=args.rate, arrival=args.arrival, max_new=args.max_new,
                max_batch=args.max_batch, policy=args.policy,
                prefill_budget=budget, ttft_slo=args.ttft_slo,
                tbt_slo=args.tbt_slo,
                autopilot=args.autopilot or args.smoke)
            records.append(rec)
            hbm_ok = all(h["ok"] for h in rec["per_replica_hbm"])
            att = rec.get("slo_attainment", float("nan"))
            n_shed = (rec["router_rejected"] + rec["admission_rejected"]
                      + rec["autopilot_shed"])
            print(f"{n_rep:4d} {router:>16s} {rec['completed']:4d} "
                  f"{n_shed:4d} {rec['ttft']['p50']:8.3f}s "
                  f"{rec['ttft']['p99']:8.3f}s {rec['tpot']['p99']:8.3f}s "
                  f"{att:6.2f} {str(rec['balance']):>12s} "
                  f"{'ok' if hbm_ok else 'VIOLATED':>4s}")
            assert hbm_ok, \
                f"per-replica expert-HBM bound violated: {rec['per_replica_hbm']}"

    if args.smoke:
        by = {(r["replicas"], r["router"]): r for r in records}
        rr = by[(2, "round_robin")]
        wins = []
        for name in ("slo_headroom", "expert_affinity"):
            c = by[(2, name)]
            wins.append(c["ttft"]["p99"] < rr["ttft"]["p99"])
            wins.append(c.get("slo_attainment", 0.0)
                        > rr.get("slo_attainment", 0.0))
        assert any(wins), (
            "neither slo_headroom nor expert_affinity beat round_robin on "
            f"p99 TTFT or SLO attainment: {json.dumps(records, indent=1)}")
        emit_bench_json("cluster", {
            name: {"completed": by[(2, name)]["completed"],
                   "ttft_p99_s": by[(2, name)]["ttft"]["p99"],
                   "slo_attainment": by[(2, name)].get(
                       "slo_attainment", float("nan")),
                   "tokens_per_s": by[(2, name)]["tokens_per_s"]}
            for name in ("round_robin", "slo_headroom", "expert_affinity")})
        print("\nbench_cluster smoke OK: QoS-aware routing beats "
              "round_robin under bursty arrivals; per-replica expert HBM "
              "bounded; 1-replica cluster bit-exact")

    out = args.out
    if out is None:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(RESULTS, "cluster_router.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
