"""Table III reproduction: expert-prediction accuracy — DuoServe's ExpertMLP
vs the MIF trace-prior — per (model, dataset). Metrics: Top-k (all routed
experts predicted) and At-Least-Half, measured on the held-out eval traces'
actual decode steps."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DATASETS, build_artifacts
from repro.core.predictor import accuracy_metrics
from repro.core.state import StateConstructor


def eval_on_traces(art):
    """Per decode step: DuoServe predicts layer l from the step's own path
    prefix (cleared per step, as in the runtime); MIF 'predicts' the top-k
    popular experts."""
    sc = StateConstructor(art.stats)
    E, k = art.cfg_trace.n_experts, art.cfg_trace.top_k
    X, Y = [], []
    for r in art.eval_results["odf"]:
        for t in range(r.decode_trace.shape[0]):
            prefix = []
            for l in range(r.decode_trace.shape[1]):
                if l >= 1:
                    X.append(sc.features(prefix, l))
                    y = np.zeros(E, np.float32)
                    y[r.decode_trace[t, l]] = 1.0
                    Y.append(y)
                prefix.append(r.decode_trace[t, l])
    X, Y = np.stack(X), np.stack(Y)
    duo_logits = art.predictor.predict_logits(X)
    duo = accuracy_metrics(duo_logits, Y, k)
    # MIF prior: layer popularity (constant per layer)
    n_layers = art.cfg_trace.n_layers
    pop_logits = np.zeros_like(duo_logits)
    i = 0
    for _ in range(len(X) // (n_layers - 1)):
        for l in range(1, n_layers):
            pop_logits[i] = art.stats.popularity[l]
            i += 1
    mif = accuracy_metrics(pop_logits, Y, k)
    return duo, mif


def run(models=("mixtral-8x7b", "mixtral-8x22b", "qwen3-30b-a3b",
                "deepseekmoe-16b"), datasets=DATASETS, quick=False):
    rows = []
    for m in models:
        for d in datasets:
            art = build_artifacts(m, d)
            (duo_k, duo_h), (mif_k, mif_h) = eval_on_traces(art)
            rows.append((f"predictor/{m}/{d}", 0.0,
                         f"duo_topk={duo_k:.3f},duo_half={duo_h:.3f},"
                         f"mif_topk={mif_k:.3f},mif_half={mif_h:.3f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
