"""Shared benchmark substrate: trace collection, predictor training, policy
construction, and cached artifacts under results/bench/.

Pipeline per (paper model, dataset):
  1. Build the trace-scale variant (same L/E/k), init params.
  2. Offline preprocess: run the live engine (ODF schedule) over the
     dataset's prompt workload, record per-token activation paths (§IV-A).
  3. Build popularity/affinity, train the ExpertMLP (§IV-B).
  4. Serve held-out requests with each policy through the same engine to get
     real routing + hit/miss behaviour, then replay through the two-stream
     simulator with the full-scale model's costs (§VI).

Also home to the shared arrival-process generators (`arrival_offsets`):
poisson / bursty (Gamma-renewal, MMPP-like clumping) / ramp, used by
bench_concurrent and bench_cluster so router and admission policies are
compared under non-stationary load, not just stationary Poisson.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.paper_models import (PAPER_MODELS, QUANT_BYTES,
                                        trace_scale)
from repro.core.predictor import TrainedPredictor, train_predictor
from repro.core.scheduler import make_scheduler
from repro.core.simulator import HW, ModelCosts, simulate_request
from repro.core.state import StateConstructor
from repro.core.tracer import ExpertsTracer, TraceStats
from repro.data.pipeline import PromptWorkload, orca_like, squad_like
from repro.models.model import build
from repro.serving.engine import MoEServingEngine, collect_traces

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
POLICIES = ("odf", "lfp", "mif", "duo", "duo+")
DATASETS = ("squad", "orca")
ARRIVALS = ("poisson", "bursty", "ramp")

# -- machine-readable bench records (PR 10) --------------------------------
# Every bench --smoke run writes results/BENCH_<name>.json through
# emit_bench_json so CI diffs runs without scraping stdout.
BENCH_SCHEMA = "repro.bench/1"


def validate_bench_record(obj) -> List[str]:
    """Schema check for a BENCH_<name>.json record: ``{"schema":
    BENCH_SCHEMA, "name": str, "metrics": {...}}`` with numeric (or
    string-annotation) leaves under ``metrics`` — the same leaf rules as a
    repro.obs metrics snapshot. Returns error strings; empty == valid."""
    from repro.obs.metrics import METRICS_SCHEMA, validate_metrics_snapshot

    if not isinstance(obj, dict):
        return [f"bench record must be a dict, got {type(obj).__name__}"]
    errs: List[str] = []
    if obj.get("schema") != BENCH_SCHEMA:
        errs.append(f"schema must be {BENCH_SCHEMA!r}, got {obj.get('schema')!r}")
    if not isinstance(obj.get("name"), str) or not obj.get("name"):
        errs.append("name must be a non-empty string")
    metrics = obj.get("metrics")
    if not isinstance(metrics, dict):
        errs.append("metrics must be a dict")
    else:
        # reuse the metrics-snapshot leaf rules (numbers, no inf/bool/None)
        errs += validate_metrics_snapshot(
            {"schema": METRICS_SCHEMA, "metrics": metrics})
    extra = set(obj) - {"schema", "name", "metrics"}
    if extra:
        errs.append(f"unknown keys {sorted(extra)}")
    return errs


def emit_bench_json(name: str, metrics: Dict[str, object]) -> str:
    """Write the schema-validated ``results/BENCH_<name>.json`` record and
    return its path. Raises on a record that fails validate_bench_record —
    a bench emitting NaN-free numbers is part of its contract."""
    rec = {"schema": BENCH_SCHEMA, "name": name, "metrics": metrics}
    errs = validate_bench_record(rec)
    if errs:
        raise ValueError(
            f"bench record {name!r} invalid: " + "; ".join(errs[:5]))
    root = os.path.abspath(os.path.join(RESULTS, ".."))
    os.makedirs(root, exist_ok=True)
    path = os.path.join(root, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
    return path


def arrival_offsets(kind: str, rate: float, n: int,
                    rng: np.random.Generator, *,
                    burstiness: float = 16.0,
                    ramp_span: float = 4.0) -> np.ndarray:
    """Cumulative arrival-time offsets (seconds from t0) for `n` requests
    at mean offered load `rate` req/s. Three processes, same mean rate:

      * "poisson" — exponential renewal (CV^2 = 1), the stationary baseline.
      * "bursty"  — Gamma renewal with shape 1/burstiness, so inter-arrival
        CV^2 = `burstiness`: most gaps are near zero (requests clump into
        bursts) separated by long quiet stretches — a renewal approximation
        of an on/off Markov-modulated Poisson process, the regime where
        load-oblivious routing falls over.
      * "ramp"    — non-stationary Poisson whose instantaneous rate grows
        linearly across the n arrivals with a ramp_span^2 start-to-end
        ratio, rescaled so the MEAN offered load is `rate` (the absolute
        endpoints land near — not exactly at — rate/ramp_span and
        rate*ramp_span; admission/routing must track the drift).
    """
    assert rate > 0 and n >= 1
    if kind == "poisson":
        inter = rng.exponential(1.0 / rate, size=n)
    elif kind == "bursty":
        shape = 1.0 / burstiness
        inter = rng.gamma(shape, (1.0 / rate) / shape, size=n)
    elif kind == "ramp":
        shape = np.linspace(1.0 / ramp_span, ramp_span, n)
        # normalize so the EXPECTED total span is n/rate — the ramp changes
        # the instantaneous rate profile, not the mean offered load
        rates = shape * (rate * (1.0 / shape).sum() / n)
        inter = rng.exponential(1.0 / rates)
    else:
        raise KeyError(f"unknown arrival process {kind!r} (have {ARRIVALS})")
    return np.cumsum(inter)


def dataset_spec(name: str, vocab: int):
    return squad_like(vocab) if name == "squad" else orca_like(vocab)


@dataclasses.dataclass
class BenchArtifacts:
    model: str
    dataset: str
    cfg_full: ArchConfig
    cfg_trace: ArchConfig
    stats: TraceStats
    predictor: TrainedPredictor
    predictor_history: dict
    eval_results: Dict[str, list]   # policy -> list[RequestResult]
    wall: Dict[str, float]


def _cache_path(model: str, dataset: str, tag: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, f"{model}__{dataset}__{tag}.pkl")


def build_artifacts(model: str, dataset: str, *, n_trace_requests: int = 48,
                    n_eval_requests: int = 8, max_new: int = 12,
                    epochs: int = 15, prompt_cap: int = 48,
                    train_steps: int = 60,
                    refresh: bool = False) -> BenchArtifacts:
    path = _cache_path(model, dataset, "artifacts")
    if os.path.exists(path) and not refresh:
        with open(path, "rb") as f:
            return pickle.load(f)

    import dataclasses as _dc
    cfg_full = PAPER_MODELS[model]
    # large expert pools are ~10x more engine work per request on this
    # 1-core container; shrink the trace budget (predictor quality saturates
    # well before this for the synthetic workloads)
    if cfg_full.n_experts >= 64 or cfg_full.n_layers >= 48:
        n_trace_requests = min(n_trace_requests, 20)
        n_eval_requests = min(n_eval_requests, 6)
        max_new = min(max_new, 8)
        epochs = min(epochs, 8)
        train_steps = min(train_steps, 40)
    cfg_t = _dc.replace(trace_scale(cfg_full), router_aux_loss=0.001)
    bundle = build(cfg_t)
    params = bundle.init(jax.random.PRNGKey(0))

    wl = PromptWorkload(dataset_spec(dataset, cfg_t.vocab), seed=7)
    wall = {}

    # Short LM pre-training on the workload so the router develops the
    # cluster-conditioned popularity/affinity structure trained MoEs show
    # (traces from a random router would understate predictability).
    if train_steps:
        import jax as _jax
        from repro.training.optimizer import AdamW
        from repro.training.train_loop import make_train_step
        t0 = time.time()
        opt = AdamW(lr=1e-3, weight_decay=0.01)
        ost = opt.init(params)
        step = _jax.jit(make_train_step(bundle, opt))
        rng = np.random.default_rng(3)
        first = last = None
        for i in range(train_steps):
            rows = []
            for _ in range(8):
                t = np.concatenate([wl.prompt()[0], wl.prompt()[0],
                                    wl.prompt()[0]])[:96]
                rows.append(np.pad(t, (0, 96 - len(t))))
            toks = np.stack(rows)
            params, ost, m = step(params, ost, {"tokens": jnp.asarray(toks)})
            if i == 0:
                first = float(m["loss"])
            last = float(m["loss"])
        wall["pretrain_s"] = time.time() - t0
        wall["pretrain_loss"] = (first, last)

    # offline preprocess: trace collection (paper: ~2.5% of the dataset)
    t0 = time.time()
    prompts = [p[:prompt_cap] for p, _ in wl.prompts(n_trace_requests)]
    tracer, _ = collect_traces(cfg_t, params, prompts, max_new=max_new)
    stats = tracer.stats()
    wall["trace_s"] = time.time() - t0

    # predictor training
    t0 = time.time()
    sc = StateConstructor(stats)
    X, Y = sc.build_dataset(tracer.as_array())
    ws = 1.0 if cfg_t.n_experts >= 32 else 0.25
    predictor, hist = train_predictor(jax.random.PRNGKey(1), X, Y,
                                      cfg_t.top_k, width_scale=ws,
                                      epochs=epochs, batch=256)
    wall["train_s"] = time.time() - t0

    # held-out serving under each policy (real engine; real hits/misses)
    t0 = time.time()
    eval_prompts = [p[:prompt_cap] for p, _ in wl.prompts(n_eval_requests)]
    eval_results = {}
    for pol in POLICIES:
        eng = MoEServingEngine(cfg_t, params, policy=pol, stats=stats,
                               predictor=predictor)
        eval_results[pol] = [eng.serve(p, max_new=max_new)
                             for p in eval_prompts]
    wall["eval_s"] = time.time() - t0

    art = BenchArtifacts(model, dataset, cfg_full, cfg_t, stats, predictor,
                         hist, eval_results, wall)
    with open(path, "wb") as f:
        pickle.dump(art, f)
    return art


def replay(art: BenchArtifacts, policy: str, hw: HW | None = None,
           seq_len: int = 512):
    """Replay the engine's eval traces through the simulator with FULL-scale
    costs. Returns list of SimResult."""
    hw = hw or HW()
    costs = ModelCosts(art.cfg_full, quant_bytes=QUANT_BYTES[art.model])
    out = []
    for r in art.eval_results[policy]:
        sched = make_scheduler(
            policy, art.cfg_full.n_layers, art.cfg_full.n_experts,
            art.cfg_full.top_k, int(costs.expert_bytes), stats=art.stats,
            predictor=art.predictor,
            state_constructor=StateConstructor(art.stats))
        out.append(simulate_request(sched, costs, hw, r.prefill_active,
                                    r.decode_trace, seq_len=seq_len))
    return out


def all_artifacts(models=None, datasets=DATASETS, **kw):
    models = models or list(PAPER_MODELS)
    return {(m, d): build_artifacts(m, d, **kw)
            for m in models for d in datasets}
