"""§Roofline: three-term roofline per (arch x shape x mesh) from the dry-run
artifacts (results/dryrun/*.json — loop-aware HLO flops/bytes/collectives).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16)
  memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
  collective = effective ICI bytes per device / link bw    (~50 GB/s)

Effective collective bytes apply the standard ring factors on the result
size r over a group of g participants:
  all-gather (g-1)/g * r, all-reduce 2(g-1)/g * r, reduce-scatter (g-1)/g * r,
  all-to-all (g-1)/g * r, collective-permute r.
Group size is approximated by the axis the op shards over — we report with
g = 16 (model axis; the dominant group in this sharding).

MODEL_FLOPS = 6*N*D (dense params N, tokens D) for train (3x forward) and
2*N*D for prefill/decode forward-only; MoE uses active params — i.e. the
SPARSE (grouped) expert accounting: per token only its top_k experts' rows
count. The serving engines' dense full-batch decode discipline used to
spend U (distinct experts) x B (batch) row evaluations per layer instead;
``decode_expert_flops`` makes that dense-vs-grouped delta explicit from a
layer's [B, k] selection matrix, and ``expert_flops_per_row`` is the
per-(token, expert) unit the engines' PerfCounters row totals convert with
(benchmarks/bench_latency --grouped).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.moe_layer import n_experts_padded

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")

_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def param_counts(cfg) -> Dict[str, float]:
    """(total, active) parameter counts from the config."""
    d = cfg.d_model
    hd = cfg.hd if cfg.n_heads else 0
    emb = cfg.vocab * d
    per_attn = (cfg.n_heads + 2 * cfg.n_kv_heads + cfg.n_heads) * hd * d
    total = active = emb
    if cfg.family in ("dense", "moe", "vlm"):
        n_moe = cfg.n_layers - cfg.n_dense_layers
        for _ in range(cfg.n_dense_layers):
            total += per_attn + 3 * d * cfg.dense_d_ff
            active += per_attn + 3 * d * cfg.dense_d_ff
        if cfg.is_moe:
            shared = 3 * d * cfg.n_shared_experts * cfg.d_expert
            total += n_moe * (per_attn + shared
                              + cfg.n_experts * 3 * d * cfg.d_expert
                              + d * cfg.n_experts)
            active += n_moe * (per_attn + shared
                               + cfg.top_k * 3 * d * cfg.d_expert
                               + d * cfg.n_experts)
        else:
            body = cfg.n_layers * (per_attn + 3 * d * cfg.d_ff)
            if cfg.family == "vlm":
                body += (cfg.n_layers // cfg.cross_attn_every) * \
                    (per_attn + 3 * d * cfg.d_ff)  # cross blocks
            total += body
            active += body
    elif cfg.family == "encdec":
        blk = per_attn + 3 * d * cfg.d_ff
        total += cfg.enc_layers * blk + cfg.n_layers * (blk + per_attn)
        active = total
    elif cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * d
        h = d_in // cfg.ssm_head_dim
        gn = cfg.ssm_groups * cfg.ssm_state
        per_ssm = (2 * d * d_in + 2 * d * gn + d * h
                   + (d_in + 2 * gn) * cfg.ssm_conv + d_in * d)
        total += cfg.n_layers * per_ssm
        if cfg.family == "hybrid":
            total += per_attn + 3 * d * cfg.d_ff  # one shared block
        active = total
    return {"total": total, "active": active}


def expert_flops_per_row(cfg) -> float:
    """FLOPs of ONE (token, expert) FFN row evaluation: three
    d_model x d_expert GEMM rows (gate, up, down) at 2 FLOPs/MAC."""
    return 6.0 * cfg.d_model * cfg.d_expert


def decode_expert_flops(cfg, selections) -> Dict[str, float]:
    """Per-layer decode expert FLOPs under the two execution disciplines.

    ``selections``: [B, k] expert picks of one layer's batched decode step.
    The dense full-batch path evaluates every DISTINCT expert over all B
    rows (U * B row evaluations); the segment-gathered path evaluates only
    each expert's selecting rows (sum of per-expert selecting-row counts,
    <= B * k). The roofline's active-param accounting above corresponds to
    the grouped figure — the dense one is the redundancy sparse execution
    removes."""
    sel = np.asarray(selections)
    B = sel.shape[0]
    uniq = np.unique(sel)
    dense_rows = int(uniq.size) * B
    grouped_rows = int(sum(int(np.any(sel == e, axis=1).sum())
                           for e in uniq))
    per = expert_flops_per_row(cfg)
    return {"dense_rows": dense_rows, "grouped_rows": grouped_rows,
            "dense_flops": dense_rows * per,
            "grouped_flops": grouped_rows * per}


def model_flops(cfg, shape) -> float:
    pc = param_counts(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * pc["active"] * tokens


def load_record(arch: str, shape: str, mesh: str) -> Optional[dict]:
    tag = f"{arch.replace('.', '_')}__{shape}__{mesh}.json"
    path = os.path.join(DRYRUN_DIR, tag)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline_terms(rec: dict, group: int = 16) -> Optional[dict]:
    hc = rec.get("hlo_cost")
    if not hc or "flops" not in hc:
        return None
    coll = rec.get("collectives") or {}
    coll_eff = 0.0
    for op, v in coll.items():
        f = _FACTORS.get(op, 1.0) * (group - 1) / group
        coll_eff += v["bytes"] * f
    t_comp = hc["flops"] / PEAK_FLOPS_BF16
    t_mem = hc["bytes"] / HBM_BW
    t_coll = coll_eff / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
             "collective_bytes_eff": coll_eff}
    terms["bottleneck"] = max(
        (("compute", t_comp), ("memory", t_mem), ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    return terms


def full_table(mesh: str = "single"):
    rows = []
    for arch in ARCH_IDS:
        if arch == "mixtral_8x7b":
            continue
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            if sname == "long_500k" and not cfg.supports_long_decode:
                continue
            rec = load_record(cfg.name, sname, mesh)
            if rec is None or not rec.get("ok"):
                rows.append({"arch": cfg.name, "shape": sname, "mesh": mesh,
                             "ok": False})
                continue
            t = roofline_terms(rec)
            mf = model_flops(cfg, shape) / rec["chips"]
            row = {"arch": cfg.name, "shape": sname, "mesh": mesh, "ok": True,
                   "model_flops_dev": mf, **(t or {})}
            if t:
                row["useful_ratio"] = mf / max(rec["hlo_cost"]["flops"], 1)
                dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
                row["dominant_s"] = dom
                row["roofline_frac"] = (mf / PEAK_FLOPS_BF16) / max(dom, 1e-12)
            rows.append(row)
    return rows


def run(quick=False):
    rows = []
    for r in full_table("single"):
        if not r.get("ok"):
            rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0, "MISSING"))
            continue
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            r["dominant_s"] * 1e6,
            f"comp={r['compute_s']:.4f}s,mem={r['memory_s']:.4f}s,"
            f"coll={r['collective_s']:.4f}s,bound={r['bottleneck']},"
            f"useful={r['useful_ratio']:.2f},"
            f"roofline_frac={r['roofline_frac']:.3f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
