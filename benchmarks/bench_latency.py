"""Fig. 5 reproduction: average TTFT + end-to-end latency per (model,
dataset, policy), on both hardware profiles (edge-24G / edge-48G class).

CSV columns: name,us_per_call,derived — us_per_call is the simulated mean
per-decode-step latency; derived is "<ttft_s>/<e2e_s>/<speedup_vs_odf>".
"""
from __future__ import annotations

import dataclasses
import numpy as np

from benchmarks.common import DATASETS, POLICIES, build_artifacts, replay
from repro.core.simulator import HW

HW_PROFILES = {
    "a5000": HW(),
    "a6000": dataclasses.replace(HW(), name="edge-gpu-48g", flops=38.7e12,
                                 hbm_bw=768e9, mem_budget=48e9),
}


def run(models=("mixtral-8x7b", "mixtral-8x22b", "qwen3-30b-a3b",
                "deepseekmoe-16b"), datasets=DATASETS, quick=False):
    rows = []
    hw_items = list(HW_PROFILES.items())[:1] if quick else \
        list(HW_PROFILES.items())
    for m in models:
        for d in datasets:
            art = build_artifacts(m, d)
            for hw_name, hw in hw_items:
                base = None
                for pol in POLICIES:
                    sims = replay(art, pol, hw=hw)
                    ttft = float(np.mean([s.ttft for s in sims]))
                    e2e = float(np.mean([s.e2e for s in sims]))
                    step_us = float(np.mean(
                        [s.step_latencies.mean() for s in sims])) * 1e6
                    if pol == "odf":
                        base = (ttft, e2e)
                    sp_t = base[0] / ttft
                    sp_e = base[1] / e2e
                    rows.append((f"latency/{m}/{d}/{hw_name}/{pol}", step_us,
                                 f"ttft={ttft:.3f}s,e2e={e2e:.3f}s,"
                                 f"ttft_x={sp_t:.2f},e2e_x={sp_e:.2f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
