"""Fig. 5 reproduction: average TTFT + end-to-end latency per (model,
dataset, policy), on both hardware profiles (edge-24G / edge-48G class).

CSV columns: name,us_per_call,derived — us_per_call is the simulated mean
per-decode-step latency; derived is "<ttft_s>/<e2e_s>/<speedup_vs_odf>".

``--grouped`` switches to a REAL-engine before/after A/B of the sparse
grouped-expert execution (serving/engine.py): one BatchedServingEngine run
with the dense full-batch expert paths (grouped_decode=False,
fused_prefill=False) vs one with segment-gathered decode + fused
single-launch prefill, same prompts, temperature 0. Reports per-layer
decode expert FLOPs (dense vs grouped vs launched-after-bucketing), decode
step wall p50/p99, and prefill FFN launches per layer. ``--smoke`` asserts
the grouped run's tokens match the dense run BIT-exactly, the measured
expert-FLOP reduction, at most ONE grouped-FFN launch per fused-prefill
layer, and the expert-HBM bound on both engines.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from benchmarks.common import (DATASETS, POLICIES, build_artifacts,
                               emit_bench_json, replay)
from repro.core.simulator import HW

HW_PROFILES = {
    "a5000": HW(),
    "a6000": dataclasses.replace(HW(), name="edge-gpu-48g", flops=38.7e12,
                                 hbm_bw=768e9, mem_budget=48e9),
}


def run(models=("mixtral-8x7b", "mixtral-8x22b", "qwen3-30b-a3b",
                "deepseekmoe-16b"), datasets=DATASETS, quick=False):
    rows = []
    hw_items = list(HW_PROFILES.items())[:1] if quick else \
        list(HW_PROFILES.items())
    for m in models:
        for d in datasets:
            art = build_artifacts(m, d)
            for hw_name, hw in hw_items:
                base = None
                for pol in POLICIES:
                    sims = replay(art, pol, hw=hw)
                    ttft = float(np.mean([s.ttft for s in sims]))
                    e2e = float(np.mean([s.e2e for s in sims]))
                    step_us = float(np.mean(
                        [s.step_latencies.mean() for s in sims])) * 1e6
                    if pol == "odf":
                        base = (ttft, e2e)
                    sp_t = base[0] / ttft
                    sp_e = base[1] / e2e
                    rows.append((f"latency/{m}/{d}/{hw_name}/{pol}", step_us,
                                 f"ttft={ttft:.3f}s,e2e={e2e:.3f}s,"
                                 f"ttft_x={sp_t:.2f},e2e_x={sp_e:.2f}"))
    return rows


def run_grouped(batch: int = 8, max_new: int = 10, budget: int = 4,
                n_experts: int = 8, seed: int = 0, smoke: bool = False):
    """Real-engine dense-vs-grouped expert execution A/B (see module
    docstring). Returns the (name, value, derived) rows it prints."""
    import jax

    from benchmarks.roofline import expert_flops_per_row
    from repro.configs.base import get_config, reduced
    from repro.models.model import build
    from repro.serving.batching import BatchedServingEngine

    # reduced() shrinks mixtral to 4 experts; widen the expert axis so the
    # batch's selections actually diverge (the regime grouping pays off in)
    cfg = dataclasses.replace(reduced(get_config("mixtral_8x7b")),
                              n_experts=n_experts)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, size=10 + (i % 4)).astype(np.int32)
               for i in range(batch)]

    def serve(grouped: bool):
        eng = BatchedServingEngine(
            cfg, params, policy="duo", max_batch=batch, max_seq=64,
            temperature=0.0, prefill_budget=budget,
            grouped_decode=grouped, fused_prefill=grouped)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        return eng, sorted(eng.run_until_drained(), key=lambda r: r.rid)

    dense_eng, dense_fin = serve(False)
    grp_eng, grp_fin = serve(True)
    per_row = expert_flops_per_row(cfg)

    def decode_stats(eng):
        layers = max(eng.perf.decode_layers, 1)
        # skip the compile-heavy first steps for the wall percentiles
        wall = np.asarray(eng.decode_step_wall[2:] or eng.decode_step_wall)
        return layers, wall

    rows = []
    for tag, eng in (("dense", dense_eng), ("grouped", grp_eng)):
        layers, wall = decode_stats(eng)
        launched = eng.perf.decode_rows_launched
        rows.append((
            f"latency/grouped_ab/{tag}", launched * per_row / layers,
            f"decode_rows/layer={launched / layers:.2f},"
            f"dense_equiv/layer={eng.perf.decode_rows_dense / layers:.2f},"
            f"selecting/layer={eng.perf.decode_rows_grouped / layers:.2f},"
            f"decode_p50_ms={np.percentile(wall, 50) * 1e3:.2f},"
            f"decode_p99_ms={np.percentile(wall, 99) * 1e3:.2f},"
            f"prefill_launches/layer="
            f"{eng.perf.prefill_ffn_launches / max(eng.perf.prefill_moe_layers, 1):.2f},"
            f"prefill_launches_max={eng.perf.max_prefill_launches_per_layer}"
        ))
    d, g = dense_eng.perf, grp_eng.perf
    rows.append((
        "latency/grouped_ab/reduction",
        d.decode_rows_launched / max(g.decode_rows_launched, 1),
        f"expert_flops_dense={d.decode_rows_launched * per_row:.0f},"
        f"expert_flops_grouped={g.decode_rows_launched * per_row:.0f},"
        f"selecting_rows={g.decode_rows_grouped},"
        f"launch_reduction="
        f"{d.decode_ffn_launches / max(g.decode_ffn_launches, 1):.2f}x"))
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")

    if smoke:
        assert len(dense_fin) == len(grp_fin) == batch
        for rd, rg in zip(dense_fin, grp_fin):
            np.testing.assert_array_equal(
                rg.result().tokens, rd.result().tokens,
                err_msg=f"grouped diverged from dense (rid {rg.rid})")
            np.testing.assert_array_equal(rg.result().decode_trace,
                                          rd.result().decode_trace)
        # measured per-layer expert-FLOP reduction at B >= 4: both the
        # selecting-row count AND the launched rows (bucketing included)
        # must come in under the dense-discipline row count
        assert batch >= 4
        assert g.decode_rows_grouped < d.decode_rows_launched, \
            (g.decode_rows_grouped, d.decode_rows_launched)
        assert g.decode_rows_launched < d.decode_rows_launched, \
            (g.decode_rows_launched, d.decode_rows_launched)
        # one grouped-FFN launch per decode layer and per fused-prefill layer
        assert g.decode_ffn_launches == g.decode_layers
        assert g.prefill_ffn_launches == g.prefill_moe_layers
        assert g.max_prefill_launches_per_layer == 1
        for eng in (dense_eng, grp_eng):
            assert eng.cache.hbm_bound_ok, "expert-HBM bound violated"
            assert eng.cache.device_bytes == \
                eng.cache.capacity * eng.cache.bytes_per_expert
        _, grp_wall = decode_stats(grp_eng)
        emit_bench_json("latency", {
            "batch": batch, "max_new": max_new,
            "dense_rows_launched": int(d.decode_rows_launched),
            "grouped_rows_launched": int(g.decode_rows_launched),
            "row_reduction_x": (d.decode_rows_launched
                                / max(g.decode_rows_launched, 1)),
            "grouped_decode_p50_ms": float(np.percentile(grp_wall, 50)) * 1e3,
            "grouped_decode_p99_ms": float(np.percentile(grp_wall, 99)) * 1e3,
        })
        print("SMOKE OK: grouped == dense bit-exactly; "
              f"{d.decode_rows_launched / max(g.decode_rows_launched, 1):.2f}x"
              " fewer decode expert rows; 1 launch/layer in fused prefill")
    return rows


def run_obs_overhead(batch: int = 8, max_new: int = 24, budget: int = 4,
                     seed: int = 0, trials: int = 2) -> None:
    """PR-10 acceptance gate: the span recorder must add < 5% to the
    decode-step wall time. Same engine/config/prompts, spans off vs on,
    `trials` interleaved runs per mode. The compared statistic is each
    mode's MINIMUM step time across all runs: wall-clock noise on a shared
    1-core runner is strictly one-sided (preemptions only ever ADD time),
    so the per-mode floor is the stable estimate of what a step costs —
    a p50-of-one-run comparison at this scale gates on scheduler luck
    (observed spread between identical runs exceeds 15%), not on the
    instrumentation."""
    import jax

    from repro.configs.base import get_config, reduced
    from repro.models.model import build
    from repro.serving.batching import BatchedServingEngine

    cfg = reduced(get_config("mixtral_8x7b"))
    params = build(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, size=10 + (i % 4)).astype(np.int32)
               for i in range(batch)]

    def one_run(spans: bool):
        eng = BatchedServingEngine(
            cfg, params, policy="duo", max_batch=batch, max_seq=64,
            temperature=0.0, prefill_budget=budget, spans=spans)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        eng.run_until_drained()
        wall = eng.decode_step_wall[2:] or eng.decode_step_wall
        return min(wall), eng

    floor = {False: math.inf, True: math.inf}
    eng_on = None
    for _ in range(trials):
        for spans in (False, True):
            t, eng = one_run(spans)
            floor[spans] = min(floor[spans], t)
            if spans:
                eng_on = eng
    base, p_on = floor[False], floor[True]
    overhead = p_on / base - 1.0
    n_spans = len(eng_on.obs.spans()) + eng_on.obs.n_dropped
    print(f"obs-overhead: decode step floor off={base * 1e3:.3f}ms "
          f"on={p_on * 1e3:.3f}ms overhead={overhead * 100:+.2f}% "
          f"({n_spans} spans recorded, {trials} trials/mode)")
    assert p_on <= base * 1.05 + 1e-3, \
        f"span overhead {overhead * 100:.1f}% exceeds the 5% budget"
    emit_bench_json("obs_overhead", {
        "decode_floor_off_s": base, "decode_floor_on_s": p_on,
        "overhead_frac": overhead, "spans_recorded": n_spans,
        "trials": trials})
    print(f"OBS OVERHEAD OK: spans cost {max(overhead, 0.0) * 100:.2f}% "
          "<= 5% on the decode-step floor")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grouped", action="store_true",
                    help="real-engine dense-vs-grouped expert execution A/B")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="spans-on vs spans-off decode-step A/B; asserts "
                         "the < 5%% instrumentation-overhead budget")
    ap.add_argument("--smoke", action="store_true",
                    help="assert bit-exactness + FLOP/launch reductions")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--budget", type=int, default=4)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.obs_overhead:
        run_obs_overhead(batch=args.batch, budget=args.budget)
    elif args.grouped:
        run_grouped(batch=args.batch, max_new=args.max_new,
                    budget=args.budget, smoke=args.smoke)
    else:
        for name, us, derived in run(quick=args.quick):
            print(f"{name},{us:.1f},{derived}")
