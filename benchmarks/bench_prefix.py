"""Cross-request prefix/KV reuse benchmark (core/prefix.py, ISSUE 7).

Production traffic is dominated by SHARED prefixes — system prompts,
few-shot templates, multi-turn history — so this benchmark offers a
shared-prefix trace (a few long templates, each instantiated with short
unique suffixes, arriving by the benchmarks.common arrival processes) and
measures what the PrefixTree buys at each layer:

  * Engine — warm (prefix_cache=True) vs cold engine over the same trace:
    TTFT p50/p99, the hit-token fraction (tokens served from cache /
    offered prompt tokens), and `prefilled_tokens` (the un-hit work the
    engine actually ran). Tokens are asserted bit-exact vs the cold run at
    temperature 0 — reuse must be invisible in the output stream.
  * Cluster — 2 replicas, `prefix_affinity` vs `round_robin` on the same
    trace: the affinity router lands matching requests on the warm replica
    (overload-gated), so its TTFT tail shrinks while round_robin keeps
    re-prefilling templates on whichever replica the cursor hits.
    Template arrivals come in back-to-back pairs (AABB...), the pattern a
    blind cursor always splits across both replicas.
  * Disagg handoff — a 1-prefill + 1-decode pool with prefix caching: the
    second request of each template ships only its unique tail
    (`handoff_bytes_saved`, `n_tail_handoffs`).
  * Expert HBM — the per-replica residency bound must be untouched by KV
    reuse (`device_bytes == pool_capacity * bytes_per_expert`, zero
    regrows), checked on every pool.

``--smoke`` (CI) shrinks the trace and asserts the acceptance criteria:
(a) warm-vs-cold bit-exactness, (b) hit-token fraction > 0 on the shared
trace, (c) `prefix_affinity` beats `round_robin` on p99 TTFT at 2
replicas, (d) the per-replica expert-HBM bound holds — plus the tail-only
handoff strictly reducing the disagg pool's host KV bytes.

  PYTHONPATH=src python -m benchmarks.bench_prefix \
      --requests 16 --templates 2 --template-len 48 --suffix-len 4 \
      --arrival bursty [--smoke]
"""
import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import (ARRIVALS, arrival_offsets,  # noqa: E402
                               emit_bench_json)
from benchmarks.bench_cluster import hbm_report  # noqa: E402

from repro.configs.base import get_config, reduced  # noqa: E402
from repro.core.qos import percentile_report  # noqa: E402
from repro.serving.api import (GenerationRequest,  # noqa: E402
                               SamplingParams)
from repro.serving.batching import (BatchedServingEngine,  # noqa: E402
                                    kv_row_bytes, parse_prefill_budget)
from repro.serving.cluster import (ClusterFrontend,  # noqa: E402
                                   ReplicaPool)
from repro.serving.frontend import ServingFrontend  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def make_shared_prefix_prompts(n: int, n_templates: int, template_len: int,
                               suffix_len: int, vocab: int, seed: int = 11):
    """The shared-prefix trace: `n_templates` long templates, each request
    = one template + a short unique suffix. Requests come in back-to-back
    same-template PAIRS (AABB...) — the arrival pattern a round-robin
    cursor always splits across replicas, while every non-leading request
    of a template is a prefix hit for whoever cached it."""
    rng = np.random.default_rng(seed)
    templates = [rng.integers(0, vocab, size=template_len).astype(np.int32)
                 for _ in range(n_templates)]
    # make templates diverge at position 0 so cross-template hits are 0
    for i, t in enumerate(templates):
        t[0] = i % vocab
    prompts = []
    for i in range(n):
        t = templates[(i // 2) % n_templates]
        sfx = rng.integers(0, vocab, size=suffix_len).astype(np.int32)
        prompts.append(np.concatenate([t, sfx]))
    return prompts


def warm_pool(pool: ReplicaPool, prompts, vocab: int, max_new: int) -> None:
    """Compile each replica's kernels outside the measurement window with
    workload-shaped RANDOM prompts (they seed the tree too, but tree-owned
    slots are reclaimed on demand — the measured trace evicts them)."""
    rng = np.random.default_rng(999)
    shape = len(max(prompts, key=len))
    for fe in pool.frontends:
        hs = [fe.submit(GenerationRequest(
                  prompt=rng.integers(0, vocab, size=shape)
                  .astype(np.int32),
                  params=SamplingParams(max_new_tokens=max_new)))
              for _ in range(2)]
        fe.drain()
        assert all(h.done for h in hs)


def offer(fe, prompts, arrivals, max_new: int):
    """Drive the trace through a frontend on its arrival stamps."""
    pending = list(zip(arrivals, prompts))
    handles = []
    while pending or not fe.idle:
        now = time.perf_counter()
        while pending and pending[0][0] <= now:
            arr, p = pending.pop(0)
            handles.append(fe.submit(GenerationRequest(
                prompt=p, params=SamplingParams(max_new_tokens=max_new),
                arrival=arr)))
        ev = fe.poll(now)
        if not ev.did_work and pending:
            time.sleep(max(pending[0][0] - time.perf_counter(), 0.0))
    return handles


def _ttfts(handles):
    return [h.req.result().ttft_wall for h in handles]


# ---------------------------------------------------------------------------
# engine layer: warm vs cold, bit-exact
# ---------------------------------------------------------------------------


def run_engine(cfg, params, prompts, args, budget, *, prefix_cache):
    eng = BatchedServingEngine(
        cfg, params, policy=args.policy, max_batch=args.max_batch,
        max_seq=max(len(p) for p in prompts) + args.max_new + 2,
        prefill_budget=budget, temperature=0.0, prefix_cache=prefix_cache)
    fe = ServingFrontend(eng)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    arrivals = t0 + arrival_offsets(args.arrival, args.rate, len(prompts),
                                    rng)
    handles = offer(fe, prompts, arrivals, args.max_new)
    offered_tokens = sum(len(p) for p in prompts)
    tree = eng.prefix
    rec = {
        "prefix_cache": prefix_cache,
        "ttft": percentile_report(_ttfts(handles)),
        "offered_prompt_tokens": offered_tokens,
        "prefilled_tokens": int(eng.prefilled_tokens),
        "hit_tokens": int(tree.hit_tokens) if tree else 0,
        "hit_fraction": (tree.hit_tokens / offered_tokens) if tree else 0.0,
        "reclaimed_slots": int(tree.reclaimed_slots) if tree else 0,
    }
    return rec, [list(h.tokens) for h in handles]


# ---------------------------------------------------------------------------
# cluster layer: prefix_affinity vs round_robin
# ---------------------------------------------------------------------------


def run_cluster(cfg, params, prompts, args, budget, *, router):
    mb = args.cluster_max_batch or args.max_batch
    pool = ReplicaPool.build(
        cfg, params, 2, policy=args.policy, max_batch=mb,
        max_seq=max(len(p) for p in prompts) + args.max_new + 2,
        prefill_budget=budget, temperature=0.0, prefix_cache=True)
    warm_pool(pool, prompts, cfg.vocab, args.max_new)
    fe = ClusterFrontend(pool, router=router)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    arrivals = t0 + arrival_offsets(args.arrival, args.rate, len(prompts),
                                    rng)
    handles = offer(fe, prompts, arrivals, args.max_new)
    hit_tokens = sum(e.prefix.hit_tokens for e in pool.engines)
    offered_tokens = sum(len(p) for p in prompts)
    rec = {
        "router": router,
        "ttft": percentile_report(_ttfts(handles)),
        "hit_tokens": int(hit_tokens),
        "hit_fraction": hit_tokens / offered_tokens,
        "prefilled_tokens": int(sum(e.prefilled_tokens
                                    for e in pool.engines)),
        "balance": [sum(1 for h in handles if h.replica == i)
                    for i in range(2)],
        "per_replica_hbm": hbm_report(pool),
    }
    return rec, [list(h.tokens) for h in handles]


# ---------------------------------------------------------------------------
# disagg layer: tail-only handoff
# ---------------------------------------------------------------------------


def run_disagg(cfg, params, prompts, args, budget, *, prefix_cache):
    pool = ReplicaPool.build(
        cfg, params, policy=args.policy, max_batch=args.max_batch,
        max_seq=max(len(p) for p in prompts) + args.max_new + 2,
        prefill_budget=budget, temperature=0.0, prefix_cache=prefix_cache,
        overrides=[{"role": "prefill"}, {"role": "decode"}])
    fe = ClusterFrontend(pool, router="disagg")
    toks = []
    for p in prompts:        # sequential: later templates find a warm head
        h = fe.submit(GenerationRequest(
            prompt=p, params=SamplingParams(max_new_tokens=args.max_new)))
        fe.drain()
        toks.append(list(h.tokens))
    rec = {
        "prefix_cache": prefix_cache,
        "handoffs": int(pool.n_handoffs),
        "tail_handoffs": int(pool.n_tail_handoffs),
        "handoff_kv_bytes": int(pool.handoff_bytes),
        "handoff_kv_bytes_saved": int(pool.handoff_bytes_saved),
        "kv_row_bytes": kv_row_bytes(pool.engines[0]),
        "per_replica_hbm": hbm_report(pool),
    }
    return rec, toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--templates", type=int, default=2)
    ap.add_argument("--template-len", type=int, default=48)
    ap.add_argument("--suffix-len", type=int, default=4)
    ap.add_argument("--arrival", default="bursty", choices=list(ARRIVALS))
    ap.add_argument("--rate", type=float, default=50.0,
                    help="mean offered load (req/s); bursty clumps it")
    ap.add_argument("--max-new", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cluster-max-batch", type=int, default=None,
                    help="per-replica KV slots for the 2-replica router "
                         "comparison (default: --max-batch)")
    ap.add_argument("--policy", default="duo")
    ap.add_argument("--prefill-budget", default="4")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI sweep asserting warm==cold tokens, hit "
                         "fraction > 0, a prefix_affinity p99-TTFT win "
                         "over round_robin at 2 replicas, the tail-only "
                         "handoff byte drop, and the per-replica "
                         "expert-HBM bound")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.max_new = 12, 2
        args.template_len, args.suffix_len = 40, 4
        # ONE slot per replica: a replica serving a single template hits
        # on every follower (the retained slot's rows are copied out
        # before the follower evicts it), while a replica fed BOTH
        # templates by a blind cursor always finds the wrong template
        # cached — the regime where prefix-aware routing is the whole
        # game. The single-engine run keeps 4 slots.
        args.cluster_max_batch = 1

    cfg = reduced(get_config(args.arch))
    from repro.models.model import build
    params = build(cfg).init(jax.random.PRNGKey(0))
    budget = parse_prefill_budget(args.prefill_budget)
    prompts = make_shared_prefix_prompts(
        args.requests, args.templates, args.template_len, args.suffix_len,
        cfg.vocab)
    records = {}

    # -- engine: warm vs cold, bit-exact -----------------------------------
    cold, cold_toks = run_engine(cfg, params, prompts, args, budget,
                                 prefix_cache=False)
    warm, warm_toks = run_engine(cfg, params, prompts, args, budget,
                                 prefix_cache=True)
    assert warm_toks == cold_toks, \
        "prefix reuse changed the token stream (temp 0 must be bit-exact)"
    records["engine"] = [cold, warm]
    print("engine (warm vs cold, same trace, tokens bit-exact):")
    for r in records["engine"]:
        print(f"  prefix_cache={str(r['prefix_cache']):5s} "
              f"ttft_p50={r['ttft']['p50']:7.3f}s "
              f"ttft_p99={r['ttft']['p99']:7.3f}s "
              f"prefilled={r['prefilled_tokens']:5d}/"
              f"{r['offered_prompt_tokens']:5d} "
              f"hit_fraction={r['hit_fraction']:.2f}")
    assert warm["prefilled_tokens"] < cold["prefilled_tokens"], \
        "prefix cache did not reduce prefilled tokens"

    # -- cluster: prefix_affinity vs round_robin ---------------------------
    print("\ncluster (2 replicas, shared-prefix trace):")
    records["cluster"] = []
    for router in ("round_robin", "prefix_affinity"):
        rec, toks = run_cluster(cfg, params, prompts, args, budget,
                                router=router)
        assert toks == cold_toks, f"{router} diverged from cold reference"
        records["cluster"].append(rec)
        hbm_ok = all(h["ok"] for h in rec["per_replica_hbm"])
        print(f"  {router:>16s} ttft_p50={rec['ttft']['p50']:7.3f}s "
              f"ttft_p99={rec['ttft']['p99']:7.3f}s "
              f"hit_fraction={rec['hit_fraction']:.2f} "
              f"balance={rec['balance']} "
              f"hbm={'ok' if hbm_ok else 'VIOLATED'}")
        assert hbm_ok, f"expert-HBM bound violated: {rec['per_replica_hbm']}"

    # -- disagg: tail-only handoff -----------------------------------------
    print("\ndisagg 1p:1d (sequential trace, tail-only handoff):")
    records["disagg"] = []
    for pc in (False, True):
        rec, toks = run_disagg(cfg, params, prompts[:6], args, budget,
                               prefix_cache=pc)
        assert toks == cold_toks[:6], "disagg run diverged from reference"
        records["disagg"].append(rec)
        print(f"  prefix_cache={str(pc):5s} handoffs={rec['handoffs']:3d} "
              f"tail={rec['tail_handoffs']:3d} "
              f"moved={rec['handoff_kv_bytes'] / 2**10:8.1f}KB "
              f"saved={rec['handoff_kv_bytes_saved'] / 2**10:8.1f}KB")
    full, tail = records["disagg"]
    assert tail["handoff_kv_bytes"] < full["handoff_kv_bytes"], \
        "tail-only handoff did not reduce host KV bytes moved"
    assert tail["handoff_kv_bytes"] + tail["handoff_kv_bytes_saved"] \
        == full["handoff_kv_bytes"]

    if args.smoke:
        assert warm["hit_fraction"] > 0.0, "no prefix hits on shared trace"
        rr, pa = records["cluster"]
        assert pa["hit_fraction"] > rr["hit_fraction"], \
            "prefix_affinity did not raise the cluster hit fraction"
        assert pa["ttft"]["p99"] < rr["ttft"]["p99"], \
            (f"prefix_affinity p99 TTFT {pa['ttft']['p99']:.3f}s did not "
             f"beat round_robin {rr['ttft']['p99']:.3f}s")
        emit_bench_json("prefix", {
            "warm_hit_fraction": warm["hit_fraction"],
            "warm_prefilled_tokens": warm["prefilled_tokens"],
            "cold_prefilled_tokens": cold["prefilled_tokens"],
            "round_robin_ttft_p99_s": rr["ttft"]["p99"],
            "prefix_affinity_ttft_p99_s": pa["ttft"]["p99"],
            "tail_handoff_bytes_saved": tail["handoff_kv_bytes_saved"],
        })
        print("\nbench_prefix smoke OK: warm==cold bit-exact; hit fraction "
              f"{warm['hit_fraction']:.2f}; prefix_affinity p99 "
              f"{pa['ttft']['p99']:.3f}s < round_robin "
              f"{rr['ttft']['p99']:.3f}s; tail handoff saved "
              f"{tail['handoff_kv_bytes_saved']} bytes; per-replica "
              "expert HBM bounded")

    out = args.out
    if out is None:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(RESULTS, "prefix.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
