"""Table II reproduction: peak memory per (model, policy) + GPU-only
reference. Byte-accounted from policy residency (CacheState.peak_bytes) +
non-expert weights + KV cache, under the paper's quantization."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POLICIES, build_artifacts, replay
from repro.configs.paper_models import PAPER_MODELS, QUANT_BYTES
from repro.core.simulator import ModelCosts


def gpu_only_bytes(model: str) -> float:
    cfg = PAPER_MODELS[model]
    q = QUANT_BYTES[model]
    costs = ModelCosts(cfg, quant_bytes=q)
    experts = cfg.n_layers * cfg.n_experts * costs.expert_bytes
    return experts + costs.nonexpert_resident_bytes()


def run(models=("mixtral-8x7b", "mixtral-8x22b", "qwen3-30b-a3b",
                "deepseekmoe-16b"), quick=False):
    rows = []
    for m in models:
        art = build_artifacts(m, "squad")
        for pol in POLICIES:
            sims = replay(art, pol)
            peak = float(np.max([s.peak_bytes for s in sims]))
            rows.append((f"memory/{m}/{pol}", peak / 1e6,
                         f"peak_gb={peak / 1e9:.2f}"))
        rows.append((f"memory/{m}/gpu_only", gpu_only_bytes(m) / 1e6,
                     f"peak_gb={gpu_only_bytes(m) / 1e9:.2f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
