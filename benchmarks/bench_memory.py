"""Table II reproduction: peak memory per (model, policy) + GPU-only
reference. Byte-accounted from policy residency (CacheState.peak_bytes) +
non-expert weights + KV cache, under the paper's quantization.

Since the unified ExpertResidency (core/cache.py), the simulator's ledger
peak is no longer an *estimate* of device behaviour — the engine's expert
HBM is a preallocated slot pool whose size IS the bound. ``--smoke`` runs
tiny real engines (single-request, batched, chunked) across policies and
asserts ``device_bytes == capacity * bytes_per_expert`` end-to-end, exiting
nonzero on violation (the CI bench-smoke job).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (POLICIES, build_artifacts, emit_bench_json,
                               replay)
from repro.configs.paper_models import PAPER_MODELS, QUANT_BYTES
from repro.core.simulator import ModelCosts


def gpu_only_bytes(model: str) -> float:
    cfg = PAPER_MODELS[model]
    q = QUANT_BYTES[model]
    costs = ModelCosts(cfg, quant_bytes=q)
    experts = cfg.n_layers * cfg.n_experts * costs.expert_bytes
    return experts + costs.nonexpert_resident_bytes()


def run(models=("mixtral-8x7b", "mixtral-8x22b", "qwen3-30b-a3b",
                "deepseekmoe-16b"), quick=False):
    rows = []
    for m in models:
        art = build_artifacts(m, "squad")
        for pol in POLICIES:
            sims = replay(art, pol)
            peak = float(np.max([s.peak_bytes for s in sims]))
            rows.append((f"memory/{m}/{pol}", peak / 1e6,
                         f"peak_gb={peak / 1e9:.2f}"))
        rows.append((f"memory/{m}/gpu_only", gpu_only_bytes(m) / 1e6,
                     f"peak_gb={gpu_only_bytes(m) / 1e9:.2f}"))
    return rows


def smoke() -> None:
    """Assert the expert-HBM bound on REAL engines with a tiny config."""
    import jax
    from repro.configs.base import get_config, reduced
    from repro.core.tracer import ExpertsTracer
    from repro.models.model import build
    from repro.serving.batching import BatchedServingEngine
    from repro.serving.engine import MoEServingEngine

    cfg = reduced(get_config("mixtral_8x7b"))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (10, 7)]
    tracer = ExpertsTracer(cfg.n_layers, cfg.n_experts, cfg.top_k)
    for _ in range(6):
        tracer.add_path(np.stack([
            rng.choice(cfg.n_experts, cfg.top_k, replace=False)
            for _ in range(cfg.n_layers)]))
    stats = tracer.stats()

    def check(tag, res):
        bound = res.capacity * res.bytes_per_expert
        ok = (res.device_bytes == res.pool_capacity * res.bytes_per_expert
              and res.pool_capacity == res.capacity
              and res.regrow_events == 0
              and set(res.slot_of) == set(res.resident))
        print(f"memory-smoke/{tag}: expert_hbm={res.device_bytes}B "
              f"bound={bound}B resident={len(res.resident)}"
              f"/{res.capacity} {'OK' if ok else 'VIOLATED'}")
        assert ok, f"{tag}: expert-HBM bound violated"

    record = {}
    for pol in ("odf", "lfp", "mif", "duo"):
        eng = MoEServingEngine(cfg, params, policy=pol, stats=stats,
                               temperature=0.0)
        for p in prompts:
            eng.serve(p, max_new=2)
        check(f"single/{pol}", eng.cache)

        beng = BatchedServingEngine(cfg, params, policy=pol, stats=stats,
                                    max_batch=2, max_seq=24,
                                    temperature=0.0, prefill_budget=3)
        for p in prompts:
            beng.submit(p, max_new=2)
        beng.run_until_drained()
        check(f"batched-chunked/{pol}", beng.cache)
        record[pol] = {"expert_hbm_bytes": int(beng.cache.device_bytes),
                       "bound_bytes": int(beng.cache.capacity
                                          * beng.cache.bytes_per_expert),
                       "regrow_events": int(beng.cache.regrow_events)}
    emit_bench_json("memory", record)
    print("bench_memory smoke OK: expert HBM bounded by "
          "capacity x bytes_per_expert for every policy and path")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny real-engine run asserting the expert-HBM "
                         "bound (CI)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for name, us, derived in run():
            print(f"{name},{us:.1f},{derived}")
