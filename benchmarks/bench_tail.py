"""Fig. 6 reproduction: P50/P95 end-to-end tail latency, representative
models (Mixtral-8x7B, Qwen3-30B-A3B) on the SQuAD-like workload."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POLICIES, build_artifacts, replay
from repro.core.qos import summarize


def run(models=("mixtral-8x7b", "qwen3-30b-a3b"), quick=False):
    rows = []
    for m in models:
        art = build_artifacts(m, "squad")
        for pol in POLICIES:
            sims = replay(art, pol)
            q = summarize([s.ttft for s in sims], [s.e2e for s in sims],
                          total_tokens=sum(len(s.step_latencies)
                                           for s in sims))
            rows.append((f"tail/{m}/squad/{pol}",
                         q.p50_e2e * 1e6,
                         f"p50={q.p50_e2e:.3f}s,p95={q.p95_e2e:.3f}s,"
                         f"p99={q.p99_e2e:.3f}s"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
