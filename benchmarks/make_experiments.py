"""Generate EXPERIMENTS.md from the dry-run artifacts + benchmark caches.

Sections:
  §Dry-run          — every (arch x shape x mesh) lower+compile result
  §Roofline         — three terms, bottleneck, MODEL_FLOPS ratio (single-pod)
  §Perf             — baseline vs optimized A/B for the hillclimb pairs,
                      with the hypothesis log (hand-written in PERF_LOG)
  §Paper-validation — Fig5/6/7 + Table II/III reproductions vs paper claims

Run:  PYTHONPATH=src:. python -m benchmarks.make_experiments
"""
from __future__ import annotations

import json
import os
from typing import Optional

from benchmarks.roofline import (full_table, load_record, model_flops,
                                 roofline_terms)
from repro.configs.base import INPUT_SHAPES, get_config, pairs
from repro.launch.mesh import PEAK_FLOPS_BF16

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "EXPERIMENTS.md")

HILLCLIMBS = [
    # (arch, shape, opts-suffix, why chosen)
    ("gemma3-1b", "prefill_32k", "opt-static_window",
     "worst roofline fraction / useful ratio (window-oblivious attention)"),
    ("qwen3-1.7b", "train_4k", "opt-seq_parallel",
     "most collective-bound (highest collective/dominant ratio)"),
    ("kimi-k2-1t-a32b", "decode_32k", "opt-active_gather",
     "most representative of the paper's technique: expert-weight movement "
     "during decode"),
]


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6)):
        if abs(b) >= div:
            return f"{b / div:.2f}{unit}"
    return f"{b:.0f}B"


def sec_dryrun() -> str:
    lines = [
        "## §Dry-run\n",
        "Every applicable (architecture x input-shape) pair lowers and "
        "compiles on BOTH production meshes (16x16 = 256 chips; 2x16x16 = "
        "512 chips). `temp` is XLA's per-device temp allocation "
        "(`memory_analysis`), `args` the per-device parameter+optimizer+"
        "cache bytes; `coll` the per-device collective payload from the "
        "loop-aware HLO walk (launch/hlo_cost.py). Train pairs use adaptive "
        "microbatch gradient accumulation (4-16 way by model size) and "
        "conditional FSDP/ZeRO-3 (params+moments data-sharded when state "
        ">8 GB/chip). Decode/prefill caches shard per DESIGN.md SS4.\n",
        "| arch | shape | mesh | lower | compile | args/dev | temp/dev |"
        " HLO flops/dev | HLO bytes/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_all = 0
    for cfg, shape in pairs():
        for mesh in ("single", "multi"):
            rec = load_record(cfg.name, shape.name, mesh)
            n_all += 1
            if rec is None:
                lines.append(f"| {cfg.name} | {shape.name} | {mesh} | "
                             f"MISSING | | | | | | |")
                continue
            if not rec.get("ok"):
                lines.append(f"| {cfg.name} | {shape.name} | {mesh} | FAIL: "
                             f"{rec.get('error', '?')[:60]} | | | | | | |")
                continue
            n_ok += 1
            m = rec.get("memory", {})
            hc = rec.get("hlo_cost", {})
            coll = rec.get("collectives", {}) or {}
            cs = ", ".join(f"{k}x{int(v['count'])}({fmt_bytes(v['bytes'])})"
                           for k, v in sorted(coll.items())) or "none"
            lines.append(
                f"| {cfg.name} | {shape.name} | {mesh} | {rec['lower_s']}s | "
                f"{rec.get('compile_s')}s | "
                f"{fmt_bytes(m.get('argument_bytes'))} | "
                f"{fmt_bytes(m.get('temp_bytes'))} | "
                f"{hc.get('flops', 0):.3e} | {fmt_bytes(hc.get('bytes'))} | "
                f"{cs} |")
    lines.insert(1, f"\n**{n_ok}/{n_all} pair-mesh combinations compile "
                 "successfully.**\n")
    return "\n".join(lines) + "\n"


def sec_roofline() -> str:
    lines = [
        "## §Roofline (single-pod 16x16, per device)\n",
        "Terms: compute = HLO_FLOPs / 197 TFLOP/s; memory = HLO_bytes / "
        "819 GB/s; collective = effective ICI bytes (ring factors, g=16) / "
        "50 GB/s. `useful` = MODEL_FLOPS (6*N_active*D train, 2*N_active*D "
        "inference) / HLO_FLOPs — the fraction of compiled compute that is "
        "model math (captures remat recompute, causal-mask waste, capacity "
        "overprovisioning). `rl_frac` = (MODEL_FLOPS/peak) / dominant term "
        "— achieved fraction of the ideal compute roofline.\n",
        "Methodology notes: (1) XLA's CPU backend promotes bf16 dots to f32 "
        "— weight/activation traffic in these numbers is ~2x what the bf16-"
        "native TPU backend moves; §Perf compares like against like. "
        "(2) The jnp chunked-attention path materializes its logit tiles to "
        "HBM; the Pallas flash_attention kernel keeps them in VMEM — the "
        "memory term here is the *pre-kernel* bound, and the kernels are "
        "exactly the fix (validated in tests/test_kernels.py).\n",
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck |"
        " useful | rl_frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    fixes = {
        ("ssm", "train_4k"): "Pallas ssd_scan (keeps decay tiles in VMEM)",
        ("hybrid", "train_4k"): "Pallas ssd_scan + flash attention",
    }
    for r in full_table("single"):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | MISSING | | | | | | |")
            continue
        cfg = get_config(r["arch"].replace("-", "_").replace(".", "_")
                         if False else r["arch"])
        if r["shape"].startswith("decode") or r["shape"] == "long_500k":
            fix = ("stream only routed experts (active-gather, §Perf H3)"
                   if cfg.is_moe else
                   "KV-cache quantization / head-sharded cache reads")
        elif cfg.is_moe:
            fix = "Pallas expert_ffn (VMEM-resident dispatch buffers) + bf16 tiles"
        elif cfg.sliding_window:
            fix = "window-restricted attention (§Perf H1)"
        elif cfg.family in ("ssm", "hybrid"):
            fix = fixes.get((cfg.family, r["shape"]),
                            "Pallas ssd_scan / flash attention")
        else:
            fix = "Pallas flash attention (VMEM tiles) + bf16 logits"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {fix} |")
    return "\n".join(lines) + "\n"


def _pair_summary(arch, shape, suffix: Optional[str]) -> Optional[dict]:
    mesh = "single" + (f"__{suffix}" if suffix else "")
    rec = load_record(arch, shape, mesh)
    if rec is None or not rec.get("ok"):
        return None
    t = roofline_terms(rec)
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape]
    mf = model_flops(cfg, sh) / rec["chips"]
    dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return {**t, "dominant_s": dom, "rl": (mf / PEAK_FLOPS_BF16) / dom,
            "flops": rec["hlo_cost"]["flops"],
            "bytes": rec["hlo_cost"]["bytes"]}


def sec_perf(log_md: str) -> str:
    lines = ["## §Perf — hillclimbing the three selected pairs\n", log_md,
             "\n### Measured A/B (dry-run, single-pod, per device)\n",
             "| pair | variant | compute_s | memory_s | collective_s | "
             "dominant | rl_frac | delta dominant |",
             "|---|---|---|---|---|---|---|---|"]
    for arch, shape, suffix, why in HILLCLIMBS:
        base = _pair_summary(arch, shape, None)
        opt = _pair_summary(arch, shape, suffix)
        for name, r in (("baseline (paper-faithful)", base),
                        (suffix, opt)):
            if r is None:
                lines.append(f"| {arch}/{shape} | {name} | MISSING | | | | | |")
                continue
            delta = ""
            if r is opt and base:
                delta = f"{(1 - r['dominant_s'] / base['dominant_s']) * 100:+.1f}%"
                delta = f"-{(1 - r['dominant_s'] / base['dominant_s']) * 100:.1f}%" \
                    if r['dominant_s'] < base['dominant_s'] else \
                    f"+{(r['dominant_s'] / base['dominant_s'] - 1) * 100:.1f}%"
            lines.append(
                f"| {arch}/{shape} | {name} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"{r['dominant_s']:.4f} | {r['rl']:.4f} | {delta} |")
    return "\n".join(lines) + "\n"


def sec_paper(bench_csv: Optional[str]) -> str:
    lines = ["## §Paper-validation\n"]
    if bench_csv and os.path.exists(bench_csv):
        lines.append("Benchmark harness output (`python -m benchmarks.run`):\n")
        lines.append("```")
        with open(bench_csv) as f:
            lines.append(f.read().strip())
        lines.append("```")
    return "\n".join(lines) + "\n"


def main(perf_log_path="benchmarks/perf_log.md",
         bench_csv="bench_output.txt",
         validation_md="benchmarks/validation.md"):
    log_md = ""
    if os.path.exists(os.path.join(ROOT, perf_log_path)):
        log_md = open(os.path.join(ROOT, perf_log_path)).read()
    parts = [
        "# EXPERIMENTS — DuoServe-MoE reproduction\n",
        "Generated by `benchmarks/make_experiments.py` from "
        "results/dryrun/*.json and the benchmark caches. "
        "See DESIGN.md for methodology.\n",
        sec_dryrun(),
        sec_roofline(),
        sec_perf(log_md),
    ]
    vpath = os.path.join(ROOT, validation_md)
    if os.path.exists(vpath):
        parts.append(open(vpath).read())
    parts.append(sec_paper(os.path.join(ROOT, bench_csv)))
    with open(OUT, "w") as f:
        f.write("\n".join(parts))
    print("wrote", OUT)


if __name__ == "__main__":
    main()
