"""Fig. 7 reproduction: total throughput (tokens/s) vs batch size 1..12.

Batching model (paper §VI-B): per decode step the batch activates the UNION
of each request's routed experts per layer — densified activation — and each
expert processes all its assigned tokens. We merge B eval-request traces per
step/layer and replay through each policy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import POLICIES, build_artifacts
from repro.configs.paper_models import QUANT_BYTES
from repro.core.scheduler import make_scheduler
from repro.core.simulator import HW, ModelCosts, StreamSim, _op_time, \
    _xfer_time
from repro.core.state import StateConstructor


def merged_step_experts(results, batch: int, step: int, layer: int):
    sel = []
    for r in results[:batch]:
        if step < r.decode_trace.shape[0]:
            sel.extend(int(e) for e in r.decode_trace[step, layer])
    return sorted(set(sel)), len(sel)


def simulate_batched(art, policy: str, batch: int, hw: HW, seq_len=512,
                     steps=10):
    cfg = art.cfg_full
    costs = ModelCosts(cfg, quant_bytes=QUANT_BYTES[art.model])
    sched = make_scheduler(policy, cfg.n_layers, cfg.n_experts, cfg.top_k,
                           int(costs.expert_bytes), stats=art.stats,
                           predictor=art.predictor,
                           state_constructor=StateConstructor(art.stats))
    sched.begin_request()
    results = art.eval_results[policy]
    # cycle eval requests to fill the batch
    results = (results * ((batch // len(results)) + 1))[:batch]
    sim = StreamSim()
    t_fx = _xfer_time(costs.expert_bytes, hw)
    done = 0.0
    total_tokens = 0
    # prefill (all B prompts; union per layer)
    for l in range(cfg.n_layers):
        active = sorted({e for r in results for e in r.prefill_active[l]})
        plan = sched.prefill_plan(l, active)
        t_attn = _op_time(costs.nonmoe_flops(seq_len * batch, seq_len),
                          costs.nonmoe_bytes_per_layer, hw)
        attn_end = sim.issue("comp", t_attn, [done])
        fx_end = attn_end if not plan.overlap_first else done
        for e in plan.fetches:
            fx_end = sim.issue("comm", t_fx, [fx_end])
        tok_e = max(batch * seq_len * cfg.top_k // max(len(active), 1), 1)
        cend = max(attn_end, fx_end if plan.prefetch_all_first else attn_end)
        for i, e in enumerate(plan.order):
            dep = [cend] if plan.prefetch_all_first else [max(cend, fx_end)]
            cend = sim.issue("comp",
                             _op_time(costs.expert_flops(tok_e),
                                      costs.expert_bytes, hw), dep)
        sched.end_layer(l)
        done = cend
    total_tokens += batch
    ttft = done

    from repro.core.scheduler import DuoServeScheduler
    for t in range(steps):
        if isinstance(sched, DuoServeScheduler):
            sched.begin_decode_step()
        for l in range(cfg.n_layers):
            union, n_assign = merged_step_experts(results, batch, t, l)
            if not union:
                continue
            t_attn = _op_time(costs.nonmoe_flops(batch, seq_len + t),
                              costs.nonmoe_bytes_per_layer
                              + batch * costs.kv_bytes(seq_len + t), hw)
            attn_end = sim.issue("comp", t_attn, [done])
            plan = sched.decode_plan(l, union)
            miss_end = attn_end
            for e in plan.misses:
                miss_end = sim.issue("comm", t_fx, [miss_end])
            cend = max(attn_end, miss_end)
            tok_e = max(n_assign // max(len(union), 1), 1)
            for e in plan.hits + plan.misses:
                cend = sim.issue("comp",
                                 _op_time(costs.expert_flops(tok_e),
                                          costs.expert_bytes, hw), [cend])
            if plan.prefetch_next:
                pdep = [attn_end]
                if sched.uses_predictor:
                    pdep = [sim.issue("pred", hw.pred_lat, [attn_end])]
                for e in plan.prefetch_next:
                    sim.issue("comm", t_fx, pdep)
            done = cend
        total_tokens += batch
    return total_tokens / done, ttft


def run(models=("mixtral-8x7b", "mixtral-8x22b", "qwen3-30b-a3b",
                "deepseekmoe-16b"), batches=(1, 2, 4, 8, 12), quick=False):
    hw = HW()
    rows = []
    if quick:
        models = models[:1]
        batches = (1, 4)
    for m in models:
        art = build_artifacts(m, "squad")
        for b in batches:
            for pol in POLICIES:
                tput, ttft = simulate_batched(art, pol, b, hw)
                rows.append((f"throughput/{m}/b{b}/{pol}", 1e6 / tput,
                             f"tokens_per_s={tput:.2f},ttft={ttft:.3f}s"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
