"""Concurrent-load QoS benchmark: Poisson arrivals into the continuous-
batching engine, p50/p99 TTFT + TPOT vs offered load.

The paper reports single-request TTFT/E2E; this driver measures the serving
regime those SLOs actually matter in — requests arriving mid-flight, decode
batched across in-flight requests, one shared expert cache. Per offered load
it reports:

  * TTFT p50/p99  (arrival -> first token, includes queueing)
  * TPOT p50/p99  (per-output-token decode latency after the first token)
  * throughput (tokens/s), mean decode batch size, shed (SLO-rejected) count

  PYTHONPATH=src python benchmarks/bench_concurrent.py \
      --rates 0.5,1.0,2.0 --requests 8 --max-new 6 [--ttft-slo 30]
"""
import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.qos import AdmissionController, percentile_report
from repro.data.pipeline import PromptWorkload, squad_like
from repro.models.model import build
from repro.serving.batching import (BatchedServingEngine, RequestQueue,
                                    parse_prefill_budget)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def run_load(cfg, params, prompts, *, rate: float, max_new: int,
             max_batch: int, policy: str, ttft_slo, seed: int = 0,
             prefill_budget=None, tbt_slo=None, fairness="rr") -> dict:
    """Offer `prompts` at Poisson rate `rate` req/s; drain; summarize."""
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / rate, size=len(prompts))
    t0 = time.perf_counter()
    arrivals = t0 + np.cumsum(inter)

    queue = RequestQueue(AdmissionController(default_ttft_slo=ttft_slo))
    eng = BatchedServingEngine(cfg, params, policy=policy,
                               max_batch=max_batch,
                               max_seq=max(len(p) for p in prompts)
                               + max_new + 2,
                               prefill_budget=prefill_budget,
                               tbt_slo=tbt_slo, prefill_fairness=fairness,
                               queue=queue, temperature=0.0)
    pending = list(zip(arrivals, prompts))
    while pending or len(eng.queue) or eng.prefilling or eng.running:
        now = time.perf_counter()
        while pending and pending[0][0] <= now:
            arr, p = pending.pop(0)
            eng.submit(p, max_new=max_new, arrival=arr)
        if not eng.step(now):
            # idle until the next arrival
            if pending:
                time.sleep(max(pending[0][0] - time.perf_counter(), 0.0))
    wall = time.perf_counter() - t0

    done = [r.result() for r in eng.finished]
    ttfts = [r.ttft_wall for r in done]
    tpots = [(r.e2e_wall - r.ttft_wall) / max(len(r.tokens) - 1, 1)
             for r in done]
    total_tokens = sum(len(r.tokens) for r in done)
    rec = {
        "rate_req_s": rate,
        "offered": len(prompts),
        "completed": len(done),
        "rejected": len(eng.queue.rejected),
        "ttft": percentile_report(ttfts),
        "tpot": percentile_report(tpots),
        "tokens_per_s": total_tokens / max(wall, 1e-9),
        "mean_decode_batch": (float(np.mean(eng.decode_batch_hist))
                              if eng.decode_batch_hist else 0.0),
        "wall_s": wall,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--rates", default="0.5,2.0",
                    help="comma list of offered loads (requests/s)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=5)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--policy", default="duo+")
    ap.add_argument("--ttft-slo", type=float, default=None,
                    help="seconds; requests predicted to breach are shed")
    ap.add_argument("--prefill-budget", default=None,
                    help="chunked prefill tokens per step, 'auto' to derive "
                         "from the live LatencyModel (needs --tbt-slo), or "
                         "omit for monolithic")
    ap.add_argument("--tbt-slo", type=float, default=None,
                    help="target inter-token gap (s) for --prefill-budget "
                         "auto")
    ap.add_argument("--fairness", default="rr", choices=["rr", "fifo"],
                    help="chunked-prefill budget sharing across requests")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    wl = PromptWorkload(squad_like(cfg.vocab), seed=11)
    prompts = [p[: args.prompt_len] for p, _ in wl.prompts(args.requests)]

    print(f"{'rate':>6s} {'done':>5s} {'shed':>5s} {'ttft_p50':>9s} "
          f"{'ttft_p99':>9s} {'tpot_p50':>9s} {'tpot_p99':>9s} "
          f"{'tok/s':>7s} {'avgB':>5s}")
    records = []
    for rate in [float(r) for r in args.rates.split(",")]:
        rec = run_load(cfg, params, prompts, rate=rate,
                       max_new=args.max_new, max_batch=args.max_batch,
                       policy=args.policy, ttft_slo=args.ttft_slo,
                       prefill_budget=parse_prefill_budget(args.prefill_budget),
                       tbt_slo=args.tbt_slo, fairness=args.fairness)
        records.append(rec)
        print(f"{rate:6.2f} {rec['completed']:5d} {rec['rejected']:5d} "
              f"{rec['ttft']['p50']:8.2f}s {rec['ttft']['p99']:8.2f}s "
              f"{rec['tpot']['p50']:8.2f}s {rec['tpot']['p99']:8.2f}s "
              f"{rec['tokens_per_s']:7.2f} {rec['mean_decode_batch']:5.2f}")

    out = args.out
    if out is None:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(RESULTS, "concurrent_qos.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
