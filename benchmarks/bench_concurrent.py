"""Concurrent-load QoS benchmark: request arrivals (``--arrival``: poisson /
bursty / ramp, see benchmarks.common.arrival_offsets) into the streaming
serving front-end, p50/p99 TTFT + TPOT vs offered load — plus per-request
TBT-SLO attainment and mid-flight cancellation latency, both measured off
the event stream.

The paper reports single-request TTFT/E2E; this driver measures the serving
regime those SLOs actually matter in — requests arriving mid-flight, decode
batched across in-flight requests, one shared expert cache, callers
streaming tokens through ``RequestHandle``s. Per offered load it reports:

  * TTFT p50/p99  (arrival -> first token, includes queueing)
  * TPOT p50/p99  (per-output-token decode latency after the first token)
  * TBT-SLO attainment (with --tbt-slo): per finished request, the fraction
    of its inter-token gaps under its tbt_slo (TBTLedger.attainment) —
    mean across requests + the fraction of requests fully attained
  * time-to-cancel (with --cancel-frac): wall time from the caller's
    cancel() to the engine's FinishEvent("cancelled") — i.e. until the KV
    slot, expert-residency contributions, and TBT entry are reclaimed
  * throughput (tokens/s), mean decode batch size, shed (SLO-rejected) count

  PYTHONPATH=src python benchmarks/bench_concurrent.py \
      --rates 0.5,1.0,2.0 --requests 8 --max-new 6 [--ttft-slo 30] \
      [--tbt-slo 0.5] [--cancel-frac 0.25 --cancel-after 2]
"""
import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import ARRIVALS, arrival_offsets  # noqa: E402

from repro.configs.base import get_config, reduced
from repro.core.qos import AdmissionController, percentile_report
from repro.data.pipeline import PromptWorkload, squad_like
from repro.models.model import build
from repro.serving.api import GenerationRequest, SamplingParams
from repro.serving.batching import (BatchedServingEngine, RequestQueue,
                                    parse_prefill_budget)
from repro.serving.frontend import ServingFrontend

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def run_load(cfg, params, prompts, *, rate: float, max_new: int,
             max_batch: int, policy: str, ttft_slo, seed: int = 0,
             prefill_budget=None, tbt_slo=None, fairness="rr",
             cancel_frac: float = 0.0, cancel_after: int = 2,
             arrival: str = "poisson") -> dict:
    """Offer `prompts` at mean rate `rate` req/s through a ServingFrontend
    (arrival process: poisson / bursty / ramp — benchmarks.common); drain;
    summarize. With cancel_frac > 0, an evenly spread fraction of requests
    is cancelled mid-flight once it has streamed `cancel_after` tokens."""
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    arrivals = t0 + arrival_offsets(arrival, rate, len(prompts), rng)

    queue = RequestQueue(AdmissionController(default_ttft_slo=ttft_slo))
    eng = BatchedServingEngine(cfg, params, policy=policy,
                               max_batch=max_batch,
                               max_seq=max(len(p) for p in prompts)
                               + max_new + 2,
                               prefill_budget=prefill_budget,
                               tbt_slo=tbt_slo, prefill_fairness=fairness,
                               queue=queue, temperature=0.0)
    fe = ServingFrontend(eng)
    n_cancel = int(round(len(prompts) * cancel_frac))
    # submission order == arrival order, so rid == prompt index
    cancel_rids = (set(np.linspace(0, len(prompts) - 1, n_cancel,
                                   dtype=int).tolist()) if n_cancel else set())
    handles = {}
    cancel_times = []

    pending = list(zip(arrivals, prompts))
    while pending or not fe.idle:
        now = time.perf_counter()
        while pending and pending[0][0] <= now:
            arr, p = pending.pop(0)
            h = fe.submit(GenerationRequest(
                prompt=p, params=SamplingParams(max_new_tokens=max_new),
                tbt_slo=tbt_slo, arrival=arr))
            handles[h.rid] = h
        ev = fe.poll(now)
        # mid-flight cancellation, timed off the event stream: the
        # FinishEvent's timestamp is when the engine finished reclaiming
        # the request's KV slot / residency / ledger resources
        for rid in sorted(cancel_rids):
            h = handles.get(rid)
            if h is None:
                continue
            if h.done:
                cancel_rids.discard(rid)
                continue
            if len(h.tokens) >= cancel_after:
                t_req = time.perf_counter()
                if h.cancel():
                    fin = h.events[-1]
                    cancel_times.append(fin.t - t_req)
                cancel_rids.discard(rid)
        if not ev.did_work and pending:
            # idle until the next arrival
            time.sleep(max(pending[0][0] - time.perf_counter(), 0.0))
    wall = time.perf_counter() - t0

    done = [r.result() for r in eng.finished]
    ttfts = [r.ttft_wall for r in done]
    tpots = [(r.e2e_wall - r.ttft_wall) / max(len(r.tokens) - 1, 1)
             for r in done]
    total_tokens = sum(len(r.tokens) for r in done)
    rec = {
        "rate_req_s": rate,
        "arrival": arrival,
        "offered": len(prompts),
        "completed": len(done),
        "rejected": len(eng.queue.rejected),
        "cancelled": len(eng.cancelled),
        "ttft": percentile_report(ttfts),
        "tpot": percentile_report(tpots),
        "tokens_per_s": total_tokens / max(wall, 1e-9),
        "mean_decode_batch": (float(np.mean(eng.decode_batch_hist))
                              if eng.decode_batch_hist else 0.0),
        "wall_s": wall,
    }
    if tbt_slo is not None:
        # per-request TBT-SLO attainment over each finished request's gaps
        atts = [eng.tbt.attainment(r.rid, tbt_slo) for r in eng.finished]
        atts = [a for a in atts if not np.isnan(a)]
        rec["tbt_attain_mean"] = float(np.mean(atts)) if atts else float("nan")
        rec["tbt_attain_full"] = (float(np.mean([a == 1.0 for a in atts]))
                                  if atts else float("nan"))
    if cancel_times:
        rec["time_to_cancel"] = percentile_report(cancel_times)
        rec["time_to_cancel"]["max"] = float(max(cancel_times))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--rates", default="0.5,2.0",
                    help="comma list of offered loads (requests/s)")
    ap.add_argument("--arrival", default="poisson", choices=list(ARRIVALS),
                    help="arrival process: stationary poisson, bursty "
                         "(Gamma-renewal clumping), or a linear rate ramp")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=5)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--policy", default="duo+")
    ap.add_argument("--ttft-slo", type=float, default=None,
                    help="seconds; requests predicted to breach are shed")
    ap.add_argument("--prefill-budget", default=None,
                    help="chunked prefill tokens per step, 'auto' to derive "
                         "from the live LatencyModel (needs --tbt-slo), or "
                         "omit for monolithic")
    ap.add_argument("--tbt-slo", type=float, default=None,
                    help="per-request inter-token-gap target (s): drives "
                         "admission, the auto budget, and the attainment "
                         "report")
    ap.add_argument("--fairness", default="rr", choices=["rr", "fifo", "srf"],
                    help="chunked-prefill budget sharing across requests")
    ap.add_argument("--cancel-frac", type=float, default=0.0,
                    help="fraction of requests cancelled mid-flight "
                         "(time-to-cancel measured off the event stream)")
    ap.add_argument("--cancel-after", type=int, default=2,
                    help="tokens a victim streams before it is cancelled")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    wl = PromptWorkload(squad_like(cfg.vocab), seed=11)
    prompts = [p[: args.prompt_len] for p, _ in wl.prompts(args.requests)]

    print(f"{'rate':>6s} {'done':>5s} {'shed':>5s} {'cancel':>6s} "
          f"{'ttft_p50':>9s} {'ttft_p99':>9s} {'tpot_p50':>9s} "
          f"{'tpot_p99':>9s} {'tok/s':>7s} {'avgB':>5s} {'tbt_att':>8s} "
          f"{'t_cancel':>9s}")
    records = []
    for rate in [float(r) for r in args.rates.split(",")]:
        rec = run_load(cfg, params, prompts, rate=rate,
                       max_new=args.max_new, max_batch=args.max_batch,
                       policy=args.policy, ttft_slo=args.ttft_slo,
                       prefill_budget=parse_prefill_budget(args.prefill_budget),
                       tbt_slo=args.tbt_slo, fairness=args.fairness,
                       cancel_frac=args.cancel_frac,
                       cancel_after=args.cancel_after,
                       arrival=args.arrival)
        records.append(rec)
        att = rec.get("tbt_attain_mean", float("nan"))
        ttc = rec.get("time_to_cancel", {}).get("p99", float("nan"))
        print(f"{rate:6.2f} {rec['completed']:5d} {rec['rejected']:5d} "
              f"{rec['cancelled']:6d} "
              f"{rec['ttft']['p50']:8.2f}s {rec['ttft']['p99']:8.2f}s "
              f"{rec['tpot']['p50']:8.2f}s {rec['tpot']['p99']:8.2f}s "
              f"{rec['tokens_per_s']:7.2f} {rec['mean_decode_batch']:5.2f} "
              f"{att:8.2f} {ttc * 1e3:8.1f}m")

    out = args.out
    if out is None:
        os.makedirs(RESULTS, exist_ok=True)
        out = os.path.join(RESULTS, "concurrent_qos.json")
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
