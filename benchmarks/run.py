"""Benchmark harness entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a summary footer on stderr).

  bench_latency     -> Fig. 5  (TTFT + E2E, 4 models x 2 datasets x 2 HW)
  bench_tail        -> Fig. 6  (P50/P95)
  bench_throughput  -> Fig. 7  (tokens/s vs batch 1..12)
  bench_memory      -> Table II (peak memory + GPU-only reference)
  bench_predictor   -> Table III (Top-k / at-least-half accuracy)
  roofline          -> §Roofline terms from the dry-run artifacts

--quick runs a reduced matrix (used by CI/pytest).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)

    from benchmarks import (bench_latency, bench_memory, bench_predictor,
                            bench_tail, bench_throughput, roofline)
    benches = {
        "latency": bench_latency.run,
        "tail": bench_tail.run,
        "throughput": bench_throughput.run,
        "memory": bench_memory.run,
        "predictor": bench_predictor.run,
        "roofline": roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,us_per_call,derived")
    n = 0
    for bname, fn in benches.items():
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # keep the harness running
            print(f"{bname}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
            n += 1
        print(f"# {bname} done in {time.time() - t0:.1f}s", file=sys.stderr)
    print(f"# total rows: {n}", file=sys.stderr)


if __name__ == "__main__":
    main()
