"""Cluster serving demo: N engine replicas behind a QoS-aware router, with
the SLO autopilot shedding doomed requests mid-flight.

The cluster layer in three moves (serving/cluster.py):

  1. Build replicas:  pool = ReplicaPool.build(cfg, params, n_replicas,
                      ...)  — each replica is a full BatchedServingEngine
                      with its own KV slots, queue, and ExpertResidency.
  2. Pick a router:   fe = ClusterFrontend(pool, router="slo_headroom")
                      round_robin | least_loaded | slo_headroom |
                      expert_affinity — the submit() surface is EXACTLY the
                      plain ServingFrontend's, so this is a one-line swap.
  3. Close the loop:  QosAutopilot(fe) — after every poll, requests whose
                      TTFT/TBT deadline is already unmeetable are shed
                      (FinishEvent reason="slo_shed"), freeing their
                      replica's KV slot and expert budget for survivors.

Plus the two elasticity moves built on the KV snapshot primitive
(BatchedServingEngine.snapshot/restore): a phase-DISAGGREGATED pool
(role="prefill" / role="decode" replicas, router="disagg" — finished
prefills hand their KV to a decode replica, handle follows, bit-exact)
and mid-flight replica DRAINING (pool.drain(i) migrates its in-flight
requests to the survivors, also bit-exact). And the memory move:
PREFIX CACHING (prefix_cache=True + router="prefix_affinity") — retired
slots are retained as a radix tree over the KV rows, admission reuses
the longest cached prefix, and the router co-locates same-template
requests on the warm replica, still bit-exact vs a cold prefill.

  PYTHONPATH=src python examples/serve_cluster.py --replicas 2 --requests 6
  PYTHONPATH=src python examples/serve_cluster.py --smoke   # CI
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models.model import build
from repro.serving.api import GenerationRequest, SamplingParams
from repro.serving.batching import (BatchedServingEngine,
                                    parse_prefill_budget)
from repro.serving.cluster import ClusterFrontend, QosAutopilot, ReplicaPool
from repro.serving.frontend import ServingFrontend


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--router", default="slo_headroom")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--policy", default="duo+")
    ap.add_argument("--prefill-budget", default="2")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fast run for CI: asserts 1-replica parity "
                         "vs the plain front-end, the per-replica expert-"
                         "HBM bound, and a deterministic autopilot shed")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto/chrome://tracing JSON timeline "
                         "of the disagg demo (open at ui.perfetto.dev)")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new = 4, 3

    cfg = reduced(get_config(args.arch))
    params = build(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    # alternating long/short prompts — the shape QoS-aware routing helps
    prompts = [rng.integers(0, cfg.vocab, size=(24 if i % 2 == 0 else 8))
               .astype(np.int32) for i in range(args.requests)]
    budget = parse_prefill_budget(args.prefill_budget)
    kw = dict(policy=args.policy, max_batch=args.max_batch, max_seq=64,
              prefill_budget=budget, temperature=0.0)

    # [cluster] route all requests across the replicas and stream them
    pool = ReplicaPool.build(cfg, params, args.replicas, **kw)
    fe = ClusterFrontend(pool, router=args.router)
    autopilot = QosAutopilot(fe)
    handles = [fe.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=args.max_new),
        ttft_slo=60.0)) for p in prompts]
    t0 = time.perf_counter()
    fe.drain()
    wall = time.perf_counter() - t0
    print(f"{args.requests} requests over {args.replicas} replicas, "
          f"router={args.router}, policy={args.policy}")
    for i, h in enumerate(handles):
        print(f"  req{i} (len {len(prompts[i]):2d}) -> replica "
              f"{h.replica}: tokens={list(h.tokens)} "
              f"reason={h.finish_reason}")
    balance = [sum(1 for h in handles if h.replica == i)
               for i in range(args.replicas)]
    print(f"balance={balance}  wall={wall:.2f}s  "
          f"autopilot shed={autopilot.n_shed}")
    hbm_ok = True
    for i, eng in enumerate(pool.engines):
        res = eng.cache
        ok = res.hbm_bound_ok
        hbm_ok &= ok
        print(f"  replica {i}: expert HBM {res.device_bytes / 2**20:.2f} "
              f"MiB == {res.pool_capacity} x "
              f"{res.bytes_per_expert / 2**20:.2f} MiB bound: "
              f"{'ok' if ok else 'VIOLATED'}")
    assert hbm_ok, "per-replica expert-HBM bound violated"
    assert all(h.finish_reason == "length" for h in handles)

    # [parity] a 1-replica cluster IS the plain front-end, bit for bit
    base = ServingFrontend(BatchedServingEngine(cfg, params, **kw))
    ref = [base.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=args.max_new)))
        for p in prompts]
    base.drain()
    one = ClusterFrontend(ReplicaPool.build(cfg, params, 1, **kw),
                          router=args.router)
    got = [one.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=args.max_new)))
        for p in prompts]
    one.drain()
    parity = all(list(r.tokens) == list(g.tokens)
                 for r, g in zip(ref, got))
    print(f"1-replica cluster bit-exact vs ServingFrontend: {parity}")
    assert parity, "1-replica cluster diverged from the plain front-end"

    # [autopilot] deterministic mid-flight shed: a decoding request with a
    # 60s TBT target (generous enough that no router/admission layer can
    # reject it, even on a slow machine), scanned with a clock 100s in the
    # future — its next token's deadline is long past, so the autopilot
    # cancels it with reason="slo_shed" and its replica's slot frees
    # immediately
    victim = fe.submit(GenerationRequest(
        prompt=prompts[0], params=SamplingParams(max_new_tokens=16),
        tbt_slo=60.0))
    while len(victim.tokens) < 2 and not victim.done:
        fe.poll()
    fe.poll(time.perf_counter() + 100.0)
    owner = pool.engines[victim.replica]
    print(f"autopilot demo: victim shed after {len(victim.tokens)} tokens "
          f"(reason={victim.finish_reason}, slot freed: "
          f"{victim.req.slot in owner._free}, engine n_slo_shed="
          f"{owner.n_slo_shed})")
    assert victim.finish_reason == "slo_shed"
    assert victim.req.slot in owner._free
    fe.drain()

    # [disagg] phase-disaggregated pool: 1 prefill + 1 decode replica.
    # The disagg router lands every new request on the prefill replica;
    # when its prefill finishes, the engine HOLDS it, the cluster snapshots
    # its KV prefix host-side and restores it on the decode replica — the
    # handle follows the request across the hop and the tokens match the
    # plain front-end bit for bit.
    dpool = ReplicaPool.build(
        cfg, params, spans=args.trace_out is not None,
        overrides=[{"role": "prefill"}, {"role": "decode"}], **kw)
    dfe = ClusterFrontend(dpool, router="disagg")
    dhs = [dfe.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=args.max_new)))
        for p in prompts]
    dfe.drain()
    for r, g in zip(ref, dhs):
        assert list(r.tokens) == list(g.tokens), "disagg diverged"
        assert g.handoffs and g.replica == 1
    print(f"disagg 1p+1d: {dpool.n_handoffs} prefill->decode handoffs "
          f"({dpool.handoff_bytes / 2**10:.1f} KiB host KV moved), "
          f"tokens bit-exact vs plain front-end")
    if args.trace_out:
        from repro.obs import write_trace
        trace = write_trace(args.trace_out, dpool.recorders())
        print(f"wrote {args.trace_out} ({len(trace['traceEvents'])} events) "
              "- open at https://ui.perfetto.dev")

    # [drain] elasticity: take a replica out of service MID-FLIGHT — its
    # queued/prefilling/running requests migrate to the survivors via the
    # same snapshot primitive, finish bit-exactly, and new work routes
    # around the draining replica until undrain().
    pool2 = ReplicaPool.build(cfg, params, 2, **kw)
    fe2 = ClusterFrontend(pool2, router="round_robin")
    hs2 = [fe2.submit(GenerationRequest(
        prompt=p, params=SamplingParams(max_new_tokens=args.max_new)))
        for p in prompts]
    fe2.poll()
    fe2.poll()
    pool2.drain(0)
    fe2.drain()
    assert pool2.engines[0].idle and 0 not in pool2.routable()
    for r, g in zip(ref, hs2):
        assert list(r.tokens) == list(g.tokens), "drain migration diverged"
    print(f"drain: replica 0 emptied mid-flight ({pool2.n_migrated} "
          f"requests migrated), all streams bit-exact; routable="
          f"{pool2.routable()}")
    pool2.undrain(0)

    # [prefix] cross-request KV reuse: two requests share prompts[0] as a
    # template (the second appends a short suffix). With prefix_cache=True
    # the first request's retired slot seeds the second's admission — only
    # the suffix is prefilled — and router="prefix_affinity" steers the
    # follower to the replica already holding the template. Tokens stay
    # bit-exact vs a cold engine without the cache.
    follow = np.concatenate([prompts[0],
                             rng.integers(0, cfg.vocab, size=4,
                                          dtype=np.int64).astype(np.int32)])
    pref_refs = []
    for p in (prompts[0], follow):
        cold = ServingFrontend(BatchedServingEngine(cfg, params, **kw))
        h = cold.submit(GenerationRequest(
            prompt=p, params=SamplingParams(max_new_tokens=args.max_new)))
        cold.drain()
        pref_refs.append(list(h.tokens))
    ppool = ReplicaPool.build(cfg, params, 2, prefix_cache=True, **kw)
    pfe = ClusterFrontend(ppool, router="prefix_affinity")
    ph0 = pfe.submit(GenerationRequest(
        prompt=prompts[0],
        params=SamplingParams(max_new_tokens=args.max_new)))
    pfe.drain()
    ph1 = pfe.submit(GenerationRequest(
        prompt=follow, params=SamplingParams(max_new_tokens=args.max_new)))
    pfe.drain()
    assert list(ph0.tokens) == pref_refs[0], "prefix reuse diverged"
    assert list(ph1.tokens) == pref_refs[1], "prefix reuse diverged"
    assert ph1.replica == ph0.replica, "follower missed the warm replica"
    warm_eng = ppool.engines[ph1.replica]
    assert warm_eng.prefix.hit_tokens >= len(prompts[0]) - 1
    print(f"prefix cache: follower reused {warm_eng.prefix.hit_tokens} "
          f"cached tokens on replica {ph1.replica} "
          f"(prefilled {warm_eng.prefilled_tokens} of "
          f"{len(prompts[0]) + len(follow)} prompt tokens), bit-exact "
          f"vs cold prefill")

    if args.smoke:
        print("serve_cluster smoke OK")


if __name__ == "__main__":
    main()
