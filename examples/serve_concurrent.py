"""Continuous-batching demo: concurrent requests through the batched engine
vs the same requests served one-by-one, with token-parity verification and
an SLO-shedding illustration.

  PYTHONPATH=src python examples/serve_concurrent.py --requests 4 --max-new 5
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.qos import AdmissionController, LatencyModel, percentile_report
from repro.data.pipeline import PromptWorkload, squad_like
from repro.models.model import build
from repro.serving.batching import (BatchedServingEngine, RequestQueue,
                                    parse_prefill_budget)
from repro.serving.engine import MoEServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=5)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--policy", default="duo+")
    ap.add_argument("--prefill-budget", default=None,
                    help="prompt tokens of chunked prefill per engine step "
                         "(stall-free interleaving), or 'auto' to derive "
                         "the chunk from the live LatencyModel via "
                         "--tbt-slo; default monolithic")
    ap.add_argument("--tbt-slo", type=float, default=None,
                    help="target inter-token gap (s) for auto budget")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    wl = PromptWorkload(squad_like(cfg.vocab), seed=5)
    prompts = [p[:16] for p, _ in wl.prompts(args.requests)]

    # sequential baseline (paper-scope engine, one request at a time)
    seq = MoEServingEngine(cfg, params, policy=args.policy, temperature=0.0)
    t0 = time.perf_counter()
    seq_results = [seq.serve(p, max_new=args.max_new) for p in prompts]
    seq_wall = time.perf_counter() - t0

    # continuous batching: all requests in flight, one shared expert cache
    eng = BatchedServingEngine(cfg, params, policy=args.policy,
                               max_batch=args.max_batch, max_seq=64,
                               prefill_budget=parse_prefill_budget(
                                   args.prefill_budget),
                               tbt_slo=args.tbt_slo,
                               temperature=0.0)
    t0 = time.perf_counter()
    for p in prompts:
        eng.submit(p, max_new=args.max_new)
    finished = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    batch_wall = time.perf_counter() - t0

    print(f"{args.requests} requests, max_new={args.max_new}, "
          f"policy={args.policy}")
    ok = True
    for i, (r, s) in enumerate(zip(finished, seq_results)):
        same = bool(np.array_equal(r.result().tokens, s.tokens))
        ok &= same
        print(f"  req{i}: tokens={r.result().tokens.tolist()} "
              f"match_sequential={same}")
    ttfts = [r.result().ttft_wall for r in finished]
    print(f"sequential wall: {seq_wall:6.2f}s   "
          f"batched wall: {batch_wall:6.2f}s "
          f"({seq_wall / max(batch_wall, 1e-9):.2f}x)")
    print(f"batched TTFT: {percentile_report(ttfts)}  "
          f"mean decode batch: {np.mean(eng.decode_batch_hist):.2f}")
    assert ok, "batched tokens diverged from sequential"

    # SLO shedding: a pessimistic cost model + tight deadline -> reject
    queue = RequestQueue(AdmissionController(
        LatencyModel(prefill_per_token=10.0), default_ttft_slo=1.0))
    shed = BatchedServingEngine(cfg, params, policy=args.policy,
                                max_batch=2, max_seq=64, queue=queue,
                                temperature=0.0)
    shed.submit(prompts[0], max_new=2)
    shed.run_until_drained(max_steps=10)
    print(f"SLO demo: {len(queue.rejected)} request(s) shed "
          f"(predicted TTFT over a 1s deadline)")


if __name__ == "__main__":
    main()
